"""Render the EXPERIMENTS.md §Roofline table from experiments/dryrun JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_table [--tag single]
"""
from __future__ import annotations

import argparse
import glob
import json


def load(tag: str):
    rows = []
    for f in sorted(glob.glob(f"experiments/dryrun/*__{tag}.json")):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_e(x):
    return f"{x:.2e}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="single")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.tag)
    hdr = ("arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
           "dominant", "useful", "peak_GB/dev")
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for d in rows:
        if d.get("skipped"):
            cells = (d["arch"], d["shape"], "-", "-", "-", "SKIP", "-", "-")
        elif "error" in d:
            cells = (d["arch"], d["shape"], "-", "-", "-", "ERROR", "-", "-")
        else:
            r = d["roofline"]
            peak = d["memory"].get("peak_bytes") or 0
            arg = d["memory"].get("argument_bytes") or 0
            cells = (d["arch"], d["shape"], fmt_e(r["t_compute_s"]),
                     fmt_e(r["t_memory_s"]), fmt_e(r["t_collective_s"]),
                     r["dominant"], f"{d['useful_flops_ratio']:.2f}",
                     f"{(peak + arg) / 1e9:.1f}")
        if args.md:
            print("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            print(",".join(str(c) for c in cells))


if __name__ == "__main__":
    main()
