"""PNPCoin benchmark harness.

The paper has no result tables (position paper) — each benchmark pins one
of its quantitative *claims* instead:

  hash_flops      §1 fn.1  "20 FLOPS per hash" -> measured FLOP/hash of our
                           SHA-256 + the implied network-FLOPS arithmetic
  network_claim   §1       34 EH/s x FLOP/hash vs 200 PFLOP/s Summit
  block_turnaround §3      "computed ... for a turnaround of minutes"
  mode_overhead   §3.3     full vs optimal aggregation cost
  pouw_overhead   §1/§5    training-as-mining vs plain training loop
                           (the paper's implicit baseline)
  docking         §4       use-case throughput (pairs/s)
  verification    §3/DESIGN quorum re-execution cost vs fraction
  roofline        (e)/(g)  dry-run roofline table from experiments/dryrun

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def row(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def _timeit(fn, *args, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6       # us


# ---------------------------------------------------------------------------


def bench_hash_flops():
    """§1 footnote: 'we consider 20 FLOPS per hash, but this can be 20000
    on a modern CPU'."""
    from repro.kernels.ops import sha256_words
    msg = jnp.zeros((4096, 20), jnp.uint32)           # 80-byte headers
    lowered = jax.jit(lambda m: sha256_words(m)).lower(msg)
    cost = lowered.cost_analysis() or {}
    flops_per_hash = float(cost.get("flops", 0.0)) / msg.shape[0]
    us = _timeit(jax.jit(lambda m: sha256_words(m)), msg)
    hashes_per_s = msg.shape[0] / (us * 1e-6)
    row("hash_flops.flop_per_hash", us / msg.shape[0],
        f"flops_per_hash={flops_per_hash:.0f} (paper assumes 20..20000)")
    row("hash_flops.throughput", us,
        f"hashes_per_s={hashes_per_s:.3g} (1 CPU miner)")
    return flops_per_hash


def bench_network_claim(flops_per_hash: float):
    """§1: 34e18 hash/s * FLOP/hash vs Summit 200 PFLOP/s = 'four orders
    of magnitude' / '50000 supercomputers'."""
    network_hs = 34e18
    summit = 200e15
    for label, fph in [("paper_20", 20.0), ("measured", flops_per_hash)]:
        implied = network_hs * fph
        ratio = implied / summit
        row(f"network_claim.{label}", 0.0,
            f"implied_flops={implied:.3g} summit_ratio={ratio:.3g}")


def bench_block_turnaround():
    """§3: block turnaround for three payload kinds on this 1-CPU miner."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.authority import classic_jash
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta, collatz_jash
    from repro.core.pow_train import PoUWTrainer
    from repro.train.steps import TrainHparams

    # classic (sha256) block over 2^12 args
    t0 = time.perf_counter()
    run_full(Jash("c", classic_jash().fn, JashMeta(arg_bits=12, res_bits=256),
                  example_args=(jnp.uint32(0),)))
    row("block_turnaround.classic_4096args",
        (time.perf_counter() - t0) * 1e6, "full sha256 block")

    # collatz block
    j = collatz_jash(max_steps=512)
    j2 = Jash(j.name, j.fn, JashMeta(arg_bits=12, res_bits=32),
              example_args=j.example_args)
    t0 = time.perf_counter()
    run_full(j2)
    row("block_turnaround.collatz_4096args",
        (time.perf_counter() - t0) * 1e6, "bounded-while block")

    # training block
    cfg = reduced(get_config("qwen3-0.6b"))
    tr = PoUWTrainer(cfg, InputShape("t", 64, 8, "train"),
                     hp=TrainHparams(), mode="full", n_miners=4)
    tr.run_block()                                    # compile
    t0 = time.perf_counter()
    tr.run_block()
    row("block_turnaround.train_block",
        (time.perf_counter() - t0) * 1e6, "PoUW train step + ledger")


def bench_mode_overhead():
    from repro.core.executor import run_full, run_optimal
    from repro.core.jash import Jash, JashMeta

    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(0xDEADBEEF)

    j = Jash("mix", fn, JashMeta(arg_bits=14, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    run_full(j)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_optimal(j)
    t_opt = time.perf_counter() - t0
    row("mode_overhead.full_16k", t_full * 1e6, "all results + hashes")
    row("mode_overhead.optimal_16k", t_opt * 1e6,
        f"argmin only; full/optimal={t_full / max(t_opt, 1e-9):.2f}x")


def bench_pouw_overhead():
    """Training-as-mining vs plain training: ledger/merkle/reward cost."""
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.pow_train import PoUWTrainer
    from repro.data.pipeline import SyntheticTokenPipeline
    from repro.train.steps import (TrainHparams, make_train_state,
                                   make_train_step)

    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 64, 8, "train")
    hp = TrainHparams()
    n = 5

    # plain baseline
    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    state = make_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, hp))
    state, _ = step(state, pipe.batch(0))             # compile
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, pipe.batch(i + 1))
    jax.block_until_ready(m["loss"])
    t_plain = (time.perf_counter() - t0) / n

    # PoUW chain
    tr = PoUWTrainer(cfg, shape, hp=hp, mode="full", n_miners=4)
    tr.run_block()
    t0 = time.perf_counter()
    tr.run(n)
    t_pouw = (time.perf_counter() - t0) / n

    tokens = shape.global_batch * shape.seq_len
    row("pouw_overhead.plain_step", t_plain * 1e6,
        f"tokens_per_s={tokens / t_plain:.0f}")
    row("pouw_overhead.pouw_block", t_pouw * 1e6,
        f"tokens_per_s={tokens / t_pouw:.0f} "
        f"overhead={(t_pouw / t_plain - 1) * 100:.1f}%")


def bench_docking():
    """§4 use case: pairs/s through the full-mode pipeline."""
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta

    N_R, N_P = 64, 64

    def matcher(b):
        r, p = b % jnp.uint32(N_R), b // jnp.uint32(N_R)
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 200, jnp.uint32(1), jnp.uint32(0))

    j = Jash("dock", matcher,
             JashMeta(arg_bits=12, res_bits=2, max_arg=N_R * N_P),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    dt = time.perf_counter() - t0
    binds = int((fr.results[:, 0] == 1).sum())
    row("docking.full_4096_pairs", dt * 1e6,
        f"pairs_per_s={N_R * N_P / dt:.0f} binds={binds}")


def bench_verification():
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.core.verify import quorum_verify

    def fn(a):
        return a * jnp.uint32(2654435761)

    j = Jash("v", fn, JashMeta(arg_bits=12, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    t_mine = time.perf_counter() - t0
    for frac in (0.05, 0.25):
        t0 = time.perf_counter()
        rep = quorum_verify(j, fr, fraction=frac)
        dt = time.perf_counter() - t0
        row(f"verification.frac_{frac}", dt * 1e6,
            f"checked={rep.n_checked} verify/mine={dt / max(t_mine, 1e-9):.3f}")


def bench_roofline():
    """Emit the dry-run roofline table (deliverable (g)) as CSV rows."""
    files = sorted(glob.glob("experiments/dryrun/*__single.json"))
    if not files:
        row("roofline.missing", 0.0, "run launch/dryrun first")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0,
                f"SKIP: {d['reason'][:50]}")
            continue
        if "error" in d:
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0, "ERROR")
            continue
        r = d["roofline"]
        t_total = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline.{d['arch']}.{d['shape']}", t_total * 1e6,
            f"dom={r['dominant']} tc={r['t_compute_s']:.2e} "
            f"tm={r['t_memory_s']:.2e} tx={r['t_collective_s']:.2e} "
            f"useful={d['useful_flops_ratio']:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    fph = bench_hash_flops()
    bench_network_claim(fph)
    bench_block_turnaround()
    bench_mode_overhead()
    bench_pouw_overhead()
    bench_docking()
    bench_verification()
    bench_roofline()
    print(f"# {len(ROWS)} rows")


if __name__ == "__main__":
    main()
