"""PNPCoin benchmark harness.

The paper has no result tables (position paper) — each benchmark pins one
of its quantitative *claims* instead:

  hash_flops      §1 fn.1  "20 FLOPS per hash" -> measured FLOP/hash of our
                           SHA-256 + the implied network-FLOPS arithmetic
  network_claim   §1       34 EH/s x FLOP/hash vs 200 PFLOP/s Summit
  block_turnaround §3      "computed ... for a turnaround of minutes"
  mode_overhead   §3.3     full vs optimal aggregation cost
  pouw_overhead   §1/§5    training-as-mining vs plain training loop
                           (the paper's implicit baseline)
  docking         §4       use-case throughput (pairs/s)
  verification    §3/DESIGN quorum re-execution cost vs fraction
  roofline        (e)/(g)  dry-run roofline table from experiments/dryrun
  merkle_commit   DESIGN §6 device block commitment vs the seed Python path
  executor_chunked DESIGN §6 chunked fused full-mode dispatch
  block_scan      DESIGN §6 scan-fused PoUW block vs per-microstep dispatch
  sim_gossip      DESIGN §9 async gossip sim: fork depth, orphan rate,
                  time-to-finality under partitions and adversaries
                  (consumes the SimReport of the canonical scenarios)

Prints ``name,us_per_call,derived`` CSV rows.  The commit-pipeline rows
are also written machine-readably to BENCH_pipeline.json (repo root) so
subsequent PRs can track the trajectory.  ``--smoke`` runs only a reduced
commit-pipeline subset (CI).
"""
from __future__ import annotations

import glob
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []
BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_pipeline.json")


def row(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def _timeit(fn, *args, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6       # us


# ---------------------------------------------------------------------------


def bench_hash_flops():
    """§1 footnote: 'we consider 20 FLOPS per hash, but this can be 20000
    on a modern CPU'."""
    from repro.core.compat import cost_analysis_dict
    from repro.kernels.ops import sha256_words
    msg = jnp.zeros((4096, 20), jnp.uint32)           # 80-byte headers
    lowered = jax.jit(lambda m: sha256_words(m)).lower(msg)
    cost = cost_analysis_dict(lowered.cost_analysis())
    flops_per_hash = float(cost.get("flops", 0.0)) / msg.shape[0]
    us = _timeit(jax.jit(lambda m: sha256_words(m)), msg)
    hashes_per_s = msg.shape[0] / (us * 1e-6)
    row("hash_flops.flop_per_hash", us / msg.shape[0],
        f"flops_per_hash={flops_per_hash:.0f} (paper assumes 20..20000)")
    row("hash_flops.throughput", us,
        f"hashes_per_s={hashes_per_s:.3g} (1 CPU miner)")
    return flops_per_hash


def bench_network_claim(flops_per_hash: float):
    """§1: 34e18 hash/s * FLOP/hash vs Summit 200 PFLOP/s = 'four orders
    of magnitude' / '50000 supercomputers'."""
    network_hs = 34e18
    summit = 200e15
    for label, fph in [("paper_20", 20.0), ("measured", flops_per_hash)]:
        implied = network_hs * fph
        ratio = implied / summit
        row(f"network_claim.{label}", 0.0,
            f"implied_flops={implied:.3g} summit_ratio={ratio:.3g}")


def bench_block_turnaround():
    """§3: block turnaround for three payload kinds on this 1-CPU miner."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.authority import classic_jash
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta, collatz_jash
    from repro.core.pow_train import PoUWTrainer
    from repro.train.steps import TrainHparams

    # classic (sha256) block over 2^12 args
    t0 = time.perf_counter()
    run_full(Jash("c", classic_jash().fn, JashMeta(arg_bits=12, res_bits=256),
                  example_args=(jnp.uint32(0),)))
    row("block_turnaround.classic_4096args",
        (time.perf_counter() - t0) * 1e6, "full sha256 block")

    # collatz block
    j = collatz_jash(max_steps=512)
    j2 = Jash(j.name, j.fn, JashMeta(arg_bits=12, res_bits=32),
              example_args=j.example_args)
    t0 = time.perf_counter()
    run_full(j2)
    row("block_turnaround.collatz_4096args",
        (time.perf_counter() - t0) * 1e6, "bounded-while block")

    # training block
    cfg = reduced(get_config("qwen3-0.6b"))
    tr = PoUWTrainer(cfg, InputShape("t", 64, 8, "train"),
                     hp=TrainHparams(), mode="full", n_miners=4)
    tr.run_block()                                    # compile
    t0 = time.perf_counter()
    tr.run_block()
    row("block_turnaround.train_block",
        (time.perf_counter() - t0) * 1e6, "PoUW train step + ledger")


def bench_mode_overhead():
    from repro.core.executor import run_full, run_optimal
    from repro.core.jash import Jash, JashMeta

    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(0xDEADBEEF)

    j = Jash("mix", fn, JashMeta(arg_bits=14, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    run_full(j)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_optimal(j)
    t_opt = time.perf_counter() - t0
    row("mode_overhead.full_16k", t_full * 1e6, "all results + hashes")
    row("mode_overhead.optimal_16k", t_opt * 1e6,
        f"argmin only; full/optimal={t_full / max(t_opt, 1e-9):.2f}x")


def bench_pouw_overhead():
    """Training-as-mining vs plain training: ledger/merkle/reward cost."""
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.pow_train import PoUWTrainer
    from repro.data.pipeline import SyntheticTokenPipeline
    from repro.train.steps import (TrainHparams, make_train_state,
                                   make_train_step)

    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 64, 8, "train")
    hp = TrainHparams()
    n = 5

    # plain baseline
    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    state = make_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, hp))
    state, _ = step(state, pipe.batch(0))             # compile
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, pipe.batch(i + 1))
    jax.block_until_ready(m["loss"])
    t_plain = (time.perf_counter() - t0) / n

    # PoUW chain
    tr = PoUWTrainer(cfg, shape, hp=hp, mode="full", n_miners=4)
    tr.run_block()
    t0 = time.perf_counter()
    tr.run(n)
    t_pouw = (time.perf_counter() - t0) / n

    tokens = shape.global_batch * shape.seq_len
    row("pouw_overhead.plain_step", t_plain * 1e6,
        f"tokens_per_s={tokens / t_plain:.0f}")
    row("pouw_overhead.pouw_block", t_pouw * 1e6,
        f"tokens_per_s={tokens / t_pouw:.0f} "
        f"overhead={(t_pouw / t_plain - 1) * 100:.1f}%")


def bench_docking():
    """§4 use case: pairs/s through the full-mode pipeline."""
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta

    N_R, N_P = 64, 64

    def matcher(b):
        r, p = b % jnp.uint32(N_R), b // jnp.uint32(N_R)
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 200, jnp.uint32(1), jnp.uint32(0))

    j = Jash("dock", matcher,
             JashMeta(arg_bits=12, res_bits=2, max_arg=N_R * N_P),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    dt = time.perf_counter() - t0
    binds = int((fr.results[:, 0] == 1).sum())
    row("docking.full_4096_pairs", dt * 1e6,
        f"pairs_per_s={N_R * N_P / dt:.0f} binds={binds}")


def bench_verification():
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.core.verify import quorum_verify

    def fn(a):
        return a * jnp.uint32(2654435761)

    j = Jash("v", fn, JashMeta(arg_bits=12, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    t_mine = time.perf_counter() - t0
    for frac in (0.05, 0.25):
        t0 = time.perf_counter()
        rep = quorum_verify(j, fr, fraction=frac)
        dt = time.perf_counter() - t0
        row(f"verification.frac_{frac}", dt * 1e6,
            f"checked={rep.n_checked} verify/mine={dt / max(t_mine, 1e-9):.3f}")


def _median_ms(fn, n: int) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e3


def bench_commit_pipeline(n_leaves: int = 4096,
                          write_json: bool = True) -> dict:
    """DESIGN.md §6: the on-device block-commitment pipeline vs the seed.

    merkle_commit compares the seed's end-to-end commit path from a mined
    FullResult — the per-arg Python loop building leaf bytes plus the
    Python/hashlib ``merkle_root`` (exactly the code the pipeline
    replaced) — against ``FullResult.commit_root()``, the fused device
    tree over the leaf digests the executor already computed in-dispatch.
    The hashlib-root-only baseline (no leaf building) is recorded too.
    """
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.core.ledger import merkle_root
    from repro.core.pow_train import PoUWTrainer
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.train.steps import TrainHparams

    arg_bits = int(np.log2(n_leaves))
    assert 1 << arg_bits == n_leaves

    def mixer(a):
        h = a * jnp.uint32(2654435761)
        return jnp.stack(
            [(h ^ jnp.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF)) *
             jnp.uint32(2246822519) for i in range(8)])

    j = Jash("commit-bench", mixer,
             JashMeta(arg_bits=arg_bits, res_bits=256),
             example_args=(jnp.uint32(0),))

    # --- executor_chunked: the fused full-mode dispatch ------------------
    run_full(j)                                        # compile
    us_full = _median_ms(lambda: run_full(j), 5) * 1e3
    run_full(j, chunk_size=n_leaves // 4)              # compile (same shape?)
    us_chunk = _median_ms(lambda: run_full(j, chunk_size=n_leaves // 4),
                          5) * 1e3
    row("executor_chunked.one_dispatch", us_full,
        f"args_per_s={n_leaves / (us_full * 1e-6):.3g}")
    row("executor_chunked.four_chunks", us_chunk,
        f"args_per_s={n_leaves / (us_chunk * 1e-6):.3g} bit-identical")

    # --- merkle_commit ---------------------------------------------------
    fr = run_full(j)

    def seed_commit():
        # the seed's commit path, verbatim: per-i leaf bytes + hashlib tree
        leaves = tuple(fr.args[i].tobytes() + fr.results[i].tobytes()
                       for i in range(len(fr.args)))
        return merkle_root(leaves, backend="hashlib")

    leaves_prebuilt = fr.merkle_leaves
    fr.commit_root()                                   # compile device tree
    assert fr.commit_root() == seed_commit()           # bit-identical
    ms_seed = _median_ms(seed_commit, 7)
    ms_root_only = _median_ms(
        lambda: merkle_root(leaves_prebuilt, backend="hashlib"), 7)
    ms_dev = _median_ms(fr.commit_root, 15)
    speedup = ms_seed / ms_dev
    row("merkle_commit.seed_path", ms_seed * 1e3,
        "python leaf build + hashlib merkle_root (seed code)")
    row("merkle_commit.hashlib_root_only", ms_root_only * 1e3,
        "hashlib merkle_root on prebuilt leaves")
    row("merkle_commit.device", ms_dev * 1e3,
        f"speedup={speedup:.2f}x vs seed path "
        f"({ms_root_only / ms_dev:.2f}x vs root-only)")

    # --- block_scan: scan-fused PoUW block -------------------------------
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 32, 4, "train")
    micro = 4
    tr = PoUWTrainer(cfg, shape, hp=TrainHparams(), mode="full",
                     n_miners=4, block_microsteps=micro)
    tr.run_block()                                     # compile scan block
    ms_scan = _median_ms(tr.run_block, 3)

    state, batch = tr.state, tr.pipeline.batch(0)
    tr._train_step(state, batch)                       # compile single step

    def seed_microsteps():
        s = state
        for _ in range(micro):
            s, m = tr._train_step(s, batch)
        jax.block_until_ready(m["loss"])

    ms_seed_steps = _median_ms(seed_microsteps, 3)
    row("block_scan.scan_block", ms_scan * 1e3,
        f"{micro} microsteps, one dispatch + ledger")
    row("block_scan.per_step_dispatch", ms_seed_steps * 1e3,
        f"seed pattern: {micro} dispatches, no ledger; "
        f"scan/step={ms_scan / ms_seed_steps:.2f}")

    payload = {
        "n_leaves": n_leaves,
        "merkle_commit": {
            "us_seed_path": ms_seed * 1e3,
            "us_hashlib_root_only": ms_root_only * 1e3,
            "us_device": ms_dev * 1e3,
            "speedup": speedup,
            "speedup_vs_root_only": ms_root_only / ms_dev,
            "baseline": "seed commit path: per-arg Python leaf build + "
                        "hashlib merkle_root, as in the seed executor",
        },
        "executor_chunked": {
            "us_one_dispatch": us_full,
            "us_four_chunks": us_chunk,
            "args_per_s": n_leaves / (us_full * 1e-6),
        },
        "block_scan": {
            "block_microsteps": micro,
            "us_scan_block": ms_scan * 1e3,
            "us_per_step_dispatch": ms_seed_steps * 1e3,
        },
    }
    if write_json:
        with open(BENCH_JSON, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# wrote {os.path.abspath(BENCH_JSON)}")
    return payload


def bench_sim_gossip(n_lanes: int = 1):
    """DESIGN §9: the async gossip simulator under partition + adversary
    scenarios.  Each row consumes the deterministic ``SimReport`` — fork
    depth histogram, orphan rate, time-to-finality — plus the wallclock
    cost of driving the scenario (events/s is the simulator's own
    overhead figure; block *mining* dominates it)."""
    from repro.chain.sim import adversarial_scenario, partitioned_scenario

    for name, build in (
        ("partition_4node",
         lambda: partitioned_scenario(n_nodes=4, seed=0,
                                      n_lanes=n_lanes)),
        ("adversarial_5node",
         lambda: adversarial_scenario(n_honest=3, seed=0)),
    ):
        sim = build()
        t0 = time.perf_counter()
        rep = sim.run()
        dt = time.perf_counter() - t0
        assert rep.converged and rep.credit_divergence == 0.0, name
        depths = ";".join(f"d{k}x{v}"
                          for k, v in rep.fork_depth_hist.items())
        row(f"sim_gossip.{name}", dt * 1e6,
            f"events={rep.n_events} events_per_s={rep.n_events / dt:.0f} "
            f"mined={rep.blocks_mined} orphan_rate={rep.orphan_rate:.2f} "
            f"forks=[{depths}] ttf_mean_s={rep.ttf_mean:.2f} "
            f"ttf_max_s={rep.ttf_max:.2f}")


def bench_roofline():
    """Emit the dry-run roofline table (deliverable (g)) as CSV rows."""
    files = sorted(glob.glob("experiments/dryrun/*__single.json"))
    if not files:
        row("roofline.missing", 0.0, "run launch/dryrun first")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0,
                f"SKIP: {d['reason'][:50]}")
            continue
        if "error" in d:
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0, "ERROR")
            continue
        r = d["roofline"]
        t_total = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline.{d['arch']}.{d['shape']}", t_total * 1e6,
            f"dom={r['dominant']} tc={r['t_compute_s']:.2e} "
            f"tm={r['t_memory_s']:.2e} tx={r['t_collective_s']:.2e} "
            f"useful={d['useful_flops_ratio']:.2f}")


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    if smoke:
        # CI subset: the commit pipeline at a reduced leaf count (full
        # 4096-leaf numbers are recorded in the committed
        # BENCH_pipeline.json by a full run) + the gossip sim scenarios
        bench_commit_pipeline(n_leaves=256, write_json=False)
        bench_sim_gossip()
        print(f"# {len(ROWS)} rows (smoke)")
        return
    fph = bench_hash_flops()
    bench_network_claim(fph)
    bench_block_turnaround()
    bench_mode_overhead()
    bench_pouw_overhead()
    bench_docking()
    bench_verification()
    bench_commit_pipeline()
    bench_sim_gossip()
    bench_roofline()
    print(f"# {len(ROWS)} rows")


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="fast CI subset (commit pipeline only, small N)")
    main(smoke=p.parse_args().smoke)
