"""PNPCoin benchmark harness.

The paper has no result tables (position paper) — each benchmark pins one
of its quantitative *claims* instead:

  hash_flops      §1 fn.1  "20 FLOPS per hash" -> measured FLOP/hash of our
                           SHA-256 + the implied network-FLOPS arithmetic
  network_claim   §1       34 EH/s x FLOP/hash vs 200 PFLOP/s Summit
  block_turnaround §3      "computed ... for a turnaround of minutes"
  mode_overhead   §3.3     full vs optimal aggregation cost
  pouw_overhead   §1/§5    training-as-mining vs plain training loop
                           (the paper's implicit baseline)
  docking         §4       use-case throughput (pairs/s)
  verification    §3/DESIGN quorum re-execution cost vs fraction
  roofline        (e)/(g)  dry-run roofline table from experiments/dryrun
  merkle_commit   DESIGN §6 device block commitment vs the seed Python path
  executor_chunked DESIGN §6 chunked fused full-mode dispatch
  block_scan      DESIGN §6 scan-fused PoUW block vs per-microstep dispatch
  sim_gossip      DESIGN §9 async gossip sim: fork depth, orphan rate,
                  time-to-finality under partitions and adversaries
                  (consumes the SimReport of the canonical scenarios),
                  plus the DESIGN §10 scale scenarios (16x128, 64x512)
                  the shared verify cache makes tractable
  verify_pipeline DESIGN §10 ``verify_chain_batched`` over a mixed
                  256-block segment vs the per-block receive-path loop
  workload_suite  DESIGN §11 application workloads (SAT / GAN inversion /
                  docking): mine + verify throughput per family, and the
                  SAT certificate-check vs re-mine asymmetry

Prints ``name,us_per_call,derived`` CSV rows.  The pipeline rows are
also written machine-readably to BENCH_pipeline.json (repo root): the
latest run's rows sit at the top level and every full run appends a
``history`` entry (git sha, date, rows), so the perf trajectory across
PRs stays recorded.  ``--smoke`` runs a reduced subset (CI) and *gates*:
the reduced ``merkle_commit`` and ``verify_chain_batched`` timings are
compared against the committed ``smoke_baseline`` and the run fails on a
>2.5x slowdown (generous tolerance for CI jitter).
"""
from __future__ import annotations

import glob
import json
import os
import statistics
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []
BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_pipeline.json")

# --smoke fails when a gated metric is slower than the committed
# smoke_baseline by more than this factor (CI-jitter tolerance)
SMOKE_SLOWDOWN_LIMIT = 2.5


_QUIET = False     # True while the full run re-measures at smoke scale


def row(name: str, us_per_call: float, derived: str = "") -> None:
    if _QUIET:
        # the full run's smoke-baseline pass re-runs sections at
        # reduced scale; emitting their rows would duplicate names
        # (e.g. merkle_commit.device) with conflicting timings
        return
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def _timeit(fn, *args, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6       # us


# ---------------------------------------------------------------------------


def bench_hash_flops():
    """§1 footnote: 'we consider 20 FLOPS per hash, but this can be 20000
    on a modern CPU'."""
    from repro.core.compat import cost_analysis_dict
    from repro.kernels.ops import sha256_words
    msg = jnp.zeros((4096, 20), jnp.uint32)           # 80-byte headers
    lowered = jax.jit(lambda m: sha256_words(m)).lower(msg)
    cost = cost_analysis_dict(lowered.cost_analysis())
    flops_per_hash = float(cost.get("flops", 0.0)) / msg.shape[0]
    us = _timeit(jax.jit(lambda m: sha256_words(m)), msg)
    hashes_per_s = msg.shape[0] / (us * 1e-6)
    row("hash_flops.flop_per_hash", us / msg.shape[0],
        f"flops_per_hash={flops_per_hash:.0f} (paper assumes 20..20000)")
    row("hash_flops.throughput", us,
        f"hashes_per_s={hashes_per_s:.3g} (1 CPU miner)")
    return flops_per_hash


def bench_network_claim(flops_per_hash: float):
    """§1: 34e18 hash/s * FLOP/hash vs Summit 200 PFLOP/s = 'four orders
    of magnitude' / '50000 supercomputers'."""
    network_hs = 34e18
    summit = 200e15
    for label, fph in [("paper_20", 20.0), ("measured", flops_per_hash)]:
        implied = network_hs * fph
        ratio = implied / summit
        row(f"network_claim.{label}", 0.0,
            f"implied_flops={implied:.3g} summit_ratio={ratio:.3g}")


def bench_block_turnaround():
    """§3: block turnaround for three payload kinds on this 1-CPU miner."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.authority import classic_jash
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta, collatz_jash
    from repro.core.pow_train import PoUWTrainer
    from repro.train.steps import TrainHparams

    # classic (sha256) block over 2^12 args
    t0 = time.perf_counter()
    run_full(Jash("c", classic_jash().fn, JashMeta(arg_bits=12, res_bits=256),
                  example_args=(jnp.uint32(0),)))
    row("block_turnaround.classic_4096args",
        (time.perf_counter() - t0) * 1e6, "full sha256 block")

    # collatz block
    j = collatz_jash(max_steps=512)
    j2 = Jash(j.name, j.fn, JashMeta(arg_bits=12, res_bits=32),
              example_args=j.example_args)
    t0 = time.perf_counter()
    run_full(j2)
    row("block_turnaround.collatz_4096args",
        (time.perf_counter() - t0) * 1e6, "bounded-while block")

    # training block
    cfg = reduced(get_config("qwen3-0.6b"))
    tr = PoUWTrainer(cfg, InputShape("t", 64, 8, "train"),
                     hp=TrainHparams(), mode="full", n_miners=4)
    tr.run_block()                                    # compile
    t0 = time.perf_counter()
    tr.run_block()
    row("block_turnaround.train_block",
        (time.perf_counter() - t0) * 1e6, "PoUW train step + ledger")


def bench_mode_overhead():
    from repro.core.executor import run_full, run_optimal
    from repro.core.jash import Jash, JashMeta

    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(0xDEADBEEF)

    j = Jash("mix", fn, JashMeta(arg_bits=14, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    run_full(j)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_optimal(j)
    t_opt = time.perf_counter() - t0
    row("mode_overhead.full_16k", t_full * 1e6, "all results + hashes")
    row("mode_overhead.optimal_16k", t_opt * 1e6,
        f"argmin only; full/optimal={t_full / max(t_opt, 1e-9):.2f}x")


def bench_pouw_overhead():
    """Training-as-mining vs plain training: ledger/merkle/reward cost."""
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.core.pow_train import PoUWTrainer
    from repro.data.pipeline import SyntheticTokenPipeline
    from repro.train.steps import (TrainHparams, make_train_state,
                                   make_train_step)

    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 64, 8, "train")
    hp = TrainHparams()
    n = 5

    # plain baseline
    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    state = make_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, hp))
    state, _ = step(state, pipe.batch(0))             # compile
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, pipe.batch(i + 1))
    jax.block_until_ready(m["loss"])
    t_plain = (time.perf_counter() - t0) / n

    # PoUW chain
    tr = PoUWTrainer(cfg, shape, hp=hp, mode="full", n_miners=4)
    tr.run_block()
    t0 = time.perf_counter()
    tr.run(n)
    t_pouw = (time.perf_counter() - t0) / n

    tokens = shape.global_batch * shape.seq_len
    row("pouw_overhead.plain_step", t_plain * 1e6,
        f"tokens_per_s={tokens / t_plain:.0f}")
    row("pouw_overhead.pouw_block", t_pouw * 1e6,
        f"tokens_per_s={tokens / t_pouw:.0f} "
        f"overhead={(t_pouw / t_plain - 1) * 100:.1f}%")


def bench_docking():
    """§4 use case: pairs/s through the full-mode pipeline."""
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta

    N_R, N_P = 64, 64

    def matcher(b):
        r, p = b % jnp.uint32(N_R), b // jnp.uint32(N_R)
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 200, jnp.uint32(1), jnp.uint32(0))

    j = Jash("dock", matcher,
             JashMeta(arg_bits=12, res_bits=2, max_arg=N_R * N_P),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    dt = time.perf_counter() - t0
    binds = int((fr.results[:, 0] == 1).sum())
    row("docking.full_4096_pairs", dt * 1e6,
        f"pairs_per_s={N_R * N_P / dt:.0f} binds={binds}")


def bench_verification():
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.core.verify import quorum_verify

    def fn(a):
        return a * jnp.uint32(2654435761)

    j = Jash("v", fn, JashMeta(arg_bits=12, res_bits=32),
             example_args=(jnp.uint32(0),))
    t0 = time.perf_counter()
    fr = run_full(j)
    t_mine = time.perf_counter() - t0
    for frac in (0.05, 0.25):
        t0 = time.perf_counter()
        rep = quorum_verify(j, fr, fraction=frac)
        dt = time.perf_counter() - t0
        row(f"verification.frac_{frac}", dt * 1e6,
            f"checked={rep.n_checked} verify/mine={dt / max(t_mine, 1e-9):.3f}")


def _median_ms(fn, n: int) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e3


def bench_commit_pipeline(n_leaves: int = 4096,
                          train_section: bool = True) -> dict:
    """DESIGN.md §6: the on-device block-commitment pipeline vs the seed.

    merkle_commit compares the seed's end-to-end commit path from a mined
    FullResult — the per-arg Python loop building leaf bytes plus the
    Python/hashlib ``merkle_root`` (exactly the code the pipeline
    replaced) — against ``FullResult.commit_root()``, the fused device
    tree over the leaf digests the executor already computed in-dispatch.
    The hashlib-root-only baseline (no leaf building) is recorded too.
    """
    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.core.ledger import merkle_root
    from repro.core.pow_train import PoUWTrainer
    from repro.configs import get_config, reduced
    from repro.configs.base import InputShape
    from repro.train.steps import TrainHparams

    arg_bits = int(np.log2(n_leaves))
    assert 1 << arg_bits == n_leaves

    def mixer(a):
        h = a * jnp.uint32(2654435761)
        return jnp.stack(
            [(h ^ jnp.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF)) *
             jnp.uint32(2246822519) for i in range(8)])

    j = Jash("commit-bench", mixer,
             JashMeta(arg_bits=arg_bits, res_bits=256),
             example_args=(jnp.uint32(0),))

    # --- executor_chunked: the fused full-mode dispatch ------------------
    run_full(j)                                        # compile
    us_full = _median_ms(lambda: run_full(j), 5) * 1e3
    run_full(j, chunk_size=n_leaves // 4)              # compile (same shape?)
    us_chunk = _median_ms(lambda: run_full(j, chunk_size=n_leaves // 4),
                          5) * 1e3
    row("executor_chunked.one_dispatch", us_full,
        f"args_per_s={n_leaves / (us_full * 1e-6):.3g}")
    row("executor_chunked.four_chunks", us_chunk,
        f"args_per_s={n_leaves / (us_chunk * 1e-6):.3g} bit-identical")

    # --- merkle_commit ---------------------------------------------------
    fr = run_full(j)

    def seed_commit():
        # the seed's commit path, verbatim: per-i leaf bytes + hashlib tree
        leaves = tuple(fr.args[i].tobytes() + fr.results[i].tobytes()
                       for i in range(len(fr.args)))
        return merkle_root(leaves, backend="hashlib")

    leaves_prebuilt = fr.merkle_leaves
    fr.commit_root()                                   # compile device tree
    assert fr.commit_root() == seed_commit()           # bit-identical
    ms_seed = _median_ms(seed_commit, 7)
    ms_root_only = _median_ms(
        lambda: merkle_root(leaves_prebuilt, backend="hashlib"), 7)
    ms_dev = _median_ms(fr.commit_root, 15)
    speedup = ms_seed / ms_dev
    row("merkle_commit.seed_path", ms_seed * 1e3,
        "python leaf build + hashlib merkle_root (seed code)")
    row("merkle_commit.hashlib_root_only", ms_root_only * 1e3,
        "hashlib merkle_root on prebuilt leaves")
    row("merkle_commit.device", ms_dev * 1e3,
        f"speedup={speedup:.2f}x vs seed path "
        f"({ms_root_only / ms_dev:.2f}x vs root-only)")

    # --- block_scan: scan-fused PoUW block -------------------------------
    if not train_section:
        # reduced-scale re-measure for the smoke gate: only the merkle
        # metric is consumed, skip the (expensive) trainer section
        return {
            "n_leaves": n_leaves,
            "merkle_commit": {
                "us_seed_path": ms_seed * 1e3,
                "us_hashlib_root_only": ms_root_only * 1e3,
                "us_device": ms_dev * 1e3,
                "speedup": speedup,
            },
        }
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 32, 4, "train")
    micro = 4
    tr = PoUWTrainer(cfg, shape, hp=TrainHparams(), mode="full",
                     n_miners=4, block_microsteps=micro)
    tr.run_block()                                     # compile scan block
    ms_scan = _median_ms(tr.run_block, 3)

    state, batch = tr.state, tr.pipeline.batch(0)
    tr._train_step(state, batch)                       # compile single step

    def seed_microsteps():
        s = state
        for _ in range(micro):
            s, m = tr._train_step(s, batch)
        jax.block_until_ready(m["loss"])

    ms_seed_steps = _median_ms(seed_microsteps, 3)
    row("block_scan.scan_block", ms_scan * 1e3,
        f"{micro} microsteps, one dispatch + ledger")
    row("block_scan.per_step_dispatch", ms_seed_steps * 1e3,
        f"seed pattern: {micro} dispatches, no ledger; "
        f"scan/step={ms_scan / ms_seed_steps:.2f}")

    return {
        "n_leaves": n_leaves,
        "merkle_commit": {
            "us_seed_path": ms_seed * 1e3,
            "us_hashlib_root_only": ms_root_only * 1e3,
            "us_device": ms_dev * 1e3,
            "speedup": speedup,
            "speedup_vs_root_only": ms_root_only / ms_dev,
            "baseline": "seed commit path: per-arg Python leaf build + "
                        "hashlib merkle_root, as in the seed executor",
        },
        "executor_chunked": {
            "us_one_dispatch": us_full,
            "us_four_chunks": us_chunk,
            "args_per_s": n_leaves / (us_full * 1e-6),
        },
        "block_scan": {
            "block_microsteps": micro,
            "us_scan_block": ms_scan * 1e3,
            "us_per_step_dispatch": ms_seed_steps * 1e3,
        },
    }


def bench_verify_pipeline(n_blocks: int = 256, full_arg_bits: int = 10
                          ) -> dict:
    """DESIGN §10: batched chain re-verification vs the per-block
    receive path.

    The segment mirrors what fork choice and chain sync actually
    replay — a mixed chain, half full-mode blocks drawn from
    ``n_publications`` distinct publications each re-mined repeatedly
    (deterministic mining makes the repeats byte-identical evidence,
    exactly as real classic/re-mined chains do, but every block is its
    own payload/evidence object — nothing is shared by identity), and
    half classic blocks.  The per-block baseline is exactly the
    ``wl.verify`` loop ``consider_chain`` used to run (hashlib root +
    quorum dispatch per full block); ``verify_chain_batched`` groups
    the segment per workload: full blocks dedup byte-identical
    evidence and share one stacked leaf-digest dispatch, one forest
    reduction and one stacked quorum dispatch per publication, classic
    blocks share a single replay of their common arg space."""
    import dataclasses as _dc

    from repro.core.executor import run_full
    from repro.core.jash import Jash, JashMeta
    from repro.chain.workload import (
        BlockContext, BlockPayload, ClassicSha256Workload,
        JashFullWorkload, verify_chain_batched)

    n_publications = 8

    def make_jash(salt):
        def mixer(a):
            h = (a + jnp.uint32(salt)) * jnp.uint32(2654435761)
            return jnp.stack(
                [(h ^ jnp.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF)) *
                 jnp.uint32(2246822519) for i in range(8)])
        return Jash(f"verify-bench-{salt}", mixer,
                    JashMeta(arg_bits=full_arg_bits, res_bits=256),
                    example_args=(jnp.uint32(0),))

    pubs = [make_jash(s) for s in range(n_publications)]
    fulls = [run_full(j) for j in pubs]
    workloads = {"full": JashFullWorkload(),
                 "classic": ClassicSha256Workload(arg_bits=full_arg_bits)}
    cw = workloads["classic"]

    def full_payload(slot):
        j, fr = pubs[slot % n_publications], fulls[slot % n_publications]
        # fresh arrays + payload per block: byte-identical to the
        # publication's evidence (deterministic re-mine), distinct
        # objects (dedup must work by content, not identity)
        fr = _dc.replace(fr, args=fr.args.copy(),
                         results=fr.results.copy())
        return BlockPayload(
            workload="full", jash_id=j.source_id(),
            merkle_root=fr.commit_root(), n_results=len(fr.args),
            jash=j, full=fr)

    payloads = [full_payload(i // 2) if i % 2 == 0
                else cw.mine(cw.prepare(BlockContext(height=i,
                                                     prev_hash="")))
                for i in range(n_blocks)]

    # explicit raises, not asserts: these checks are the timed work —
    # under ``python -O`` an assert would strip and time empty bodies
    def per_block():
        if not all(workloads[p.workload].verify(p) for p in payloads):
            raise RuntimeError("per-block verification rejected a block")

    def batched():
        if not verify_chain_batched(workloads, payloads):
            raise RuntimeError("batched verification rejected the segment")

    batched()                                          # compile
    per_block()
    ms_loop = _median_ms(per_block, 3)
    ms_batch = _median_ms(batched, 3)
    speedup = ms_loop / ms_batch
    row(f"verify_pipeline.per_block_{n_blocks}", ms_loop * 1e3,
        f"receive-path wl.verify loop (half full over {n_publications} "
        "publications, half classic)")
    row(f"verify_pipeline.batched_{n_blocks}", ms_batch * 1e3,
        f"verify_chain_batched speedup={speedup:.2f}x")
    return {
        "n_blocks": n_blocks,
        "full_arg_bits": full_arg_bits,
        "composition": (f"alternating full / classic; full blocks from "
                        f"{n_publications} publications (byte-identical "
                        "re-mines, distinct objects)"),
        "us_per_block_loop": ms_loop * 1e3,
        "us_batched": ms_batch * 1e3,
        "speedup": speedup,
    }


def bench_sim_scale() -> dict:
    """DESIGN §10: the gossip scale scenarios the verify cache + batched
    fork choice make tractable.  Wall-clock covers mining AND the N-1
    per-block re-verifications (cached: once per trust domain)."""
    from repro.chain.sim import throughput_scenario

    out = {}
    for name, nodes, blocks in (("gossip_16x128", 16, 128),
                                ("gossip_64x512", 64, 512)):
        sim = throughput_scenario(nodes, blocks)
        t0 = time.perf_counter()
        rep = sim.run()
        dt = time.perf_counter() - t0
        if not rep.converged or rep.credit_divergence != 0.0:
            raise RuntimeError(
                f"{name}: scenario diverged (converged={rep.converged}, "
                f"divergence={rep.credit_divergence})")
        hits = sim.verify_cache.hits if sim.verify_cache else 0
        row(f"sim_gossip.{name}", dt * 1e6,
            f"events={rep.n_events} events_per_s={rep.n_events / dt:.0f} "
            f"mined={rep.blocks_mined} cache_hits={hits} "
            f"converged={rep.converged}")
        out[name] = {"wall_s": dt, "events": rep.n_events,
                     "blocks_mined": rep.blocks_mined,
                     "verify_cache_hits": hits}
    return out


def bench_workload_suite(*, sat_vars: int = 12, sat_clauses: int = 48,
                         grid_bits: int = 10, dock: int = 32,
                         gan_rounds: int = 3, segment: int = 8) -> dict:
    """DESIGN §11: mine/verify throughput per application workload
    family, and the SAT certificate-check vs re-mine asymmetry.

    Each family is timed from both chairs: the miner's
    ``mine(prepare(ctx))`` and a *separate* verifier instance's
    ``verify`` (what every peer pays on receive).  The headline number
    is ``sat_cert_verify``: checking a committed satisfiability
    certificate is O(clauses) host work, orders of magnitude under the
    full-space re-mine — the first mine-hard/verify-cheap asymmetry in
    the repo.  GAN rounds re-jit per round (each round's grid is a new
    closure), so their cost is end-to-end including compile — that is
    what a real node pays.  Docking also times ``verify_batch`` over a
    repeated-screening segment (content dedup collapses it to ~one
    verification)."""
    from repro.chain.workload import BlockContext
    from repro.chain.workloads import (DockingWorkload,
                                       GanInversionWorkload, SatWorkload)

    def ctx(h: int) -> BlockContext:
        return BlockContext(height=h, prev_hash="")

    out: dict = {}

    # --- SAT: certificate asymmetry ----------------------------------
    miner = SatWorkload(n_vars=sat_vars, n_clauses=sat_clauses, seed=1)
    verifier = SatWorkload(n_vars=sat_vars, n_clauses=sat_clauses, seed=1)
    sat_h = unsat_h = sat_p = unsat_p = None
    for h in range(64):
        p = miner.mine(miner.prepare(ctx(h)))
        if p.certificate is not None and sat_p is None:
            sat_h, sat_p = h, p
        if p.certificate is None and unsat_p is None:
            unsat_h, unsat_p = h, p
        if sat_p is not None and unsat_p is not None:
            break
    if sat_p is None or unsat_p is None:
        raise RuntimeError("no SAT+UNSAT pair in 64 instances — "
                           "adjust sat_vars/sat_clauses")
    ms_mine = _median_ms(lambda: miner.mine(miner.prepare(ctx(sat_h))), 5)
    for p, name in ((sat_p, "cert"), (unsat_p, "refute")):
        if not verifier.verify(p):
            raise RuntimeError(f"sat {name} verification rejected an "
                               "honest block")
    ms_cert = _median_ms(lambda: verifier.verify(sat_p), 20)
    ms_refute = _median_ms(lambda: verifier.verify(unsat_p), 5)
    n_args = 1 << sat_vars
    cert_speedup = ms_mine / max(ms_cert, 1e-9)
    row("workload_suite.sat_mine", ms_mine * 1e3,
        f"2^{sat_vars} assignments, args_per_s="
        f"{n_args / (ms_mine * 1e-3):.3g}")
    row("workload_suite.sat_cert_verify", ms_cert * 1e3,
        f"O({sat_clauses} clauses) witness check; "
        f"cert_vs_remine={cert_speedup:.0f}x")
    row("workload_suite.sat_refute_verify", ms_refute * 1e3,
        f"hashlib root + quorum over the table; "
        f"vs_mine={ms_mine / max(ms_refute, 1e-9):.2f}x")
    out["sat"] = {"n_vars": sat_vars, "us_mine": ms_mine * 1e3,
                  "us_cert_verify": ms_cert * 1e3,
                  "us_refute_verify": ms_refute * 1e3,
                  "cert_vs_remine_speedup": cert_speedup}

    # --- GAN inversion: stateful rounds ------------------------------
    gm = GanInversionWorkload(seed=0, grid_bits=grid_bits)
    gv = GanInversionWorkload(seed=0, grid_bits=grid_bits)
    mine_ms, verify_ms = [], []
    for r in range(gan_rounds):
        t0 = time.perf_counter()
        p = gm.mine(gm.prepare(ctx(r)))
        mine_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        if not gv.verify(p):
            raise RuntimeError("gan round verification rejected an "
                               "honest block")
        verify_ms.append((time.perf_counter() - t0) * 1e3)
    ms_gmine = statistics.median(mine_ms)
    ms_gverify = statistics.median(verify_ms)
    row("workload_suite.gan_mine", ms_gmine * 1e3,
        f"2^{grid_bits} latents/round incl. per-round jit, err -> "
        f"{gm.inversion_error():.4f}")
    row("workload_suite.gan_verify", ms_gverify * 1e3,
        "stateful replay + zoom-digest compare (doubles as state sync)")
    out["gan"] = {"grid_bits": grid_bits, "rounds": gan_rounds,
                  "us_mine": ms_gmine * 1e3,
                  "us_verify": ms_gverify * 1e3}

    # --- docking: consensus-bound data bundle ------------------------
    dm = DockingWorkload(n_r=dock, n_p=dock, seed=0)
    dv = DockingWorkload(n_r=dock, n_p=dock, seed=0)
    dm.mine(dm.prepare(ctx(0)))                       # compile
    ms_dmine = _median_ms(lambda: dm.mine(dm.prepare(ctx(0))), 5)
    dp = dm.mine(dm.prepare(ctx(0)))
    if not dv.verify(dp):
        raise RuntimeError("docking verification rejected an honest block")
    ms_dverify = _median_ms(lambda: dv.verify(dp), 5)
    seg = [dm.mine(dm.prepare(ctx(h))) for h in range(segment)]
    if not all(dv.verify_batch(seg)):
        raise RuntimeError("docking batched verification rejected the "
                           "segment")
    ms_dbatch = _median_ms(lambda: dv.verify_batch(seg), 5)
    pairs = dock * dock
    row("workload_suite.dock_mine", ms_dmine * 1e3,
        f"pairs_per_s={pairs / (ms_dmine * 1e-3):.0f}")
    row("workload_suite.dock_verify", ms_dverify * 1e3,
        "bundle-checksum bind + hashlib root + quorum")
    row(f"workload_suite.dock_verify_batch_{segment}", ms_dbatch * 1e3,
        f"content dedup: {segment} repeat screenings ~ "
        f"{ms_dbatch / max(ms_dverify, 1e-9):.2f}x one verify")
    out["docking"] = {"n_pairs": pairs, "us_mine": ms_dmine * 1e3,
                      "us_verify": ms_dverify * 1e3, "segment": segment,
                      "us_verify_batch": ms_dbatch * 1e3}
    return out


def bench_sim_gossip(n_lanes: int = 1):
    """DESIGN §9: the async gossip simulator under partition + adversary
    scenarios.  Each row consumes the deterministic ``SimReport`` — fork
    depth histogram, orphan rate, time-to-finality — plus the wallclock
    cost of driving the scenario (events/s is the simulator's own
    overhead figure; block *mining* dominates it)."""
    from repro.chain.sim import adversarial_scenario, partitioned_scenario

    for name, build in (
        ("partition_4node",
         lambda: partitioned_scenario(n_nodes=4, seed=0,
                                      n_lanes=n_lanes)),
        ("adversarial_5node",
         lambda: adversarial_scenario(n_honest=3, seed=0)),
    ):
        sim = build()
        t0 = time.perf_counter()
        rep = sim.run()
        dt = time.perf_counter() - t0
        if not rep.converged or rep.credit_divergence != 0.0:
            raise RuntimeError(
                f"{name}: scenario diverged (converged={rep.converged}, "
                f"divergence={rep.credit_divergence})")
        depths = ";".join(f"d{k}x{v}"
                          for k, v in rep.fork_depth_hist.items())
        row(f"sim_gossip.{name}", dt * 1e6,
            f"events={rep.n_events} events_per_s={rep.n_events / dt:.0f} "
            f"mined={rep.blocks_mined} orphan_rate={rep.orphan_rate:.2f} "
            f"forks=[{depths}] ttf_mean_s={rep.ttf_mean:.2f} "
            f"ttf_max_s={rep.ttf_max:.2f}")


def bench_recovery(n_blocks: int = 512, arg_bits: int = 6) -> dict:
    """DESIGN §12: journal replay throughput — what a restart costs.
    Mines a classic chain into an in-memory journal, then times
    ``Node.recover`` replaying it through the batched verify path."""
    from repro.chain import ChainStore, Node

    donor = Node(node_id=0, classic_arg_bits=arg_bits, store=ChainStore())
    for _ in range(n_blocks):
        donor.mine_block()
    data = donor.store.to_bytes()
    t0 = time.perf_counter()
    node = Node.recover(ChainStore.from_bytes(data),
                        node=Node(node_id=0, classic_arg_bits=arg_bits))
    dt = time.perf_counter() - t0
    if node.ledger.tip_hash != donor.ledger.tip_hash:
        raise RuntimeError("recovery replay diverged from the donor tip")
    row(f"recovery.replay_{n_blocks}", dt * 1e6,
        f"blocks_per_s={n_blocks / dt:.0f} journal_bytes={len(data)}")
    return {"n_blocks": n_blocks, "wall_s": dt,
            "blocks_per_s": n_blocks / dt, "journal_bytes": len(data)}


def bench_chaos(n_nodes: int = 16, n_blocks: int = 24) -> dict:
    """DESIGN §12: the crash/corrupt/long-range-rewrite chaos scenario —
    wallclock for the full fault gauntlet plus its recovery/finality
    counters (any divergence is a hard failure, not a slow row)."""
    from repro.chain.sim import chaos_scenario

    sim = chaos_scenario(n_nodes=n_nodes, n_blocks=n_blocks)
    t0 = time.perf_counter()
    rep = sim.run()
    dt = time.perf_counter() - t0
    if (not rep.converged or rep.credit_divergence != 0.0
            or rep.finalized_divergence != 0):
        raise RuntimeError(
            f"chaos_scenario diverged (converged={rep.converged}, "
            f"finalized_divergence={rep.finalized_divergence})")
    row(f"sim_chaos.{n_nodes}x{n_blocks}", dt * 1e6,
        f"events={rep.n_events} events_per_s={rep.n_events / dt:.0f} "
        f"recoveries={rep.recoveries} truncated={rep.truncated_records} "
        f"finality_rejects={rep.finality_rejects} "
        f"converged={rep.converged}")
    return {"n_nodes": n_nodes, "blocks": n_blocks, "wall_s": dt,
            "events": rep.n_events, "recoveries": rep.recoveries,
            "truncated_records": rep.truncated_records,
            "finality_rejects": rep.finality_rejects}


def bench_model_pouw(n_blocks: int = 4) -> dict:
    """DESIGN §16: real-model PoUW on the CI micro transformer —
    blocks/s mined (steady state, after the one shared XLA compile),
    the verifier's replay cost vs the miner's mine cost (verify *is*
    re-execution plus digest checks, so the ratio sits near 1 — the
    price of verify-as-state-sync, unlike SAT's certificate asymmetry)
    and the canonical gather-then-hash params digest overhead per
    block."""
    from repro.chain.workload import BlockContext
    from repro.chain.workloads import ModelTrainingWorkload
    from repro.chain.workloads.model_train import MICRO_KWARGS
    from repro.train.steps import params_digest

    miner = ModelTrainingWorkload(**MICRO_KWARGS)
    verifier = ModelTrainingWorkload(**MICRO_KWARGS)

    def ctx(h: int) -> BlockContext:
        return BlockContext(height=h, prev_hash="")

    # block 0 pays the (process-shared) step compile for both chairs
    warm = miner.mine(miner.prepare(ctx(0)))
    if not verifier.verify(warm):
        raise RuntimeError("verifier rejected an honest warmup block")

    t0 = time.perf_counter()
    payloads = [miner.mine(miner.prepare(ctx(1 + i)))
                for i in range(n_blocks)]
    dt_mine = time.perf_counter() - t0
    t0 = time.perf_counter()
    for p in payloads:
        if not verifier.verify(p):
            raise RuntimeError("verifier rejected an honest block")
    dt_verify = time.perf_counter() - t0
    if verifier.state_digest() != miner.state_digest():
        raise RuntimeError("miner/verifier params digests diverged")

    us_mine = dt_mine / n_blocks * 1e6
    us_verify = dt_verify / n_blocks * 1e6
    us_digest = _timeit(lambda: params_digest(miner._state))
    row("model_pouw.mine", us_mine,
        f"blocks_per_s={n_blocks / dt_mine:.1f} "
        f"microsteps={MICRO_KWARGS['block_microsteps']}")
    row("model_pouw.verify", us_verify,
        f"verify_vs_remine={us_verify / us_mine:.2f}x")
    row("model_pouw.digest", us_digest,
        f"pct_of_mine={us_digest / us_mine * 100:.1f}%")
    return {"n_blocks": n_blocks,
            "blocks_per_s": n_blocks / dt_mine,
            "us_mine": us_mine, "us_verify": us_verify,
            "verify_vs_remine": us_verify / us_mine,
            "us_digest": us_digest}


def bench_wire_relay(n_peers: int = 4, n_blocks: int = 6) -> dict:
    """DESIGN §13: compact vs full-body relay over the deterministic
    loopback wire.  Same peers, same seed, same chain — the only
    difference is whether announces inline the payload body or carry
    its 16-byte content checksum (bodies fetched on demand, re-gossip
    deduplicated).  Bytes-on-wire and blocks/s for both; divergence
    between the two chains, or compact failing to save bytes, is a
    hard failure rather than a slow row."""
    from repro.chain.net import loopback_scenario

    schedule = ("classic",) * n_blocks
    # first-touch warmup (suite construction, jit) so neither timed
    # variant pays it
    loopback_scenario(n_peers=2, seed=0, schedule=("classic",),
                      oracle=False)
    results = {}
    for label, compact in (("compact", True), ("full_body", False)):
        t0 = time.perf_counter()
        rep = loopback_scenario(n_peers=n_peers, seed=0, compact=compact,
                                schedule=schedule, oracle=False)
        dt = time.perf_counter() - t0
        if not rep["converged"]:
            raise RuntimeError(f"wire_relay {label}: peers diverged")
        results[label] = (rep, dt)
        row(f"wire_relay.{label}", dt * 1e6,
            f"bytes_on_wire={rep['bytes_on_wire']} "
            f"blocks_per_s={n_blocks / dt:.1f} "
            f"frames={rep['frames_delivered']}")
    (c, dt_c), (f, dt_f) = results["compact"], results["full_body"]
    if c["chain_digest"] != f["chain_digest"]:
        raise RuntimeError("wire_relay: compact and full-body runs "
                           "committed different chains")
    if c["bytes_on_wire"] >= f["bytes_on_wire"]:
        raise RuntimeError(
            f"wire_relay: compact relay saved no bytes "
            f"({c['bytes_on_wire']} vs {f['bytes_on_wire']})")
    saving = 1.0 - c["bytes_on_wire"] / f["bytes_on_wire"]
    row("wire_relay.saving", 0.0,
        f"compact saves {saving:.0%} of wire bytes "
        f"({c['bytes_on_wire']} vs {f['bytes_on_wire']})")
    return {"n_peers": n_peers, "n_blocks": n_blocks,
            "wire_relay_us": dt_c * 1e6,
            "wire_relay_blocks_per_s": n_blocks / dt_c,
            "wire_relay_compact_bytes": c["bytes_on_wire"],
            "wire_relay_full_bytes": f["bytes_on_wire"],
            "wire_relay_saving_frac": saving}


def bench_mesh_discovery(n_peers: int = 5, n_blocks: int = 6) -> dict:
    """DESIGN §14: single-seed mesh bootstrap.  N loopback peers start
    knowing only peer0's address, learn the mesh from HELLO/ADDR
    gossip, dial it full, then mine round-robin.  Rows: wall-clock to
    full mesh, discovery rounds, and the post-discovery convergence
    check — failing to fill the mesh or to converge is a hard failure
    rather than a slow row."""
    from repro.chain.net import mesh_scenario

    schedule = ("classic",) * n_blocks
    # warmup (suite construction, identity derivation) off the clock
    mesh_scenario(n_peers=2, seed=0, schedule=("classic",), oracle=False)
    t0 = time.perf_counter()
    rep = mesh_scenario(n_peers=n_peers, seed=0, schedule=schedule,
                        oracle=False)
    dt = time.perf_counter() - t0
    if not rep["full_mesh"]:
        raise RuntimeError("mesh_discovery: mesh never filled")
    if not rep["converged"]:
        raise RuntimeError("mesh_discovery: peers diverged")
    row("mesh_discovery", rep["discovery_s"] * 1e6,
        f"n_peers={n_peers} rounds={rep['discovery_rounds']} "
        f"addrs_added={rep['addrs_added']} "
        f"bytes_on_wire={rep['bytes_on_wire']} "
        f"blocks_per_s={n_blocks / dt:.1f}")
    return {"n_peers": n_peers, "n_blocks": n_blocks,
            "mesh_discovery_us": rep["discovery_s"] * 1e6,
            "mesh_discovery_rounds": rep["discovery_rounds"],
            "mesh_total_us": dt * 1e6,
            "mesh_bytes_on_wire": rep["bytes_on_wire"],
            "mesh_addrs_added": rep["addrs_added"]}


def bench_mesh_chaos(n_peers: int = 5, n_blocks: int = 10) -> dict:
    """DESIGN §15: time-to-reconverge under everything at once — two
    crash/restart cycles (one with a corrupted journal tail), a 10:1
    addr-flooding eclipse adversary on peer1, and one corrupted frame
    per block.  Failing to reconverge, or the victim losing its last
    honest anchor, is a hard failure rather than a slow row."""
    from repro.chain.net import mesh_chaos_scenario

    schedule = ("classic",) * n_blocks
    faults = ((3, "crash", 2), (3, "corrupt_store", 2), (5, "restart", 2),
              (7, "crash", 3), (8, "restart", 3))
    t0 = time.perf_counter()
    rep = mesh_chaos_scenario(n_peers=n_peers, seed=0, schedule=schedule,
                              faults=faults, oracle=False)
    dt = time.perf_counter() - t0
    if not rep["converged"]:
        raise RuntimeError("mesh_chaos: peers diverged")
    if rep["victim"]["honest_anchors"] < 1:
        raise RuntimeError("mesh_chaos: victim lost every honest anchor")
    row("mesh_chaos", dt * 1e6,
        f"n_peers={n_peers} blocks={n_blocks} "
        f"settle_rounds={rep['settle_rounds']} "
        f"recoveries={len(rep['recoveries'])} "
        f"timeouts={rep['timeouts']} failovers={rep['failovers']} "
        f"honest_anchors={rep['victim']['honest_anchors']}")
    return {"n_peers": n_peers, "n_blocks": n_blocks,
            "mesh_chaos_us": dt * 1e6,
            "mesh_chaos_settle_rounds": rep["settle_rounds"],
            "mesh_chaos_timeouts": rep["timeouts"],
            "mesh_chaos_failovers": rep["failovers"],
            "mesh_chaos_recoveries": len(rep["recoveries"])}


def bench_roofline():
    """Emit the dry-run roofline table (deliverable (g)) as CSV rows."""
    files = sorted(glob.glob("experiments/dryrun/*__single.json"))
    if not files:
        row("roofline.missing", 0.0, "run launch/dryrun first")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0,
                f"SKIP: {d['reason'][:50]}")
            continue
        if "error" in d:
            row(f"roofline.{d['arch']}.{d['shape']}", 0.0, "ERROR")
            continue
        r = d["roofline"]
        t_total = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline.{d['arch']}.{d['shape']}", t_total * 1e6,
            f"dom={r['dominant']} tc={r['t_compute_s']:.2e} "
            f"tm={r['t_memory_s']:.2e} tx={r['t_collective_s']:.2e} "
            f"useful={d['useful_flops_ratio']:.2f}")


# smoke-scale parameters: the exact shapes --smoke re-measures and the
# full run records as the regression baseline
SMOKE_LEAVES = 256
SMOKE_VERIFY_BLOCKS = 64
SMOKE_VERIFY_ARG_BITS = 8
SMOKE_SUITE = dict(sat_vars=10, sat_clauses=40, grid_bits=6, dock=16,
                   gan_rounds=2, segment=4)


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:                                  # noqa: BLE001
        return "unknown"


def write_bench_json(payload: dict) -> None:
    """Latest rows at the top level; every run appended to ``history``
    (git sha, date, rows) so the trajectory across PRs is recorded.  A
    pre-history file's top-level rows are folded in as the first
    entry."""
    history = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as fh:
                old = json.load(fh)
            history = old.pop("history", [])
            if not history and old:
                history = [{"git_sha": "pre-history", "date": "",
                            "rows": old}]
        except (OSError, json.JSONDecodeError):
            pass
    history.append({"git_sha": _git_sha(),
                    "date": time.strftime("%Y-%m-%d %H:%M:%S"),
                    "rows": payload})
    with open(BENCH_JSON, "w") as fh:
        json.dump({**payload, "history": history}, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {os.path.abspath(BENCH_JSON)} "
          f"({len(history)} history entries)")


def check_smoke_regression(measured: dict) -> int:
    """Gate the reduced-scale metrics against the committed
    ``smoke_baseline``; returns the number of regressions (>2.5x)."""
    try:
        with open(BENCH_JSON) as fh:
            baseline = json.load(fh).get("smoke_baseline")
    except (OSError, json.JSONDecodeError):
        baseline = None
    if not baseline:
        print("# no smoke_baseline in committed BENCH_pipeline.json — "
              "regression gate skipped (run a full bench to record one)")
        return 0
    failures = 0
    for key in ("merkle_commit_us_device", "verify_chain_batched_us",
                "workload_suite_dock_verify_us", "wire_relay_us",
                "mesh_discovery_us", "mesh_chaos_us",
                "model_pouw_verify_us"):
        base, got = baseline.get(key), measured.get(key)
        if base is None or got is None:
            continue
        verdict = "OK"
        if got > base * SMOKE_SLOWDOWN_LIMIT:
            verdict = f"REGRESSION (>{SMOKE_SLOWDOWN_LIMIT}x)"
            failures += 1
        print(f"# gate {key}: measured {got:.0f}us vs baseline "
              f"{base:.0f}us -> {verdict}")
    return failures


def _smoke_scale_metrics(train_section: bool = True,
                         quiet: bool = False) -> dict:
    """The two gated metrics, measured at smoke scale (the full run
    records them as the baseline — with ``quiet`` row suppression so
    reduced-scale timings don't shadow the full-scale rows; --smoke
    re-measures and compares)."""
    global _QUIET
    _QUIET = quiet
    try:
        commit = bench_commit_pipeline(n_leaves=SMOKE_LEAVES,
                                       train_section=train_section)
        verify = bench_verify_pipeline(n_blocks=SMOKE_VERIFY_BLOCKS,
                                       full_arg_bits=SMOKE_VERIFY_ARG_BITS)
        suite = bench_workload_suite(**SMOKE_SUITE)
        wire = bench_wire_relay()
        mesh = bench_mesh_discovery()
        chaos = bench_mesh_chaos()
        model = bench_model_pouw()
    finally:
        _QUIET = False
    return {
        "n_leaves": SMOKE_LEAVES,
        "verify_blocks": SMOKE_VERIFY_BLOCKS,
        "verify_arg_bits": SMOKE_VERIFY_ARG_BITS,
        "suite_scale": SMOKE_SUITE,
        "merkle_commit_us_device": commit["merkle_commit"]["us_device"],
        "verify_chain_batched_us": verify["us_batched"],
        "workload_suite_dock_verify_us": suite["docking"]["us_verify"],
        "wire_relay_us": wire["wire_relay_us"],
        "wire_relay_compact_bytes": wire["wire_relay_compact_bytes"],
        "wire_relay_full_bytes": wire["wire_relay_full_bytes"],
        "mesh_discovery_us": mesh["mesh_discovery_us"],
        "mesh_discovery_rounds": mesh["mesh_discovery_rounds"],
        "mesh_bytes_on_wire": mesh["mesh_bytes_on_wire"],
        "mesh_chaos_us": chaos["mesh_chaos_us"],
        "mesh_chaos_settle_rounds": chaos["mesh_chaos_settle_rounds"],
        "model_pouw_verify_us": model["us_verify"],
        "model_pouw_blocks_per_s": model["blocks_per_s"],
        "model_pouw_verify_vs_remine": model["verify_vs_remine"],
        "model_pouw_digest_us": model["us_digest"],
    }


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    if smoke:
        # CI subset: commit + verify pipelines at reduced scale (the
        # full-scale numbers are recorded in the committed
        # BENCH_pipeline.json by a full run) + the gossip sim
        # scenarios, then the regression gate against smoke_baseline
        measured = _smoke_scale_metrics()
        bench_sim_gossip()
        bench_recovery(n_blocks=64)
        bench_chaos(n_nodes=8, n_blocks=12)
        failures = check_smoke_regression(measured)
        print(f"# {len(ROWS)} rows (smoke)")
        if failures:
            raise SystemExit(f"{failures} bench regression(s) vs "
                             "committed smoke_baseline")
        return
    fph = bench_hash_flops()
    bench_network_claim(fph)
    bench_block_turnaround()
    bench_mode_overhead()
    bench_pouw_overhead()
    bench_docking()
    bench_verification()
    payload = bench_commit_pipeline()
    payload["verify_pipeline"] = bench_verify_pipeline()
    payload["workload_suite"] = bench_workload_suite()
    payload["sim_gossip"] = bench_sim_scale()
    payload["recovery"] = bench_recovery()
    payload["sim_chaos"] = bench_chaos()
    payload["wire_relay"] = bench_wire_relay()
    payload["mesh_discovery"] = bench_mesh_discovery()
    payload["mesh_chaos"] = bench_mesh_chaos()
    payload["model_pouw"] = bench_model_pouw()
    payload["smoke_baseline"] = _smoke_scale_metrics(train_section=False,
                                                     quiet=True)
    bench_sim_gossip()
    bench_roofline()
    write_bench_json(payload)
    print(f"# {len(ROWS)} rows")


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="fast CI subset (commit pipeline only, small N)")
    main(smoke=p.parse_args().smoke)
