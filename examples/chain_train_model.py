"""Real-model PoUW end to end: two nodes chain-train ``pnpcoin-demo``.

The paper's §1 claim — the PoW slot hosts "finding the next optimum in
hyperdimensional stochastic gradient descent" — with the repo's actual
transformer stack as the block payload.  A 2-node ``Network`` mines
four ``ModelTrainingWorkload`` blocks on the ~2M-param ``pnpcoin-demo``
LM (miners alternate; the non-miner verifies each block by re-executing
its microbatches on its *own* state and comparing the canonical params
digest bit-exactly), then the chain is pinned through the two
stateful-consensus stress cases:

1. **crash/recover** — node 0's journal is replayed into a fresh shell
   by ``Node.recover``; the recovered chain and model weights are
   byte-identical to the donor's.
2. **mid-chain reorg** — the recovered node mines a private block,
   loses the fork race, and ``consider_chain`` rolls the optimizer
   back and re-syncs it onto the winning chain, digests bit-equal.

  PYTHONPATH=src python examples/chain_train_model.py

The first block pays the one XLA compile of the shared train step;
steady-state blocks are sub-second on CPU.
"""
import numpy as np

from repro.chain import ChainStore, Network, Node
from repro.chain.workloads import ModelTrainingWorkload
from repro.configs import get_config

SEQ_LEN, BATCH, MICROSTEPS = 32, 4, 2


def make_node(i: int, **kwargs) -> Node:
    wl = ModelTrainingWorkload(cfg=get_config("pnpcoin-demo"),
                               seq_len=SEQ_LEN, batch=BATCH,
                               block_microsteps=MICROSTEPS, n_miners=2)
    return Node(node_id=i, classic_arg_bits=6,
                workloads={"model_train": wl}, **kwargs)


store = ChainStore()                 # node 0's durable journal
net = Network.create(2, node_factory=lambda i: make_node(
    i, **({"store": store} if i == 0 else {})))

# --- four real train-step blocks, miners alternating ----------------------
for b in range(4):
    res = net.mine(b % 2, "model_train")
    assert not res.rejected_by, f"peers rejected: {res.rejected_by}"
    p = res.receipt.payload
    print(f"height {res.receipt.record.height} [model_train] "
          f"miner=node{p.origin} step={p.train_height} "
          f"loss={p.loss:.4f} digest={p.state_digest[:16]}…")

assert net.converged(), (net.heights, net.tips)
a, b = net.nodes
digests = {n.workloads["model_train"].state_digest() for n in net.nodes}
assert len(digests) == 1, "model weights diverged"
books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
assert len(books) == 1, "credit books diverged"
print(f"\nconverged: height {a.ledger.height}, params digest "
      f"{digests.pop()[:16]}… on both nodes")

# --- crash/recover: journal replay into a fresh shell ---------------------
rec = Node.recover(store, node=make_node(0))
assert rec.last_recovery.adopted_height == a.ledger.height
assert [blk.block_hash for blk in rec.ledger.blocks] == \
    [blk.block_hash for blk in a.ledger.blocks]
assert rec.workloads["model_train"].state_digest() == \
    a.workloads["model_train"].state_digest()
assert rec.book.balances == a.book.balances
print(f"recovered: {rec.last_recovery.adopted_height} blocks replayed "
      f"from the journal, weights byte-identical")

# --- mid-chain reorg: private block loses the fork race -------------------
rec.mine_block("model_train")        # private: height 5, train step 4
r5 = b.mine_block("model_train")     # competing step 4 on the public chain
b.mine_block("classic")              # public chain wins on height
assert rec.consider_chain([blk for blk in b.ledger.blocks],
                          b.chain_payloads())
assert rec.workloads["model_train"].round == r5.payload.train_height + 1
assert rec.workloads["model_train"].state_digest() == \
    b.workloads["model_train"].state_digest()
assert np.isfinite(r5.payload.loss)
print(f"reorged: private step rolled back, re-synced to height "
      f"{rec.ledger.height}, weights bit-equal to the winning chain")
print("\nok")
