"""PNPCoin §4 use case — cellular docking brute force — as a chain
workload (``repro.chain.workloads.DockingWorkload``):

  - pair space b = (n_r mod N_r + n_p * N_r)_2            (eq. 1)
  - 2-bit output: 01 binds / 00 no-bind / 10 did-not-terminate
  - bounded matcher (a fori_loop "simulation" with early exit, §3.2)
  - the data-bundle checksum is **bound into consensus**: the jash meta
    checksums the receptor/peptide tables, the committed ``jash_id``
    hashes the meta, and every verifier rebuilds the jash from its own
    local bundle — so a peer holding a tampered bundle rejects the
    block (demonstrated below), and vice versa.

Mined on a 2-node ``Network``: gossip, bit-exact re-verification on
receive, even §3.3 reward split on both books.

  PYTHONPATH=src python examples/docking.py
"""
import dataclasses

from repro.chain import Network, Node
from repro.chain.workloads import DockingBundle, DockingWorkload

N_R, N_P, SEED = 32, 32, 0


def make_node(i: int) -> Node:
    return Node(node_id=i, workloads={
        "docking": DockingWorkload(n_r=N_R, n_p=N_P, seed=SEED)})


net = Network.create(2, node_factory=make_node)
bundle = net.nodes[0].workloads["docking"].bundle
print(f"data bundle: {N_R} receptors x {N_P} peptides, "
      f"sha256={bundle.checksum()[:16]}…")

res = net.mine(0, "docking")
p = res.receipt.payload
counts = {code: int((p.full.results[:, 0] == code).sum())
          for code in (1, 0, 2)}
print(f"pairs evaluated: {p.n_results}  binds: {counts[1]}  "
      f"no-bind: {counts[0]}  non-terminated: {counts[2]}")
print(f"merkle root: {p.merkle_root[:16]}…  accepted_by={res.accepted_by}")
assert not res.rejected_by

# -- the consensus data binding, negatively: a peer whose bundle was
#    tampered in p2p transit cannot re-derive the committed jash_id and
#    rejects the (honest) block outright -------------------------------
tampered = DockingBundle(
    receptors=bundle.receptors ^ 1, peptides=bundle.peptides)
bad_peer = Node(node_id=9, workloads={
    "docking": DockingWorkload(bundle=tampered)})
accepted = bad_peer.receive(res.receipt.record.to_block(), p, origin=0)
print(f"peer with tampered bundle accepts the block: {accepted}")
assert not accepted

# -- and a forged evidence table under the honest header fails quorum --
bad_results = p.full.results.copy()
bad_results[0, 0] ^= 1
forged = dataclasses.replace(
    p, full=dataclasses.replace(p.full, results=bad_results))
assert not net.nodes[1].workloads["docking"].verify(forged)
print("forged result table under the honest header: rejected by quorum")

assert net.converged() and all(n.audit_chain() for n in net.nodes)
books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
assert len(books) == 1
b0 = net.nodes[0].book
print(f"rewards: {b0.total_issued:.1f} split over {len(b0.balances)} "
      "miner lanes, identical on both nodes")
