"""PNPCoin §4 use case: cellular docking brute force.

Reproduces the paper's walkthrough exactly:
  - pair space b = (n_r mod N_r + n_p * N_r)_2           (eq. 1)
  - 2-bit output: 01 binds / 00 no-bind / 10 did-not-terminate
  - bounded matcher (a fori_loop "simulation" with early exit)
  - data bundle checksum in the meta
  - RA review -> full-mode execution -> Merkle commit -> even rewards

  PYTHONPATH=src python examples/docking.py
"""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.authority import RuntimeAuthority
from repro.core.executor import run_full
from repro.core.jash import Jash, JashMeta, bounded_while
from repro.core.ledger import Ledger, merkle_root
from repro.core.rewards import CreditBook, reward_full
from repro.core.verify import quorum_verify

N_R, N_P = 32, 32                       # receptors x peptides
MAX_STEPS = 64                          # §3 req. 5: bounded loop

# the "data bundle": per-receptor/peptide feature tables (checksummed)
rng = np.random.RandomState(0)
RECEPTORS = jnp.asarray(rng.randint(0, 1 << 16, (N_R,), dtype=np.uint32))
PEPTIDES = jnp.asarray(rng.randint(0, 1 << 16, (N_P,), dtype=np.uint32))
checksum = hashlib.sha256(np.asarray(RECEPTORS).tobytes() +
                          np.asarray(PEPTIDES).tobytes()).hexdigest()


def matcher(b: jax.Array) -> jax.Array:
    """Simulated docking energy minimization: bounded relaxation loop;
    binds if the energy drops under threshold before the step bound."""
    r = RECEPTORS[b % jnp.uint32(N_R)]
    p = PEPTIDES[b // jnp.uint32(N_R)]
    e0 = ((r ^ p) * jnp.uint32(2654435761)) >> jnp.uint32(16)

    def cond(s):
        return s[0] > jnp.uint32(100)

    def body(s):
        e, t = s
        return (e - (e >> jnp.uint32(3)) - jnp.uint32(1), t + 1)

    (e, steps), terminated = bounded_while(
        cond, body, (e0, jnp.uint32(0)), max_steps=MAX_STEPS)
    # 01 binds (fast convergence), 00 no-bind, 10 did not terminate
    return jnp.where(~terminated, jnp.uint32(0b10),
                     jnp.where(steps < jnp.uint32(24), jnp.uint32(0b01),
                               jnp.uint32(0b00)))


jash = Jash("docking-matcher", matcher,
            JashMeta(arg_bits=10, res_bits=2, max_arg=N_R * N_P,
                     data_checksum=checksum, data_acquisition="p2p",
                     importance=0.9,
                     description="peptide-receptor docking (paper §4)"),
            example_args=(jnp.uint32(0),))

ra = RuntimeAuthority()
rep = ra.submit(jash)
print(f"RA: compiled={rep.compiled} est_runtime={rep.runtime_mean_s*1e3:.2f}ms "
      f"data_sha256={checksum[:16]}…")

published, _ = ra.publish_next()
full = run_full(published, block_reward=50.0)
assert quorum_verify(published, full, fraction=0.05).ok

ledger = Ledger()
book = CreditBook()
root = merkle_root(full.merkle_leaves)
ledger.append(jash_id=published.source_id(), mode="full", merkle=root,
              winner=None, best_res=None, n_results=len(full.args))
reward_full(book, full.miner_of.tolist(), 50.0)

res = full.results[:, 0]
print(f"pairs evaluated: {len(res)}  binds: {int((res == 1).sum())}  "
      f"no-bind: {int((res == 0).sum())}  non-terminated: {int((res == 2).sum())}")
print(f"merkle root: {root[:16]}…  chain ok: {ledger.verify_chain()}")
print(f"rewards: {book.total_issued} split over {len(book.balances)} miners")
