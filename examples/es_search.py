"""Optimal-mode ES mining through the chain API (§3.3 + §1 "next
optimum in hyperdimensional SGD"): every block, each miner lane
evaluates one perturbed parameter candidate; the lowest loss is "the
result with most leading zeros" and wins the block.

Rewired (PR 5) from a standalone ``PoUWTrainer`` script into a thin
driver over the chain stack: two ``Node``\\ s each carry a
``TrainingWorkload`` wrapping an identically-seeded optimal-mode
trainer, mine alternately on a ``Network``, and the peer re-executes
every ES block on receive (verification doubles as state sync — both
nodes end at the same weights).  The beyond-paper ES-gradient update
demo (reusing ALL submitted results) rides at the end.

  PYTHONPATH=src python examples/es_search.py
"""
import dataclasses

import jax

from repro.chain import Network, Node, TrainingWorkload
from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core import es as es_mod
from repro.core.pow_train import PoUWTrainer
from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.steps import make_eval_step, make_train_state

# ES's signal-to-noise at LM scale requires a small payload and a fixed
# block batch ("find THE next optimum", §1) — candidate 0 is always the
# incumbent, so the accepted loss is monotone non-increasing per batch.
cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")),
                          n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                          head_dim=32, d_ff=128, vocab_size=256)
shape = InputShape("es", 32, 8, "train")
N_BLOCKS = 8


def trainer_factory():
    # identical seed on every node: re-execution on receive must land on
    # bit-identical weights (that IS the §3 req. 2 audit)
    return PoUWTrainer(cfg, shape, mode="optimal", n_miners=8, pop_size=32,
                       sigma=0.02, seed=0, fixed_batch=True)


net = Network.create(2, node_factory=lambda i: Node(
    node_id=i, workloads={"training": TrainingWorkload(trainer_factory)}))

print("== optimal-mode ES chain (2 nodes, winner-takes-block) ==")
for b in range(N_BLOCKS):
    res = net.mine(b % 2, "training")
    r = res.receipt
    assert not res.rejected_by, res.rejected_by
    print(f"  block {r.record.height}: miner=node{r.payload.origin} "
          f"winner={r.payload.winner} loss={r.payload.loss:.4f}")

losses = [p.loss for p in net.nodes[0].chain_payloads()]
print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
      f"converged: {net.converged()}")
assert net.converged()
books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
assert len(books) == 1, "credit books diverged"
print("credit balances:",
      {k: round(v, 1)
       for k, v in sorted(net.nodes[0].book.balances.items())})

# --- beyond-paper: ES-gradient update from the same submissions -----------
pipe = SyntheticTokenPipeline(cfg, shape, seed=3)
state = make_train_state(cfg, jax.random.key(1))
eval_step = jax.jit(make_eval_step(cfg))
params = state.params
key = jax.random.key(2)
fixed = pipe.batch(0)
eval_fn = make_eval_step(cfg)
es_block_j = jax.jit(lambda p, b, k: es_mod.es_block(
    eval_fn, p, b, k, pop_size=32, sigma=0.02))
es_update_j = jax.jit(lambda p, k, l: es_mod.es_update(
    p, k, l, sigma=0.02, lr=0.05))
losses0 = float(eval_step(params, fixed))
for step in range(20):
    key, sub = jax.random.split(key)
    losses, best = es_block_j(params, fixed, sub)
    params = es_update_j(params, sub, losses)
lossesN = float(eval_step(params, fixed))
print(f"ES-gradient (all submissions reused): {losses0:.4f} -> {lossesN:.4f}")
