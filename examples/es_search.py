"""Optimal-mode mining (§3.3 + §1 "next optimum in hyperdimensional SGD"):
every block, each miner evaluates one perturbed parameter candidate; the
lowest loss is "the result with most leading zeros" and wins the block.

Also demonstrates the beyond-hillclimb ES update (core/es.es_update) that
reuses ALL submitted results — the chain already paid for them.

  PYTHONPATH=src python examples/es_search.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core import es as es_mod
from repro.core.pow_train import PoUWTrainer
from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.steps import make_eval_step, make_train_state

# ES's signal-to-noise at LM scale requires a small payload and a fixed
# block batch ("find THE next optimum", §1) — candidate 0 is always the
# incumbent, so the accepted loss is monotone non-increasing per batch.
cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")),
                          n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                          head_dim=32, d_ff=128, vocab_size=256)
shape = InputShape("es", 32, 8, "train")

# --- optimal-mode chain: winner-takes-block hillclimb ---------------------
tr = PoUWTrainer(cfg, shape, mode="optimal", n_miners=8, pop_size=32,
                 sigma=0.02, seed=0, fixed_batch=True)
recs = tr.run(40)
print("optimal-mode chain: loss",
      f"{recs[0].loss:.4f} -> {recs[-1].loss:.4f};",
      f"chain ok: {tr.ledger.verify_chain()}")
winners = [b.winner for b in tr.ledger.blocks]
print("block winners:", winners)
print("credit balances:", {k: round(v, 1)
                           for k, v in sorted(tr.book.balances.items())})

# --- beyond-paper: ES-gradient update from the same submissions -----------
pipe = SyntheticTokenPipeline(cfg, shape, seed=3)
state = make_train_state(cfg, jax.random.key(1))
eval_step = jax.jit(make_eval_step(cfg))
params = state.params
key = jax.random.key(2)
fixed = pipe.batch(0)
eval_fn = make_eval_step(cfg)
es_block_j = jax.jit(lambda p, b, k: es_mod.es_block(
    eval_fn, p, b, k, pop_size=32, sigma=0.02))
es_update_j = jax.jit(lambda p, k, l: es_mod.es_update(
    p, k, l, sigma=0.02, lr=0.05))
losses0 = float(eval_step(params, fixed))
for step in range(40):
    key, sub = jax.random.split(key)
    losses, best = es_block_j(params, fixed, sub)
    params = es_update_j(params, sub, losses)
lossesN = float(eval_step(params, fixed))
print(f"ES-gradient (all submissions reused): {losses0:.4f} -> {lossesN:.4f}")
