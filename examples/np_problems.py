"""The paper's §1 application list as *chain workloads* (not scripts):

1. **Brute-force theorem proving** — ``SatWorkload``: each block
   decides one random 3-CNF exhaustively.  A SAT block commits a
   satisfiability certificate the peer re-checks in O(clauses) — no
   re-mining — while an UNSAT refutation stays quorum-sampled.
2. **GAN inversion** — ``GanInversionWorkload``: each block is one
   optimal-mode refinement round over a latent grid; accepting a block
   zooms the grid around the winner (stateful — verification doubles
   as state sync, like training blocks).

Both families mine on a 2-node ``Network``: every block is gossiped,
re-verified bit-exactly by the peer, and rewarded identically on both
credit books.

  PYTHONPATH=src python examples/np_problems.py
"""
from repro.chain import Network, Node
from repro.chain.workloads import GanInversionWorkload, SatWorkload

N_VARS, N_CLAUSES = 12, 48


def make_node(i: int) -> Node:
    # fresh workload instances per node (same seeds, so both nodes hold
    # the same formula family and inverse problem)
    return Node(node_id=i, classic_arg_bits=6, workloads={
        "sat": SatWorkload(n_vars=N_VARS, n_clauses=N_CLAUSES, seed=1),
        "gan": GanInversionWorkload(seed=0, grid_bits=10),
    })


net = Network.create(2, node_factory=make_node)

print(f"== brute-force SAT (full mode, §1 'theorem proving') ==")
for b in range(3):
    res = net.mine(b % 2, "sat")
    p = res.receipt.payload
    verdict = (f"SAT, witness={int.from_bytes(p.certificate, 'little')} "
               f"(peer checked {N_CLAUSES} clauses, no re-mine)"
               if p.certificate is not None
               else "UNSAT — exhaustively refuted (peer quorum-sampled)")
    print(f"  block {res.receipt.record.height}: 2^{N_VARS} assignments "
          f"-> {verdict}; accepted_by={res.accepted_by}")
    assert not res.rejected_by

print("== GAN inversion (optimal mode, §1) ==")
for b in range(4):                      # each refinement round is a block
    res = net.mine(b % 2, "gan")
    gan = net.nodes[0].workloads["gan"]
    print(f"  round {res.receipt.payload.train_height}: winner "
          f"arg={res.receipt.payload.best_arg:4d} "
          f"err={gan.inversion_error():.4f}")
    assert not res.rejected_by

err = net.nodes[0].workloads["gan"].inversion_error()
assert err < 1.0, err
# both nodes replayed every round -> bit-identical search state
assert (net.nodes[0].workloads["gan"].state_digest()
        == net.nodes[1].workloads["gan"].state_digest())
print(f"  inverted: ||G(z)-x*||^2 = {err:.4f} after 4 blocks "
      "(both nodes hold the same grid state)")

assert net.converged()
assert all(n.audit_chain() for n in net.nodes)
books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
assert len(books) == 1, "credit books diverged"
s = net.nodes[0].state()
print(f"converged: height {s.height}, credits {s.total_issued:.1f} "
      f"over {len(s.balances)} miners, books bit-identical")
