"""The paper's §1 application list, end-to-end:

1. **GAN inversion** — "finding the appropriate input to a Generator to
   fit a Discriminator": optimal-mode search over a latent grid, with
   RA-published refinement rounds (each block zooms the grid around the
   previous winner).
2. **Brute-force theorem proving** — "running Sledgehammer on randomly
   generated theorems": the SAT analogue; a full-mode block evaluates a
   random 3-CNF over all assignments, res = #unsatisfied clauses, so the
   chain *proves* satisfiability (res 0 exists) or exhaustively refutes.
3. **Difficulty retargeting** — the §5 "inconvenient limitation on the
   runtime of each node", fixed with the §3.1 max_arg granularity knob.

  PYTHONPATH=src python examples/np_problems.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.difficulty import DifficultyController, work_for_runtime
from repro.core.executor import run_full, run_optimal
from repro.core.jash import Jash, JashMeta

# ---------------------------------------------------------------------------
# 1. GAN inversion via optimal mode
# ---------------------------------------------------------------------------
print("== GAN inversion (optimal mode, §1) ==")
D_Z, D_X = 8, 32
key = jax.random.key(0)
k1, k2, k3 = jax.random.split(key, 3)
W1 = jax.random.normal(k1, (D_Z, 64)) / np.sqrt(D_Z)
W2 = jax.random.normal(k2, (64, D_X)) / 8.0


def generator(z):
    return jnp.tanh(z @ W1) @ W2


z_true = jax.random.normal(k3, (D_Z,))
x_target = generator(z_true)

GRID = 16                       # 16 candidates per latent dim per round
center = jnp.zeros((D_Z,))
scale = 3.0
for block in range(4):          # each refinement round is one block
    c, s = center, scale

    def invert_jash(arg):
        # arg indexes one perturbed latent: deterministic pseudo-grid
        zs = jax.random.normal(jax.random.fold_in(jax.random.key(7), arg),
                               (D_Z,))
        z = c + s * zs / 3.0
        err = jnp.sum(jnp.square(generator(z) - x_target))
        return (err * 1e4).astype(jnp.uint32)      # lower res wins (§3.3)

    jash = Jash(f"gan-invert-r{block}", invert_jash,
                JashMeta(arg_bits=10, res_bits=32, importance=1.0),
                example_args=(jnp.uint32(0),))
    opt = run_optimal(jash)
    zs = jax.random.normal(jax.random.fold_in(jax.random.key(7),
                                              jnp.uint32(opt.best_arg)),
                           (D_Z,))
    center = c + s * zs / 3.0
    scale = s * 0.5
    err = float(jnp.sum(jnp.square(generator(center) - x_target)))
    print(f"  block {block}: winner arg={opt.best_arg:4d} "
          f"err={err:.4f} scale={s:.2f}")
assert err < 1.0, err
print(f"  inverted: ||G(z)-x*||^2 = {err:.4f} after 4 blocks")

# ---------------------------------------------------------------------------
# 2. Brute-force theorem proving (SAT) via full mode
# ---------------------------------------------------------------------------
print("== brute-force SAT (full mode, §1 'theorem proving') ==")
N_VARS, N_CLAUSES = 12, 48
rng = np.random.RandomState(1)
cl_vars = jnp.asarray(rng.randint(0, N_VARS, (N_CLAUSES, 3)))
cl_neg = jnp.asarray(rng.randint(0, 2, (N_CLAUSES, 3)).astype(np.bool_))


def sat_jash(arg):
    bits = (arg[None] >> jnp.arange(N_VARS, dtype=jnp.uint32)) & 1
    lits = bits[cl_vars].astype(jnp.bool_) ^ cl_neg
    unsat = jnp.sum(~jnp.any(lits, axis=1))
    return unsat.astype(jnp.uint32)


jash = Jash("sat-3cnf", sat_jash,
            JashMeta(arg_bits=N_VARS, res_bits=32, importance=0.7,
                     description="random 3-CNF exhaustive check"),
            example_args=(jnp.uint32(0),))
t0 = time.time()
full = run_full(jash)
n_sat = int((full.results[:, 0] == 0).sum())
print(f"  2^{N_VARS} = {len(full.args)} assignments in "
      f"{time.time() - t0:.2f}s: {n_sat} satisfying "
      f"({'SATISFIABLE' if n_sat else 'UNSAT — exhaustively refuted'})")

# ---------------------------------------------------------------------------
# 3. Difficulty retargeting (§3.1 / §5)
# ---------------------------------------------------------------------------
print("== difficulty retargeting (§3.1 granularity knob) ==")
ctrl = DifficultyController(target_block_s=0.25, min_work=256)
work = work_for_runtime(runtime_mean_s=1e-4, target_block_s=0.25,
                        n_miners=1)
print(f"  initial work from RA runtime estimate: {work} args/block")
for blk in range(6):
    jash_b = Jash("sat-retarget", sat_jash,
                  JashMeta(arg_bits=N_VARS, res_bits=32,
                           max_arg=min(work, 1 << N_VARS)),
                  example_args=(jnp.uint32(0),))
    t0 = time.time()
    run_full(jash_b)
    dt = time.time() - t0
    ctrl.observe(dt)
    new_work = ctrl.next_work(work)
    print(f"  block {blk}: work={work:6d} time={dt * 1e3:7.1f}ms "
          f"ema={ctrl.ema_block_s * 1e3:7.1f}ms -> next={new_work}")
    work = new_work
print("  block time converges toward the 250 ms target.")
