"""PNPCoin quickstart: the complete Fig. 1 pipeline in ~60 lines.

A researcher submits the paper's own Collatz example (§3.2) to the
Runtime Authority; miners run full blocks; the chain falls back to
Classic SHA-256 blocks (§3.4) when the queue empties; every block is
verified and rewarded.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core.authority import RuntimeAuthority
from repro.core.executor import run_full, run_optimal
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.ledger import Ledger, merkle_root
from repro.core.rewards import CreditBook, reward_full, reward_optimal
from repro.core.verify import quorum_verify

ra = RuntimeAuthority()
ledger = Ledger()
book = CreditBook()

# --- researcher submits the paper's Fig. 2->3 Collatz jash ----------------
base = collatz_jash(max_steps=512)
report = ra.submit(Jash(base.name, base.fn,
                        JashMeta(arg_bits=10, res_bits=32, importance=0.8,
                                 description="Collatz stopping times"),
                        example_args=base.example_args))
print(f"RA review: compiled={report.compiled} "
      f"runtime={report.runtime_mean_s * 1e3:.2f}ms "
      f"priority={report.priority:.3g}")

# --- three blocks: queued jash, then Classic fallback ---------------------
for height in range(3):
    jash, source = ra.publish_next()
    if source == "classic":
        jash = Jash(jash.name, jash.fn,
                    JashMeta(arg_bits=10, res_bits=256),
                    example_args=jash.example_args)
        opt = run_optimal(jash)
        ledger.append(jash_id=jash.source_id(), mode="classic",
                      merkle=merkle_root([opt.best_res.tobytes()]),
                      winner=opt.winner,
                      best_res=opt.best_res.tobytes().hex()[:16],
                      n_results=opt.n_evaluated)
        reward_optimal(book, opt.winner, 50.0)
        print(f"block {height}: CLASSIC sha256, winner arg={opt.best_arg} "
              f"res={opt.best_res.tobytes().hex()[:16]}…")
    else:
        full = run_full(jash)
        assert quorum_verify(jash, full, fraction=0.1).ok
        ledger.append(jash_id=jash.source_id(), mode="full",
                      merkle=merkle_root(full.merkle_leaves), winner=None,
                      best_res=None, n_results=len(full.args))
        reward_full(book, full.miner_of.tolist(), 50.0)
        longest = int(full.results[:, 0].max())
        arg = int(full.args[full.results[:, 0].argmax()])
        print(f"block {height}: FULL {jash.name}, {len(full.args)} args; "
              f"longest stopping time {longest} at n={arg}")

print(f"\nledger verified: {ledger.verify_chain()}  tip={ledger.tip_hash[:16]}…")
print(f"credits issued: {book.total_issued} across {len(book.balances)} miners")
