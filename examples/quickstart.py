"""PNPCoin quickstart: the complete Fig. 1 pipeline through the chain API.

A researcher submits the paper's own Collatz example (§3.2) to a
``Node``; each ``mine_block()`` publishes, mines, self-verifies, commits
and rewards one block, falling back to Classic SHA-256 blocks (§3.4)
when the researcher queue empties.

  PYTHONPATH=src python examples/quickstart.py

Migration note (PR 2): the ~40 lines of hand-wired RuntimeAuthority +
Ledger + CreditBook + run_full + quorum_verify + reward_* glue this
script used to carry now live behind ``repro.chain.Node`` — see
DESIGN.md §7.  ``repro.core.*`` remains available as the kernel layer.
"""
from repro.chain import Node
from repro.core.jash import Jash, JashMeta, collatz_jash

node = Node(classic_arg_bits=10)

# --- researcher submits the paper's Fig. 2->3 Collatz jash ----------------
base = collatz_jash(max_steps=512)
report = node.submit(Jash(base.name, base.fn,
                          JashMeta(arg_bits=10, res_bits=32, importance=0.8,
                                   description="Collatz stopping times"),
                          example_args=base.example_args))
print(f"RA review: compiled={report.compiled} "
      f"runtime={report.runtime_mean_s * 1e3:.2f}ms "
      f"priority={report.priority:.3g}")

# --- three blocks: the queued jash (full mode), then Classic fallback -----
for _ in range(3):
    r = node.mine_block()
    print(f"block {r.record.height}: {r.record.workload.upper():8s} "
          f"{r.record.n_results} results, root={r.record.merkle_root[:16]}… "
          f"mined+verified in {r.block_time_s:.2f}s")

s = node.state()
print(f"\nledger verified: {s.chain_valid}  tip={s.tip_hash[:16]}…")
print(f"credits issued: {s.total_issued} across {len(s.balances)} miners")
