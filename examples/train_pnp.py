"""End-to-end PoUW training through the chain API (deliverable (b)):
train the ~2M-param pnpcoin-demo LM for a few hundred blocks on CPU —
each block one training step mined by a ``Node`` carrying a
``TrainingWorkload``, state digests chained into the ledger, miners
credited.

  PYTHONPATH=src python examples/train_pnp.py [--blocks 300]

Migration note (PR 2): this script used to shell out to
``repro.launch.train``; it now drives ``repro.chain.Node`` directly.
``repro.launch.train`` remains the full-featured CLI (checkpoint blocks,
ledger/credits export).
"""
import argparse

from repro.chain import Node, TrainingWorkload
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.pow_train import PoUWTrainer
from repro.train.steps import TrainHparams

ap = argparse.ArgumentParser()
ap.add_argument("--blocks", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--miners", type=int, default=8)
ap.add_argument("--lr", type=float, default=1e-3)
args = ap.parse_args()

cfg = get_config("pnpcoin-demo")
shape = InputShape("cli", args.seq, args.batch, "train")
hp = TrainHparams(peak_lr=args.lr, warmup_steps=max(args.blocks // 20, 5),
                  total_steps=args.blocks)
node = Node(workloads={"training": TrainingWorkload(
    lambda: PoUWTrainer(cfg, shape, hp=hp, mode="full",
                        n_miners=args.miners))})

for b in range(args.blocks):
    r = node.mine_block("training")
    if b % 10 == 0 or b == args.blocks - 1:
        print(f"block {r.record.height:4d} loss={r.payload.loss:.4f} "
              f"chain={r.record.block_hash[:12]} ({r.block_time_s:.2f}s)",
              flush=True)

s = node.state()
assert s.chain_valid
losses = [p.loss for p in node.chain_payloads()]
print(f"done: {args.blocks} blocks, loss {losses[0]:.4f} -> "
      f"{losses[-1]:.4f}, credits issued {s.total_issued:.1f}, "
      f"chain verified.")
