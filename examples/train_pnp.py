"""End-to-end driver (deliverable (b)): train the ~30M-param pnpcoin-demo
LM for a few hundred PoUW blocks on CPU — one block per training step,
checkpoint digests chained into the ledger, miners credited.

  PYTHONPATH=src python examples/train_pnp.py [--blocks 300]

(This is a thin veneer over ``repro.launch.train``; see that module for
the full CLI.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or []
    main(["--arch", "pnpcoin-demo", "--blocks", "300", "--batch", "16",
          "--seq", "128", "--mode", "full", "--miners", "8",
          "--lr", "1e-3", "--ckpt-every", "150",
          "--out", "experiments/train_pnp", *argv])
