"""Cross-process peer networking (``repro.chain.net``, DESIGN.md §13)
in one sitting:

  - three peers on the deterministic loopback wire, signed identities,
    compact relay — mine a few classic blocks and watch the announce /
    body-fetch / dedup counters,
  - a forged announce (wrong key claiming another origin) dying at the
    signature check before any body crosses the wire,
  - the convergence oracle: the same schedule on the in-process
    ``Network`` commits the byte-identical chain.

The two-OS-process TCP flavor is ``python -m repro.chain.net --demo``.

  PYTHONPATH=src python examples/wire_peers.py
"""
from repro.chain import Node
from repro.chain.net import (Announce, LoopbackHub, PeerNode, chain_digest,
                             loopback_scenario, make_announce,
                             make_identities)

N_PEERS, N_BLOCKS = 3, 6


def main() -> int:
    ids, ring = make_identities(N_PEERS)
    hub = LoopbackHub(seed=0)
    peers = []
    for i in range(N_PEERS):
        pn = PeerNode(Node(node_id=i, classic_arg_bits=6, keyring=ring),
                      ids[i], ring)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)

    for b in range(N_BLOCKS):
        receipt = peers[b % N_PEERS].mine_and_announce()
        hub.pump()
        print(f"height {receipt.record.height} mined by "
              f"node{b % N_PEERS}: all peers at "
              f"{[p.node.ledger.height for p in peers]}")

    digests = {chain_digest(p.node) for p in peers}
    assert len(digests) == 1, "peers diverged"
    s = peers[0].stats
    print(f"\ncompact relay: {s.announces_sent} announces sent, "
          f"{sum(p.stats.compact_hits for p in peers)} body-dedup hits, "
          f"{hub.total_bytes()} bytes on the wire")

    # a forged announce: node 2's key claiming node 0 mined the block
    receipt = peers[0].node.mine_block()
    honest = make_announce(ids[0], receipt.record.to_block(),
                           receipt.payload)
    forged = Announce(header=honest.header, checksum=honest.checksum,
                      origin=honest.origin, pubkey=ids[2].pubkey,
                      signature=honest.signature, body=None)
    requests_before = peers[1].stats.body_requests
    peers[0].port.send("peer1", forged)
    hub.pump()
    assert peers[1].stats.sig_rejects == 1
    assert peers[1].stats.body_requests == requests_before
    print("forged announce: rejected at the signature, zero body bytes")

    # the convergence oracle, end to end (wire vs in-process Network)
    report = loopback_scenario(n_peers=2, seed=0,
                               schedule=("classic",) * 4)
    assert report["oracle_match"], report
    print(f"oracle: wire chain == in-process chain "
          f"({report['chain_digest'][:16]}…)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
