#!/usr/bin/env python
"""Doc-consistency CI check (wired into the examples-smoke job).

Two invariants keep the docs honest:

1. **API coverage** — every name in the ``__all__`` of ``repro``,
   ``repro.chain`` and ``repro.core`` has a ``### `module.name` ``
   heading in ``docs/api.md`` (a new export without a doc entry fails
   CI; a doc entry for a removed export fails too).
2. **README executes** — every ```` ```python ```` block in README.md
   runs, in order, in one shared namespace (a doctest-style session:
   later blocks may use names defined by earlier ones).

Run it the way CI does::

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

MODULES = ("repro", "repro.chain", "repro.core")


def check_api_coverage(api_md: Path = REPO / "docs" / "api.md"
                       ) -> list:
    """Names exported but undocumented, plus documented-but-not-exported
    headings (empty list == consistent)."""
    text = api_md.read_text()
    problems = []
    documented = set(re.findall(r"^###\s+`([\w.]+)`", text, re.M))
    exported = set()
    for modname in MODULES:
        mod = importlib.import_module(modname)
        for name in mod.__all__:
            exported.add(f"{modname}.{name}")
            if f"{modname}.{name}" not in documented:
                problems.append(
                    f"{modname}.{name} is exported in {modname}.__all__ "
                    f"but has no `### \\`{modname}.{name}\\`` entry in "
                    f"{api_md.relative_to(REPO)}")
    for heading in sorted(documented):
        modname = heading.rsplit(".", 1)[0]
        if modname in MODULES and heading not in exported:
            problems.append(
                f"{heading} is documented in {api_md.relative_to(REPO)} "
                f"but not exported from {modname}.__all__ (stale entry?)")
    return problems


def run_readme_blocks(readme: Path = REPO / "README.md") -> list:
    """Execute every ```python block of the README in one shared
    namespace, in order.  Returns a list of failure descriptions."""
    text = readme.read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    ns: dict = {}
    problems = []
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"<README block {i}>", "exec"), ns)
        except Exception as e:                     # noqa: BLE001
            problems.append(
                f"README python block {i} failed: {type(e).__name__}: {e}"
                f"\n---\n{block}---")
    if not blocks:
        problems.append("README.md contains no ```python blocks")
    return problems


def main() -> int:
    problems = check_api_coverage()
    n_api = len(problems)
    print(f"api coverage: {'OK' if not n_api else f'{n_api} problem(s)'} "
          f"({sum(len(importlib.import_module(m).__all__) for m in MODULES)}"
          " exported names checked)")
    readme_problems = run_readme_blocks()
    problems += readme_problems
    print(f"README blocks: "
          f"{'OK' if not readme_problems else 'FAILED'}")
    for p in problems:
        print(f"  - {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
