#!/usr/bin/env python
"""Doc-consistency CI check (wired into the examples-smoke job).

Three invariants keep the docs honest:

1. **API coverage** — every name in the ``__all__`` of ``repro``,
   ``repro.chain``, ``repro.chain.net``, ``repro.chain.workloads`` and
   ``repro.core`` has a ``### `module.name` `` heading in
   ``docs/api.md`` (a new export without a doc entry fails CI; a doc
   entry for a removed export fails too).
2. **Docs execute** — every ```` ```python ```` block in README.md and
   ``docs/workloads.md`` runs, in order, in one shared namespace per
   file (a doctest-style session: later blocks may use names defined
   by earlier ones).  ``docs/api.md`` blocks are executed by the
   tier-1 suite (``tests/test_docs.py``) — they are numerous and
   belong with the fast feedback loop.
3. **No orphan docs** — every ``docs/*.md`` file must be claimed by an
   entry in ``DOC_CHECKS`` below; a doc nothing executes or
   cross-checks is a doc that silently rots.

Run it the way CI does::

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

MODULES = ("repro", "repro.chain", "repro.chain.net",
           "repro.chain.workloads", "repro.core")

# every file under docs/ must appear here, mapped to how it is kept
# honest: "blocks" (its ```python blocks execute in this script),
# "tier1" (executed/cross-checked by tests/test_docs.py), or a
# free-form justification string for genuinely static docs.
DOC_CHECKS = {
    "api.md": "tier1",      # coverage here + snippets in tests/test_docs.py
    "workloads.md": "blocks",
}


def check_api_coverage(api_md: Path = REPO / "docs" / "api.md"
                       ) -> list:
    """Names exported but undocumented, plus documented-but-not-exported
    headings (empty list == consistent)."""
    text = api_md.read_text()
    problems = []
    documented = set(re.findall(r"^###\s+`([\w.]+)`", text, re.M))
    exported = set()
    for modname in MODULES:
        mod = importlib.import_module(modname)
        for name in mod.__all__:
            exported.add(f"{modname}.{name}")
            if f"{modname}.{name}" not in documented:
                problems.append(
                    f"{modname}.{name} is exported in {modname}.__all__ "
                    f"but has no `### \\`{modname}.{name}\\`` entry in "
                    f"{api_md.relative_to(REPO)}")
    for heading in sorted(documented):
        modname = heading.rsplit(".", 1)[0]
        if modname in MODULES and heading not in exported:
            problems.append(
                f"{heading} is documented in {api_md.relative_to(REPO)} "
                f"but not exported from {modname}.__all__ (stale entry?)")
    return problems


def run_md_blocks(path: Path) -> list:
    """Execute every ```python block of ``path`` in one shared
    namespace, in order.  Returns a list of failure descriptions."""
    text = path.read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    ns: dict = {}
    problems = []
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"<{path.name} block {i}>", "exec"), ns)
        except Exception as e:                     # noqa: BLE001
            problems.append(
                f"{path.name} python block {i} failed: "
                f"{type(e).__name__}: {e}\n---\n{block}---")
    if not blocks:
        problems.append(f"{path.name} contains no ```python blocks")
    return problems


def run_readme_blocks(readme: Path = REPO / "README.md") -> list:
    """README's executable session (kept as its own entry point — the
    tier-1 suite calls it too)."""
    return run_md_blocks(readme)


def check_docs_coverage(docs_dir: Path = REPO / "docs") -> list:
    """Every docs/*.md must be claimed by DOC_CHECKS (and vice versa) —
    a doc no check executes or cross-references rots silently."""
    problems = []
    on_disk = {p.name for p in docs_dir.glob("*.md")}
    for name in sorted(on_disk - set(DOC_CHECKS)):
        problems.append(
            f"docs/{name} is not covered by any doc check — add it to "
            "DOC_CHECKS in scripts/check_docs.py (execute its blocks, "
            "or justify why it is static)")
    for name in sorted(set(DOC_CHECKS) - on_disk):
        problems.append(
            f"DOC_CHECKS claims docs/{name} but the file does not exist "
            "(stale entry in scripts/check_docs.py?)")
    return problems


def main() -> int:
    problems = check_api_coverage()
    n_api = len(problems)
    print(f"api coverage: {'OK' if not n_api else f'{n_api} problem(s)'} "
          f"({sum(len(importlib.import_module(m).__all__) for m in MODULES)}"
          " exported names checked)")
    readme_problems = run_readme_blocks()
    problems += readme_problems
    print(f"README blocks: "
          f"{'OK' if not readme_problems else 'FAILED'}")
    for name, how in DOC_CHECKS.items():
        if how != "blocks":
            continue
        doc_problems = run_md_blocks(REPO / "docs" / name)
        problems += doc_problems
        print(f"docs/{name} blocks: "
              f"{'OK' if not doc_problems else 'FAILED'}")
    coverage_problems = check_docs_coverage()
    problems += coverage_problems
    print(f"docs coverage: "
          f"{'OK' if not coverage_problems else 'FAILED'} "
          f"({len(DOC_CHECKS)} docs claimed)")
    for p in problems:
        print(f"  - {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
