"""PNPCoin reproduction: distributed useful-work computing on Bitcoin
infrastructure (Kolar, 2022), on JAX.

The stable public surface is the chain API::

    from repro import Node, Network, Workload

``repro.core`` (kernel layer), ``repro.kernels`` (device SHA-256 /
Merkle), ``repro.models`` / ``repro.train`` (PoUW payload models) sit
underneath and move faster; import them directly when you need them.
"""
from repro.chain import BlockRecord, Network, Node, Workload

__all__ = ["BlockRecord", "Network", "Node", "Workload"]
