"""``repro.chain`` — the public Node/Network API over the PNPCoin loop.

Layering (DESIGN.md §7)::

    repro.core.*   stable kernel layer (executor, ledger, rewards, verify)
    repro.chain.*  the protocol: Workload payloads, Node facade, Network
    examples/      thin scripts over repro.chain

Start here::

    from repro.chain import Node
    node = Node()
    node.submit(my_jash)
    receipt = node.mine_block()

``repro.chain.sim`` layers a deterministic event-driven asynchronous
network simulator (latency, drops, partitions, churn, adversaries) on
top of Node/Network; its core surface (``Sim``/``SimConfig``/
``SimReport``/``LinkModel``) is re-exported here, the adversary classes
and canonical scenarios live in the module.

``repro.chain.store`` is the crash-fault layer: ``ChainStore`` is a
durable append-only journal of everything a ``Node(store=...)``
commits, and ``Node.recover`` rebuilds a node from it after a crash
(truncating torn/corrupted tails instead of failing).  Finality
(``Node(confirmation_depth=k)``) checkpoints blocks with ``k``
successors, fences fork choice against long-range rewrites, and prunes
retained state so long-running memory stays bounded.

``repro.chain.workloads`` is the application workload suite — SAT
(certificate-asymmetric), GAN inversion (stateful grid refinement),
and docking (consensus-bound data bundle) as first-class ``Workload``
families; see ``docs/workloads.md`` for the authoring guide.

``repro.chain.net`` takes nodes out-of-process: a signed typed wire
protocol over the same canonical encoding as the journal, compact
block relay, loopback and TCP transports, and a convergence oracle
requiring wire-connected peers to reconverge bit-identically with the
in-process ``Network`` (DESIGN.md §13).
"""
from repro.chain.network import BroadcastResult, Network
from repro.chain.node import (BlockReceipt, BlockRecord, Node, NodeState,
                              RecoveryReport, VerifyCache)
from repro.chain.sim import LinkModel, Sim, SimConfig, SimReport
from repro.chain.store import ChainStore, collect_jash_fns, payload_checksum
from repro.chain.workload import (
    BlockContext, BlockPayload, ChainError, ClassicSha256Workload,
    JashFullWorkload, JashOptimalWorkload, TrainingWorkload, Workload,
    certificate_digest, verify_chain_batched,
)

__all__ = [
    "BlockContext",
    "BlockPayload",
    "BlockReceipt",
    "BlockRecord",
    "BroadcastResult",
    "ChainError",
    "ChainStore",
    "ClassicSha256Workload",
    "JashFullWorkload",
    "JashOptimalWorkload",
    "LinkModel",
    "Network",
    "Node",
    "NodeState",
    "RecoveryReport",
    "Sim",
    "SimConfig",
    "SimReport",
    "TrainingWorkload",
    "VerifyCache",
    "Workload",
    "certificate_digest",
    "collect_jash_fns",
    "payload_checksum",
    "verify_chain_batched",
]
