"""``repro.chain`` — the public Node/Network API over the PNPCoin loop.

Layering (DESIGN.md §7)::

    repro.core.*   stable kernel layer (executor, ledger, rewards, verify)
    repro.chain.*  the protocol: Workload payloads, Node facade, Network
    examples/      thin scripts over repro.chain

Start here::

    from repro.chain import Node
    node = Node()
    node.submit(my_jash)
    receipt = node.mine_block()
"""
from repro.chain.network import BroadcastResult, Network
from repro.chain.node import BlockReceipt, BlockRecord, Node, NodeState
from repro.chain.workload import (
    BlockContext, BlockPayload, ChainError, ClassicSha256Workload,
    JashFullWorkload, JashOptimalWorkload, TrainingWorkload, Workload,
)

__all__ = [
    "BlockContext",
    "BlockPayload",
    "BlockReceipt",
    "BlockRecord",
    "BroadcastResult",
    "ChainError",
    "ClassicSha256Workload",
    "JashFullWorkload",
    "JashOptimalWorkload",
    "Network",
    "Node",
    "NodeState",
    "TrainingWorkload",
    "Workload",
]
