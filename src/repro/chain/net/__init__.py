"""``repro.chain.net`` — cross-process peer networking for PNPCoin
nodes (DESIGN.md §13).

Everything below ``repro.chain`` so far ran N ``Node`` objects in one
interpreter (``Network``, ``Sim``).  This package takes them out of
process without touching consensus:

* ``messages`` — the typed, versioned wire catalogue (HELLO, ANNOUNCE,
  GET_HEADERS, TIP, GET_BODIES, BODIES), framed with the journal's
  ``type | length | body | sha256[:16]`` discipline and carrying the
  canonical ``encode_block`` / ``encode_payload`` bytes as the body
  format — the disk format *is* the wire format.
* ``identity`` — Ed25519 peer identities (pure-Python RFC 8032; no
  third-party crypto dependency): every ANNOUNCE is origin-signed, so
  ``BlockPayload.origin`` is cryptographically bound to the sender.
* ``transport`` — a deterministic seeded loopback hub (tests, sim,
  benches) and real asyncio TCP, both with retry/backoff and
  malformed-frame quarantine behind a never-raising decoder.
* ``peer`` — ``PeerNode``: sans-IO protocol logic driving one
  unmodified ``Node`` with BIP-152-style compact relay (header +
  content checksum announces; bodies fetched by checksum on demand;
  already-seen payloads never cross the wire twice), plus the
  liveness layer (DESIGN.md §15): PING/PONG keepalive, per-request
  deadlines with exponential-backoff failover, and anchor
  connections — ``EclipseAttacker`` + ``mesh_chaos_scenario`` pin
  the whole stack under crashes, journal corruption, an addr-flood
  eclipse adversary, and corrupted frames at once.
* ``peerbook`` — the mesh layer (DESIGN.md §14): ``PeerBook`` is a
  capped two-bucket address manager fed by signed HELLO/ADDR addr
  gossip and driving outbound dialing; ``PeerScore`` ranks
  connections for eviction and bans protocol abusers; ``TokenBucket``
  rate-limits the serve path (GET_BODIES / GET_HEADERS) so a spammer
  cannot starve honest sync.

The correctness contract is the **convergence oracle**: peers mining
over the wire — two OS processes over TCP (``python -m
repro.chain.net --demo``) or N loopback peers
(``loopback_scenario``) — must reconverge **bit-identically** with the
in-process ``Network`` on the same seeds: tips, ledgers, and credit
books byte-for-byte.

Run the two-process TCP convergence demo (used by CI)::

    PYTHONPATH=src python -m repro.chain.net --demo
"""
from repro.chain.net.identity import (KeyRing, PeerAddr, PeerIdentity,
                                      SignedAnnounce, ed25519_public_key,
                                      ed25519_sign, ed25519_verify,
                                      make_addr, make_announce,
                                      make_identities)
from repro.chain.net.messages import (MAX_ADDRS, MAX_BODY, PROTOCOL_VERSION,
                                      WIRE_MAGIC, Addr, Announce, Bodies,
                                      FrameBuffer, GetBodies, GetHeaders,
                                      Hello, Message, Ping, Pong, Tip,
                                      decode_message, encode_message)
from repro.chain.net.peer import (EclipseAttacker, PeerNode, PeerStats,
                                  chain_digest, loopback_scenario,
                                  mesh_chaos_scenario, mesh_scenario)
from repro.chain.net.peerbook import PeerBook, PeerScore, TokenBucket
from repro.chain.net.transport import (LoopbackHub, LoopbackPort,
                                       TcpTransport, WireStats)

__all__ = [
    "Addr",
    "Announce",
    "Bodies",
    "EclipseAttacker",
    "FrameBuffer",
    "GetBodies",
    "GetHeaders",
    "Hello",
    "KeyRing",
    "LoopbackHub",
    "LoopbackPort",
    "MAX_ADDRS",
    "MAX_BODY",
    "Message",
    "PROTOCOL_VERSION",
    "PeerAddr",
    "PeerBook",
    "PeerIdentity",
    "PeerNode",
    "PeerScore",
    "PeerStats",
    "Ping",
    "Pong",
    "SignedAnnounce",
    "TcpTransport",
    "Tip",
    "TokenBucket",
    "WIRE_MAGIC",
    "WireStats",
    "chain_digest",
    "decode_message",
    "ed25519_public_key",
    "ed25519_sign",
    "ed25519_verify",
    "encode_message",
    "loopback_scenario",
    "make_addr",
    "make_announce",
    "make_identities",
    "mesh_chaos_scenario",
    "mesh_scenario",
]
