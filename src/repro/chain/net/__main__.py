"""``python -m repro.chain.net --demo`` — the two-OS-process TCP
convergence oracle (DESIGN.md §13, run by CI's examples-smoke).

The parent process listens on an ephemeral TCP port, spawns a child
interpreter (``--role child``), and the two mine the heterogeneous
workload suite round-robin over real TCP with signed compact relay
(parent mines even heights, child odd).  When both reach the target
height the child prints its canonical chain digest and credit book;
the parent then mines the *same* schedule on an in-process ``Network``
with the same seeds and requires all three — parent, child, oracle —
to be bit-identical.  Wall-clock is bounded by ``--timeout``.

Exit status 0 iff the chains converged AND matched the in-process
oracle.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import subprocess
import sys
import time

from repro.chain.net.identity import make_identities
from repro.chain.net.peer import (_SUITE_SCHEDULE, PeerNode, _suite_node,
                                  chain_digest)
from repro.chain.net.transport import TcpTransport

_RESULT_PREFIX = "RESULT "


def _build_peer(idx: int, *, suite_seed: int) -> PeerNode:
    identities, ring = make_identities(2)
    node = _suite_node(idx, suite_seed=suite_seed, keyring=ring)
    return PeerNode(node, identities[idx], ring, compact=True)


async def _mine_loop(peer: PeerNode, transport: TcpTransport, idx: int,
                     schedule, deadline: float) -> None:
    """Round-robin over TCP: mine when the tip height is ours, else let
    the reader tasks advance the chain.  After reaching the target,
    keep serving body fetches until the other side reports the target
    height too (its last block may still need our bodies)."""
    loop = asyncio.get_running_loop()
    target = len(schedule)
    last_hello = 0.0
    last_height = -1
    while True:
        if loop.time() > deadline:
            raise TimeoutError(
                f"peer {idx} stuck at height {peer.node.ledger.height}")
        h = peer.node.ledger.height
        if h != last_height:
            # announce every height change at once: a chain pull can
            # jump several heights in one event, and the peer must see
            # the final height before we are allowed to exit — a timer
            # alone races with shutdown
            last_height = h
            last_hello = loop.time()
            peer.broadcast_hello()
            await transport.drain()
        if h >= target and max(peer.peer_heights.values(),
                               default=0) >= target:
            peer.broadcast_hello()       # parting beacon: peer exits too
            await transport.drain()
            return
        now = loop.time()
        if now - last_hello > 0.2:
            last_hello = now
            peer.broadcast_hello()       # height beacon + resync trigger
            await transport.drain()
        if h < target and h % 2 == idx:
            peer.mine_and_announce(schedule[h])
            await transport.drain()
        else:
            await asyncio.sleep(0.02)


async def _run_child(port: int, *, suite_seed: int, timeout: float,
                     schedule) -> dict:
    peer = _build_peer(1, suite_seed=suite_seed)
    transport = TcpTransport()
    peer.attach(transport)
    await transport.connect("127.0.0.1", port)
    deadline = asyncio.get_running_loop().time() + timeout
    await _mine_loop(peer, transport, 1, schedule, deadline)
    await transport.drain()
    report = {
        "role": "child",
        "height": peer.node.ledger.height,
        "chain_digest": chain_digest(peer.node),
        "book": sorted(peer.node.book.balances.items()),
        "chain_valid": peer.node.ledger.verify_chain(),
        "stats": peer.stats.to_dict(),
        "wire": transport.stats.to_dict(),
    }
    # linger a moment so late body fetches from the parent still land
    await asyncio.sleep(0.3)
    await transport.close()
    return report


async def _run_parent(*, suite_seed: int, timeout: float,
                      verbose: bool, schedule) -> int:
    t0 = time.perf_counter()
    peer = _build_peer(0, suite_seed=suite_seed)
    transport = TcpTransport()
    peer.attach(transport)
    port = await transport.listen()
    child = subprocess.Popen(
        [sys.executable, "-m", "repro.chain.net", "--role", "child",
         "--port", str(port), "--suite-seed", str(suite_seed),
         "--timeout", str(timeout), "--schedule", ",".join(schedule)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env=dict(os.environ))
    try:
        deadline = asyncio.get_running_loop().time() + timeout
        await _mine_loop(peer, transport, 0, schedule, deadline)
        await transport.drain()
        out, _ = await asyncio.get_running_loop().run_in_executor(
            None, lambda: child.communicate(timeout=timeout))
    except BaseException:
        if child.poll() is None:
            child.kill()
        try:
            dump, _ = child.communicate(timeout=10)
            print(f"--- child output ---\n{dump}", file=sys.stderr)
        except Exception:
            pass
        raise
    finally:
        if child.poll() is None:
            child.kill()
        await transport.close()
    child_report = None
    for line in (out or "").splitlines():
        if line.startswith(_RESULT_PREFIX):
            child_report = json.loads(line[len(_RESULT_PREFIX):])
    if child_report is None:
        print(out or "", file=sys.stderr)
        print("FAIL: child produced no RESULT line", file=sys.stderr)
        return 1

    # the in-process oracle: same seeds, same schedule, one interpreter
    from repro.chain.network import Network
    identities, ring = make_identities(2)
    net = Network.create(
        2, node_factory=lambda i: _suite_node(
            i, suite_seed=suite_seed, keyring=ring),
        identities=identities)
    net.run(len(schedule), list(schedule))
    oracle_digest = chain_digest(net.nodes[0])
    oracle_book = sorted(net.nodes[0].book.balances.items())

    parent_digest = chain_digest(peer.node)
    parent_book = sorted(peer.node.book.balances.items())
    ok = (parent_digest == child_report["chain_digest"] == oracle_digest
          and parent_book == [tuple(e) for e in child_report["book"]]
          == oracle_book
          and peer.node.ledger.verify_chain()
          and child_report["chain_valid"])
    report = {
        "demo": "two-process TCP convergence",
        "n_blocks": len(schedule),
        "height": peer.node.ledger.height,
        "converged": parent_digest == child_report["chain_digest"],
        "oracle_match": ok,
        "chain_digest": parent_digest,
        "oracle_digest": oracle_digest,
        "elapsed_s": round(time.perf_counter() - t0, 3),
        "parent_stats": peer.stats.to_dict(),
        "child_stats": child_report["stats"],
        "parent_wire": transport.stats.to_dict(),
        "child_wire": child_report["wire"],
    }
    if verbose:
        print(json.dumps(report, indent=2))
    else:
        print(json.dumps({k: report[k] for k in
                          ("converged", "oracle_match", "height",
                           "elapsed_s")}))
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--demo", action="store_true",
                    help="run the two-process TCP convergence demo")
    ap.add_argument("--role", choices=("parent", "child"),
                    default="parent")
    ap.add_argument("--port", type=int, default=0,
                    help="(child) parent's listen port")
    ap.add_argument("--suite-seed", type=int, default=7)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="overall wall-clock bound (generous: first-run "
                         "XLA compilation of the workload kernels can "
                         "dominate)")
    ap.add_argument("--schedule", default=",".join(_SUITE_SCHEDULE),
                    help="comma-separated workload families to mine, "
                         "round-robin (default: the full heterogeneous "
                         "suite)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    schedule = tuple(f for f in args.schedule.split(",") if f)
    if args.role == "child":
        report = asyncio.run(
            _run_child(args.port, suite_seed=args.suite_seed,
                       timeout=args.timeout, schedule=schedule))
        print(_RESULT_PREFIX + json.dumps(report), flush=True)
        return 0
    if not args.demo:
        ap.error("nothing to do: pass --demo (or --role child)")
    return asyncio.run(
        _run_parent(suite_seed=args.suite_seed, timeout=args.timeout,
                    verbose=args.verbose, schedule=schedule))


if __name__ == "__main__":
    raise SystemExit(main())
