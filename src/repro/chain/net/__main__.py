"""``python -m repro.chain.net --demo [--peers N]`` — the N-OS-process
TCP mesh convergence oracle (DESIGN.md §13–§14, run by CI's
examples-smoke).

The parent process (worker 0) listens on an ephemeral TCP port — the
**single seed address** — and spawns N-1 child interpreters
(``--role child``).  Every child knows only the seed: it dials it,
learns the rest of the mesh from signed HELLO/ADDR gossip, and dials
the peers its ``PeerBook`` proposes until the mesh is connected.  The
N workers then mine the heterogeneous workload suite round-robin
(block ``k`` is mined by worker ``k mod N``) over real TCP with
signed compact relay.  When every worker sees every other at the
target height, children print their canonical chain digest and credit
book; the parent mines the *same* schedule on an in-process
``Network`` with the same seeds and requires all N+1 — every worker
plus the oracle — to be bit-identical.  Wall-clock is bounded by
``--timeout``.

Exit status 0 iff every chain converged AND matched the in-process
oracle.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import subprocess
import sys
import time

from repro.chain.net.identity import make_addr, make_identities
from repro.chain.net.peer import (_SUITE_SCHEDULE, PeerNode, _suite_node,
                                  chain_digest)
from repro.chain.net.transport import TcpTransport

_RESULT_PREFIX = "RESULT "
_HOST = "127.0.0.1"


def _build_peer(idx: int, n_peers: int, *, suite_seed: int):
    """One worker's peer plus the shared identity list (every process
    derives the same deterministic identities, so any worker can
    reconstruct the seed's signed addr locally)."""
    identities, ring = make_identities(n_peers)
    node = _suite_node(idx, suite_seed=suite_seed, keyring=ring)
    peer = PeerNode(node, identities[idx], ring, compact=True,
                    max_peers=2 * n_peers)
    return peer, identities


async def _dial_round(peer: PeerNode, transport: TcpTransport) -> int:
    """Dial every candidate the PeerBook proposes right now."""
    dialed = 0
    for cand in list(peer.dial_candidates()):
        peer.note_dialing(cand.node_id)
        try:
            conn = await transport.connect(cand.host, cand.port,
                                           retries=3, backoff=0.1)
        except ConnectionError:
            peer.note_dial_failed(cand.node_id)
            continue
        peer.on_dialed(conn, cand)
        dialed += 1
    if dialed:
        await transport.drain()
    return dialed


async def _mine_loop(peer: PeerNode, transport: TcpTransport, idx: int,
                     n_peers: int, schedule, deadline: float) -> None:
    """Round-robin over TCP: mine when the tip height is ours, else let
    the reader tasks advance the chain.  Between turns, dial whatever
    the PeerBook has discovered.  After reaching the target, keep
    serving body fetches until every known peer reports the target
    height too (their last blocks may still need our bodies)."""
    loop = asyncio.get_running_loop()
    target = len(schedule)
    last_hello = 0.0
    last_height = -1
    while True:
        if loop.time() > deadline:
            raise TimeoutError(
                f"peer {idx} stuck at height {peer.node.ledger.height} "
                f"knowing {sorted(peer.known_heights().items())}")
        await _dial_round(peer, transport)
        h = peer.node.ledger.height
        if h != last_height:
            # announce every height change at once: a chain pull can
            # jump several heights in one event, and the peers must see
            # the final height before we are allowed to exit — a timer
            # alone races with shutdown
            last_height = h
            last_hello = loop.time()
            peer.broadcast_hello()
            await transport.drain()
        heights = peer.known_heights()
        if (h >= target and len(heights) >= n_peers - 1
                and all(v >= target for v in heights.values())):
            peer.broadcast_hello()       # parting beacon: peers exit too
            await transport.drain()
            return
        now = loop.time()
        if now - last_hello > 0.2:
            last_hello = now
            peer.broadcast_hello()       # height beacon + resync trigger
            await transport.drain()
        if h < target and h % n_peers == idx:
            peer.mine_and_announce(schedule[h])
            await transport.drain()
        else:
            await asyncio.sleep(0.02)


def _report(peer: PeerNode, transport: TcpTransport, role: str) -> dict:
    return {
        "role": role,
        "height": peer.node.ledger.height,
        "chain_digest": chain_digest(peer.node),
        "book": sorted(peer.node.book.balances.items()),
        "chain_valid": peer.node.ledger.verify_chain(),
        "known_ids": sorted(peer.known_heights()),
        "n_conns": len(transport.peer_names()),
        "stats": peer.stats.to_dict(),
        "wire": transport.stats.to_dict(),
    }


async def _run_child(idx: int, seed_port: int, n_peers: int, *,
                     suite_seed: int, timeout: float, schedule) -> dict:
    peer, identities = _build_peer(idx, n_peers, suite_seed=suite_seed)
    transport = TcpTransport()
    peer.attach(transport)
    own_port = await transport.listen(_HOST)
    peer.addr = make_addr(identities[idx], _HOST, own_port)
    # single-seed bootstrap: the only address a child starts with is
    # worker 0's (its signed record is reconstructible — identities
    # are deterministic — so it enters the tried bucket like any dial)
    seed_addr = make_addr(identities[0], _HOST, seed_port)
    peer.note_dialing(0)
    conn = await transport.connect(_HOST, seed_port)
    peer.on_dialed(conn, seed_addr)
    deadline = asyncio.get_running_loop().time() + timeout
    await _mine_loop(peer, transport, idx, n_peers, schedule, deadline)
    await transport.drain()
    report = _report(peer, transport, f"child{idx}")
    # linger a moment so late body fetches from slower peers still land
    await asyncio.sleep(0.3)
    await transport.close()
    return report


async def _run_parent(*, n_peers: int, suite_seed: int, timeout: float,
                      verbose: bool, schedule) -> int:
    t0 = time.perf_counter()
    peer, identities = _build_peer(0, n_peers, suite_seed=suite_seed)
    transport = TcpTransport()
    peer.attach(transport)
    port = await transport.listen(_HOST)
    peer.addr = make_addr(identities[0], _HOST, port)
    children = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.chain.net", "--role", "child",
             "--index", str(i), "--port", str(port),
             "--peers", str(n_peers), "--suite-seed", str(suite_seed),
             "--timeout", str(timeout), "--schedule", ",".join(schedule)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True, env=dict(os.environ))
        for i in range(1, n_peers)]
    outputs = []
    try:
        deadline = asyncio.get_running_loop().time() + timeout
        await _mine_loop(peer, transport, 0, n_peers, schedule, deadline)
        await transport.drain()
        for child in children:
            out, _ = await asyncio.get_running_loop().run_in_executor(
                None, lambda c=child: c.communicate(timeout=timeout))
            outputs.append(out)
    except BaseException:
        for child in children:
            if child.poll() is None:
                child.kill()
            try:
                dump, _ = child.communicate(timeout=10)
                print(f"--- child output ---\n{dump}", file=sys.stderr)
            except Exception:
                pass
        raise
    finally:
        for child in children:
            if child.poll() is None:
                child.kill()
        await transport.close()
    child_reports = []
    for out in outputs:
        found = None
        for line in (out or "").splitlines():
            if line.startswith(_RESULT_PREFIX):
                found = json.loads(line[len(_RESULT_PREFIX):])
        if found is None:
            print(out or "", file=sys.stderr)
            print("FAIL: a child produced no RESULT line", file=sys.stderr)
            return 1
        child_reports.append(found)

    # the in-process oracle: same seeds, same schedule, one interpreter
    from repro.chain.network import Network
    oracle_ids, ring = make_identities(n_peers)
    net = Network.create(
        n_peers, node_factory=lambda i: _suite_node(
            i, suite_seed=suite_seed, keyring=ring),
        identities=oracle_ids)
    net.run(len(schedule), list(schedule))
    oracle_digest = chain_digest(net.nodes[0])
    oracle_book = sorted(net.nodes[0].book.balances.items())

    parent_digest = chain_digest(peer.node)
    parent_book = sorted(peer.node.book.balances.items())
    converged = all(r["chain_digest"] == parent_digest
                    for r in child_reports)
    ok = (converged and parent_digest == oracle_digest
          and parent_book == oracle_book
          and all([tuple(e) for e in r["book"]] == oracle_book
                  for r in child_reports)
          and peer.node.ledger.verify_chain()
          and all(r["chain_valid"] for r in child_reports))
    report = {
        "demo": f"{n_peers}-process TCP mesh convergence",
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "height": peer.node.ledger.height,
        "converged": converged,
        "oracle_match": ok,
        "chain_digest": parent_digest,
        "oracle_digest": oracle_digest,
        "elapsed_s": round(time.perf_counter() - t0, 3),
        "parent": _report(peer, transport, "parent"),
        "children": child_reports,
    }
    if verbose:
        print(json.dumps(report, indent=2))
    else:
        print(json.dumps({k: report[k] for k in
                          ("n_peers", "converged", "oracle_match",
                           "height", "elapsed_s")}))
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--demo", action="store_true",
                    help="run the N-process TCP mesh convergence demo")
    ap.add_argument("--peers", type=int, default=2,
                    help="total number of OS processes in the mesh "
                         "(parent + N-1 children; default 2)")
    ap.add_argument("--role", choices=("parent", "child"),
                    default="parent")
    ap.add_argument("--index", type=int, default=1,
                    help="(child) this worker's index in [1, peers)")
    ap.add_argument("--port", type=int, default=0,
                    help="(child) the seed's (parent's) listen port")
    ap.add_argument("--suite-seed", type=int, default=7)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="overall wall-clock bound (generous: first-run "
                         "XLA compilation of the workload kernels can "
                         "dominate)")
    ap.add_argument("--schedule", default=",".join(_SUITE_SCHEDULE),
                    help="comma-separated workload families to mine, "
                         "round-robin (default: the full heterogeneous "
                         "suite)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    schedule = tuple(f for f in args.schedule.split(",") if f)
    if args.peers < 2:
        ap.error("--peers must be >= 2")
    if args.role == "child":
        if not (1 <= args.index < args.peers):
            ap.error("--index must be in [1, peers)")
        report = asyncio.run(
            _run_child(args.index, args.port, args.peers,
                       suite_seed=args.suite_seed,
                       timeout=args.timeout, schedule=schedule))
        print(_RESULT_PREFIX + json.dumps(report), flush=True)
        return 0
    if not args.demo:
        ap.error("nothing to do: pass --demo (or --role child)")
    return asyncio.run(
        _run_parent(n_peers=args.peers, suite_seed=args.suite_seed,
                    timeout=args.timeout, verbose=args.verbose,
                    schedule=schedule))


if __name__ == "__main__":
    raise SystemExit(main())
