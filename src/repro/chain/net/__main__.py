"""``python -m repro.chain.net --demo [--peers N]`` — the N-OS-process
TCP mesh convergence oracle (DESIGN.md §13–§14, run by CI's
examples-smoke).

The parent process (worker 0) listens on an ephemeral TCP port — the
**single seed address** — and spawns N-1 child interpreters
(``--role child``).  Every child knows only the seed: it dials it,
learns the rest of the mesh from signed HELLO/ADDR gossip, and dials
the peers its ``PeerBook`` proposes until the mesh is connected.  The
N workers then mine the heterogeneous workload suite round-robin
(block ``k`` is mined by worker ``k mod N``) over real TCP with
signed compact relay.  When every worker sees every other at the
target height, children print their canonical chain digest and credit
book; the parent mines the *same* schedule on an in-process
``Network`` with the same seeds and requires all N+1 — every worker
plus the oracle — to be bit-identical.  Wall-clock is bounded by
``--timeout``.

``--chaos`` is the kill-and-restart variant (wire-level crash
recovery, DESIGN.md §15): worker 1 journals to a durable
``ChainStore`` file; when the mesh reaches the midpoint height the
parent SIGKILLs it — no goodbye, frames in flight lost — and respawns
it with ``--recover``.  The restarted process replays its journal
through ``Node.recover``, redials the seed on a fresh port, resyncs
the lost tail headers-first over TCP, and must still land on the
oracle digest.

Exit status 0 iff every chain converged AND matched the in-process
oracle.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import subprocess
import sys
import tempfile
import time

from repro.chain.net.identity import make_addr, make_identities
from repro.chain.net.peer import (_SUITE_SCHEDULE, PeerNode, _suite_node,
                                  chain_digest)
from repro.chain.net.transport import TcpTransport
from repro.chain.node import Node
from repro.chain.store import ChainStore

_RESULT_PREFIX = "RESULT "
_HOST = "127.0.0.1"


def _build_peer(idx: int, n_peers: int, *, suite_seed: int,
                store_path: str = "", recover: bool = False):
    """One worker's peer plus the shared identity list (every process
    derives the same deterministic identities, so any worker can
    reconstruct the seed's signed addr locally).  ``store_path``
    attaches a durable journal; ``recover`` replays it through
    ``Node.recover`` instead of starting at genesis — the restarted
    half of the ``--chaos`` demo.

    Liveness windows are generous on real TCP: synchronous mining and
    first-run XLA compilation can stall a worker's event loop for tens
    of seconds, and a spurious keepalive drop just forces a redial."""
    identities, ring = make_identities(n_peers)
    if recover:
        shell = _suite_node(idx, suite_seed=suite_seed, keyring=ring)
        node = Node.recover(ChainStore(store_path), node=shell)
    else:
        node = _suite_node(idx, suite_seed=suite_seed, keyring=ring,
                           store=ChainStore(store_path) if store_path
                           else None)
    peer = PeerNode(node, identities[idx], ring, compact=True,
                    max_peers=2 * n_peers,
                    request_timeout=10.0, ping_interval=15.0,
                    keepalive_timeout=120.0)
    return peer, identities


async def _dial_round(peer: PeerNode, transport: TcpTransport) -> int:
    """Dial every candidate the PeerBook proposes right now."""
    dialed = 0
    for cand in list(peer.dial_candidates()):
        peer.note_dialing(cand.node_id)
        try:
            conn = await transport.connect(cand.host, cand.port,
                                           retries=3, backoff=0.1)
        except ConnectionError:
            peer.note_dial_failed(cand.node_id)
            continue
        peer.on_dialed(conn, cand)
        dialed += 1
    if dialed:
        await transport.drain()
    return dialed


async def _mine_loop(peer: PeerNode, transport: TcpTransport, idx: int,
                     n_peers: int, schedule, deadline: float) -> None:
    """Round-robin over TCP: mine when the tip height is ours, else let
    the reader tasks advance the chain.  Between turns, dial whatever
    the PeerBook has discovered.  After reaching the target, keep
    serving body fetches until every known peer reports the target
    height too (their last blocks may still need our bodies)."""
    loop = asyncio.get_running_loop()
    target = len(schedule)
    last_hello = 0.0
    last_height = -1
    while True:
        if loop.time() > deadline:
            raise TimeoutError(
                f"peer {idx} stuck at height {peer.node.ledger.height} "
                f"knowing {sorted(peer.known_heights().items())}")
        await _dial_round(peer, transport)
        h = peer.node.ledger.height
        if h != last_height:
            # announce every height change at once: a chain pull can
            # jump several heights in one event, and the peers must see
            # the final height before we are allowed to exit — a timer
            # alone races with shutdown
            last_height = h
            last_hello = loop.time()
            peer.broadcast_hello()
            await transport.drain()
        heights = peer.known_heights()
        if (h >= target and len(heights) >= n_peers - 1
                and all(v >= target for v in heights.values())):
            peer.broadcast_hello()       # parting beacon: peers exit too
            await transport.drain()
            return
        now = loop.time()
        if now - last_hello > 0.2:
            last_hello = now
            peer.broadcast_hello()       # height beacon + resync trigger
            await transport.drain()
        # liveness sweep: expire stalled pulls (a killed peer's requests
        # fail over), ping idle conns, drop the silent ones
        peer.tick()
        await transport.drain()
        if h < target and h % n_peers == idx:
            peer.mine_and_announce(schedule[h])
            await transport.drain()
        else:
            await asyncio.sleep(0.02)


def _report(peer: PeerNode, transport: TcpTransport, role: str) -> dict:
    out = {
        "role": role,
        "height": peer.node.ledger.height,
        "chain_digest": chain_digest(peer.node),
        "book": sorted(peer.node.book.balances.items()),
        "chain_valid": peer.node.ledger.verify_chain(),
        "known_ids": sorted(peer.known_heights()),
        "n_conns": len(transport.peer_names()),
        "stats": peer.stats.to_dict(),
        "wire": transport.stats.to_dict(),
    }
    rec = getattr(peer.node, "last_recovery", None)
    if rec is not None:
        out["recovered"] = {"replayed": rec.replayed,
                            "adopted_height": rec.adopted_height,
                            "truncated_records": rec.truncated_records,
                            "resynced_height": rec.resynced_height}
    return out


async def _kill_and_respawn(peer: PeerNode, children: list, child_args,
                            mid: int, deadline: float,
                            verbose: bool) -> dict:
    """The --chaos fault: SIGKILL worker 1 once the parent's chain
    reaches the midpoint height, then respawn it with ``--recover``.
    The journal file survives the kill; everything else — sockets,
    conns, in-flight frames — dies with the process."""
    loop = asyncio.get_running_loop()
    while peer.node.ledger.height < mid:
        if loop.time() > deadline:
            return {"killed": False, "reason": "deadline before midpoint"}
        await asyncio.sleep(0.05)
    proc = children[0]                     # worker 1 is children[0]
    proc.kill()
    out, _ = await loop.run_in_executor(
        None, lambda: proc.communicate(timeout=30))
    if verbose and out:
        print(f"--- killed child output ---\n{out}", file=sys.stderr)
    children[0] = subprocess.Popen(
        child_args + ["--recover"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env=dict(os.environ))
    return {"killed": True, "killed_at_height": peer.node.ledger.height,
            "respawned_pid": children[0].pid}


async def _run_child(idx: int, seed_port: int, n_peers: int, *,
                     suite_seed: int, timeout: float, schedule,
                     store_path: str = "", recover: bool = False) -> dict:
    peer, identities = _build_peer(idx, n_peers, suite_seed=suite_seed,
                                   store_path=store_path, recover=recover)
    transport = TcpTransport()
    peer.attach(transport)
    own_port = await transport.listen(_HOST)
    peer.addr = make_addr(identities[idx], _HOST, own_port)
    # single-seed bootstrap: the only address a child starts with is
    # worker 0's (its signed record is reconstructible — identities
    # are deterministic — so it enters the tried bucket like any dial)
    seed_addr = make_addr(identities[0], _HOST, seed_port)
    peer.note_dialing(0)
    conn = await transport.connect(_HOST, seed_port)
    peer.on_dialed(conn, seed_addr)
    deadline = asyncio.get_running_loop().time() + timeout
    await _mine_loop(peer, transport, idx, n_peers, schedule, deadline)
    await transport.drain()
    report = _report(peer, transport, f"child{idx}")
    # linger a moment so late body fetches from slower peers still land
    await asyncio.sleep(0.3)
    await transport.close()
    return report


async def _run_parent(*, n_peers: int, suite_seed: int, timeout: float,
                      verbose: bool, schedule,
                      chaos: bool = False) -> int:
    t0 = time.perf_counter()
    peer, identities = _build_peer(0, n_peers, suite_seed=suite_seed)
    transport = TcpTransport()
    peer.attach(transport)
    port = await transport.listen(_HOST)
    peer.addr = make_addr(identities[0], _HOST, port)
    chaos_dir = tempfile.mkdtemp(prefix="pnp-chaos-") if chaos else None

    def _args_for(i: int) -> list:
        out = [sys.executable, "-m", "repro.chain.net", "--role", "child",
               "--index", str(i), "--port", str(port),
               "--peers", str(n_peers), "--suite-seed", str(suite_seed),
               "--timeout", str(timeout), "--schedule", ",".join(schedule)]
        if chaos and i == 1:
            out += ["--store", os.path.join(chaos_dir, "worker1.journal")]
        return out

    children = [
        subprocess.Popen(_args_for(i),
                         stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                         text=True, env=dict(os.environ))
        for i in range(1, n_peers)]
    outputs = []
    fault: dict = {}
    try:
        deadline = asyncio.get_running_loop().time() + timeout
        kill_task = None
        if chaos:
            kill_task = asyncio.create_task(_kill_and_respawn(
                peer, children, _args_for(1),
                mid=max(1, len(schedule) // 2), deadline=deadline,
                verbose=verbose))
        await _mine_loop(peer, transport, 0, n_peers, schedule, deadline)
        await transport.drain()
        if kill_task is not None:
            fault = await kill_task
        for child in children:
            out, _ = await asyncio.get_running_loop().run_in_executor(
                None, lambda c=child: c.communicate(timeout=timeout))
            outputs.append(out)
    except BaseException:
        for child in children:
            if child.poll() is None:
                child.kill()
            try:
                dump, _ = child.communicate(timeout=10)
                print(f"--- child output ---\n{dump}", file=sys.stderr)
            except Exception:
                pass
        raise
    finally:
        for child in children:
            if child.poll() is None:
                child.kill()
        await transport.close()
        if chaos_dir is not None:
            import shutil
            shutil.rmtree(chaos_dir, ignore_errors=True)
    child_reports = []
    for out in outputs:
        found = None
        for line in (out or "").splitlines():
            if line.startswith(_RESULT_PREFIX):
                found = json.loads(line[len(_RESULT_PREFIX):])
        if found is None:
            print(out or "", file=sys.stderr)
            print("FAIL: a child produced no RESULT line", file=sys.stderr)
            return 1
        child_reports.append(found)

    # the in-process oracle: same seeds, same schedule, one interpreter
    from repro.chain.network import Network
    oracle_ids, ring = make_identities(n_peers)
    net = Network.create(
        n_peers, node_factory=lambda i: _suite_node(
            i, suite_seed=suite_seed, keyring=ring),
        identities=oracle_ids)
    net.run(len(schedule), list(schedule))
    oracle_digest = chain_digest(net.nodes[0])
    oracle_book = sorted(net.nodes[0].book.balances.items())

    parent_digest = chain_digest(peer.node)
    parent_book = sorted(peer.node.book.balances.items())
    converged = all(r["chain_digest"] == parent_digest
                    for r in child_reports)
    ok = (converged and parent_digest == oracle_digest
          and parent_book == oracle_book
          and all([tuple(e) for e in r["book"]] == oracle_book
                  for r in child_reports)
          and peer.node.ledger.verify_chain()
          and all(r["chain_valid"] for r in child_reports))
    if chaos:
        # the fault must actually have fired, and the respawned worker
        # must have come back through Node.recover, not from genesis
        ok = (ok and bool(fault.get("killed"))
              and child_reports[0].get("recovered") is not None)
    report = {
        "demo": (f"{n_peers}-process TCP mesh "
                 + ("kill-and-restart recovery" if chaos
                    else "convergence")),
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "height": peer.node.ledger.height,
        "converged": converged,
        "oracle_match": ok,
        "chain_digest": parent_digest,
        "oracle_digest": oracle_digest,
        "elapsed_s": round(time.perf_counter() - t0, 3),
        "parent": _report(peer, transport, "parent"),
        "children": child_reports,
    }
    if chaos:
        report["fault"] = fault
        report["recovered"] = child_reports[0].get("recovered")
    if verbose:
        print(json.dumps(report, indent=2))
    else:
        brief = {k: report[k] for k in
                 ("n_peers", "converged", "oracle_match",
                  "height", "elapsed_s")}
        if chaos:
            brief["fault"] = fault
            brief["recovered"] = report["recovered"]
        print(json.dumps(brief))
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--demo", action="store_true",
                    help="run the N-process TCP mesh convergence demo")
    ap.add_argument("--peers", type=int, default=2,
                    help="total number of OS processes in the mesh "
                         "(parent + N-1 children; default 2)")
    ap.add_argument("--role", choices=("parent", "child"),
                    default="parent")
    ap.add_argument("--index", type=int, default=1,
                    help="(child) this worker's index in [1, peers)")
    ap.add_argument("--port", type=int, default=0,
                    help="(child) the seed's (parent's) listen port")
    ap.add_argument("--suite-seed", type=int, default=7)
    ap.add_argument("--chaos", action="store_true",
                    help="kill-and-restart variant: SIGKILL worker 1 at "
                         "the midpoint height, respawn it with --recover "
                         "(its journal survives), require oracle parity "
                         "anyway")
    ap.add_argument("--store", default="",
                    help="(child) journal the chain to this file")
    ap.add_argument("--recover", action="store_true",
                    help="(child) replay --store through Node.recover "
                         "before joining the mesh")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="overall wall-clock bound (generous: first-run "
                         "XLA compilation of the workload kernels can "
                         "dominate)")
    ap.add_argument("--schedule", default=",".join(_SUITE_SCHEDULE),
                    help="comma-separated workload families to mine, "
                         "round-robin (default: the full heterogeneous "
                         "suite)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    schedule = tuple(f for f in args.schedule.split(",") if f)
    if args.peers < 2:
        ap.error("--peers must be >= 2")
    if args.role == "child":
        if not (1 <= args.index < args.peers):
            ap.error("--index must be in [1, peers)")
        if args.recover and not args.store:
            ap.error("--recover needs --store")
        report = asyncio.run(
            _run_child(args.index, args.port, args.peers,
                       suite_seed=args.suite_seed,
                       timeout=args.timeout, schedule=schedule,
                       store_path=args.store, recover=args.recover))
        print(_RESULT_PREFIX + json.dumps(report), flush=True)
        return 0
    if not args.demo:
        ap.error("nothing to do: pass --demo (or --role child)")
    return asyncio.run(
        _run_parent(n_peers=args.peers, suite_seed=args.suite_seed,
                    timeout=args.timeout, verbose=args.verbose,
                    schedule=schedule, chaos=args.chaos))


if __name__ == "__main__":
    raise SystemExit(main())
