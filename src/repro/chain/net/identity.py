"""``repro.chain.net.identity`` — signed peer identities.

A peer is its keypair: the peer id is the SHA-256 hash of the Ed25519
public key, and every block ANNOUNCE carries an origin signature so
``BlockPayload.origin`` is *cryptographically bound* to the key that
mined the block instead of trusted from the transport (the in-process
``Network`` passed the sender index as a stand-in — DESIGN.md §13).

Ed25519 is implemented here from RFC 8032 directly on ``hashlib`` —
the container has no third-party crypto package, and the reference
scalar arithmetic is ~80 lines of bigint math.  It is the *slow*
textbook implementation (no constant-time guarantees, ~ms per
operation); that is fine for a research chain signing one announce per
block, and it is bit-compatible with any standard Ed25519 verifier.

Trust model: the ``KeyRing`` (node id -> public key) is distributed
out of band, like the genesis block — consensus membership is not
negotiated over the wire.  ``Hello`` introduces a peer's key but never
*registers* it; a signature only counts if it verifies under the key
the ring already holds for the claimed origin.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import struct
from typing import Dict, Iterable, Optional, Tuple

from repro.chain.store import encode_block, encode_payload, payload_checksum
from repro.chain.workload import BlockPayload
from repro.core.ledger import Block

__all__ = [
    "KeyRing",
    "PeerAddr",
    "PeerIdentity",
    "SignedAnnounce",
    "ed25519_public_key",
    "ed25519_sign",
    "ed25519_verify",
    "make_addr",
    "make_announce",
    "make_identities",
]

# ---------------------------------------------------------------------------
# RFC 8032 Ed25519 on stdlib hashlib (reference/slow implementation)
# ---------------------------------------------------------------------------

_P = 2 ** 255 - 19
_L = 2 ** 252 + 27742317777372353535851937790883648493
_D = (-121665 * pow(121666, _P - 2, _P)) % _P
_SQRT_M1 = pow(2, (_P - 1) // 4, _P)

# extended homogeneous coordinates (X, Y, Z, T), T = XY/Z
_Pt = Tuple[int, int, int, int]
_NEUTRAL: _Pt = (0, 1, 1, 0)


def _pt_add(p: _Pt, q: _Pt) -> _Pt:
    # add-2008-hwcd-3: complete (works for doubling too)
    x1, y1, z1, t1 = p
    x2, y2, z2, t2 = q
    a = (y1 - x1) * (y2 - x2) % _P
    b = (y1 + x1) * (y2 + x2) % _P
    c = 2 * t1 * t2 * _D % _P
    d = 2 * z1 * z2 % _P
    e, f, g, h = b - a, d - c, d + c, b + a
    return (e * f % _P, g * h % _P, f * g % _P, e * h % _P)


def _pt_mul(s: int, p: _Pt) -> _Pt:
    q = _NEUTRAL
    while s:
        if s & 1:
            q = _pt_add(q, p)
        p = _pt_add(p, p)
        s >>= 1
    return q


def _pt_eq(p: _Pt, q: _Pt) -> bool:
    # cross-multiply out the projective denominators
    return ((p[0] * q[2] - q[0] * p[2]) % _P == 0
            and (p[1] * q[2] - q[1] * p[2]) % _P == 0)


def _x_from_y(y: int, sign: int) -> Optional[int]:
    xx = (y * y - 1) * pow(_D * y * y + 1, _P - 2, _P) % _P
    x = pow(xx, (_P + 3) // 8, _P)
    if (x * x - xx) % _P != 0:
        x = x * _SQRT_M1 % _P
    if (x * x - xx) % _P != 0:
        return None
    if x == 0 and sign:
        return None
    if x % 2 != sign:
        x = _P - x
    return x


_BY = 4 * pow(5, _P - 2, _P) % _P
_BX = _x_from_y(_BY, 0)
_B: _Pt = (_BX, _BY, 1, _BX * _BY % _P)


def _pt_compress(p: _Pt) -> bytes:
    zi = pow(p[2], _P - 2, _P)
    x = p[0] * zi % _P
    y = p[1] * zi % _P
    return int.to_bytes(y | ((x & 1) << 255), 32, "little")


def _pt_decompress(s: bytes) -> Optional[_Pt]:
    if len(s) != 32:
        return None
    n = int.from_bytes(s, "little")
    sign = n >> 255
    y = n & ((1 << 255) - 1)
    if y >= _P:
        return None
    x = _x_from_y(y, sign)
    if x is None:
        return None
    return (x, y, 1, x * y % _P)


def _sha512(*parts: bytes) -> bytes:
    h = hashlib.sha512()
    for part in parts:
        h.update(part)
    return h.digest()


def _clamp(b: bytes) -> int:
    a = int.from_bytes(b, "little")
    a &= (1 << 254) - 8
    a |= 1 << 254
    return a


def ed25519_public_key(seed: bytes) -> bytes:
    """The 32-byte public key of a 32-byte private seed (RFC 8032)."""
    if len(seed) != 32:
        raise ValueError(f"Ed25519 seed must be 32 bytes, got {len(seed)}")
    a = _clamp(_sha512(seed)[:32])
    return _pt_compress(_pt_mul(a, _B))


def ed25519_sign(seed: bytes, message: bytes) -> bytes:
    """Sign ``message`` with the key derived from ``seed`` -> 64 bytes."""
    h = _sha512(seed)
    a = _clamp(h[:32])
    pub = _pt_compress(_pt_mul(a, _B))
    r = int.from_bytes(_sha512(h[32:], message), "little") % _L
    big_r = _pt_compress(_pt_mul(r, _B))
    k = int.from_bytes(_sha512(big_r, pub, message), "little") % _L
    s = (r + k * a) % _L
    return big_r + s.to_bytes(32, "little")


def ed25519_verify(pubkey: bytes, message: bytes, signature: bytes) -> bool:
    """True iff ``signature`` is a valid Ed25519 signature of
    ``message`` under ``pubkey``.  Never raises — malformed keys,
    non-canonical scalars, and off-curve points all return False."""
    if len(signature) != 64 or len(pubkey) != 32:
        return False
    a = _pt_decompress(pubkey)
    big_r = _pt_decompress(signature[:32])
    if a is None or big_r is None:
        return False
    s = int.from_bytes(signature[32:], "little")
    if s >= _L:
        return False
    k = int.from_bytes(_sha512(signature[:32], pubkey, message),
                       "little") % _L
    return _pt_eq(_pt_mul(s, _B), _pt_add(big_r, _pt_mul(k, a)))


# ---------------------------------------------------------------------------
# identities and the key ring
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PeerIdentity:
    """One peer's keypair.  ``peer_id`` (the wire-level name) is the
    hex SHA-256 hash of the public key — knowing an id proves nothing,
    producing a signature that verifies under its preimage does."""
    node_id: int
    seed: bytes
    pubkey: bytes

    @classmethod
    def generate(cls, node_id: int) -> "PeerIdentity":
        seed = os.urandom(32)
        return cls(node_id=node_id, seed=seed,
                   pubkey=ed25519_public_key(seed))

    @classmethod
    def from_seed(cls, node_id: int, seed) -> "PeerIdentity":
        """Deterministic identity for tests, sims, and the two-process
        demo (both processes derive the same ring without exchanging
        keys).  ``seed`` is 32 bytes or an int expanded through
        SHA-256.  Deterministic seeds are a *fixture*, not security."""
        if isinstance(seed, int):
            seed = hashlib.sha256(
                b"pnpcoin-peer-seed|" + struct.pack("<q", seed)).digest()
        if len(seed) != 32:
            raise ValueError(f"seed must be 32 bytes, got {len(seed)}")
        return cls(node_id=node_id, seed=seed,
                   pubkey=ed25519_public_key(seed))

    @property
    def peer_id(self) -> str:
        return hashlib.sha256(self.pubkey).hexdigest()

    def sign(self, message: bytes) -> bytes:
        return ed25519_sign(self.seed, message)


class KeyRing:
    """Out-of-band registry: node id -> Ed25519 public key.  A
    signature binds an origin only if it verifies under the key the
    ring holds for that origin — an unknown origin never verifies."""

    def __init__(self, keys: Optional[Dict[int, bytes]] = None) -> None:
        self._keys: Dict[int, bytes] = dict(keys or {})

    @classmethod
    def of(cls, identities: Iterable[PeerIdentity]) -> "KeyRing":
        return cls({i.node_id: i.pubkey for i in identities})

    def register(self, node_id: int, pubkey: bytes) -> None:
        have = self._keys.get(node_id)
        if have is not None and have != pubkey:
            raise ValueError(
                f"node {node_id} already registered with a different key")
        self._keys[node_id] = pubkey

    def pubkey_of(self, node_id: int) -> Optional[bytes]:
        return self._keys.get(node_id)

    def __contains__(self, node_id: int) -> bool:
        return node_id in self._keys

    def __len__(self) -> int:
        return len(self._keys)


def make_identities(n: int, *, seed: int = 0
                    ) -> Tuple[Dict[int, PeerIdentity], KeyRing]:
    """``n`` deterministic identities (node ids ``0..n-1``) plus the
    ring holding all their public keys — the test/demo fixture for a
    closed consensus group."""
    ids = {i: PeerIdentity.from_seed(i, seed * 1_000_003 + i)
           for i in range(n)}
    return ids, KeyRing.of(ids.values())


# ---------------------------------------------------------------------------
# self-signed peer addresses (the discovery gossip payload)
# ---------------------------------------------------------------------------

_ADDR_DOMAIN = b"PNPADDR1"
MAX_HOST_LEN = 255


def well_formed_endpoint(host: str, port: int) -> bool:
    """The structural rule every wire-carried endpoint obeys — shared
    by ``PeerAddr`` records and HELLO's observed-address echoes: a
    printable-ASCII host of bounded length and a real port number."""
    return (isinstance(host, str) and isinstance(port, int)
            and 0 < port < 65536
            and 0 < len(host) <= MAX_HOST_LEN
            and all(33 <= ord(c) < 127 for c in host))


def _addr_message(node_id: int, host: str, port: int) -> bytes:
    return (_ADDR_DOMAIN + struct.pack("<q", node_id)
            + struct.pack("<I", port) + host.encode("utf-8"))


@dataclasses.dataclass(frozen=True)
class PeerAddr:
    """A self-signed endpoint claim: "node ``node_id`` is reachable at
    ``host:port``", signed by the node's own key.  Addr gossip relays
    these records verbatim — a peer cannot fabricate an endpoint for
    somebody else's identity, so a hostile relay can redirect *its own*
    traffic but never poison the ``PeerBook`` mapping for an honest
    node.  ``verify`` is the admission rule: structural sanity, the
    signature under the carried key, and (when a ``KeyRing`` is
    given) that the carried key IS the ring's key for the claimed id."""
    node_id: int
    host: str
    port: int
    pubkey: bytes
    signature: bytes

    def well_formed(self) -> bool:
        """Structural sanity only (no crypto): field shapes a decoder
        or book must refuse regardless of signatures."""
        return (len(self.pubkey) == 32 and len(self.signature) == 64
                and well_formed_endpoint(self.host, self.port))

    def verify(self, keyring: Optional["KeyRing"] = None) -> bool:
        """True iff this addr may enter a ``PeerBook``: well-formed,
        self-signed under the carried key, and — with a ring — the
        carried key matches the ring's key for ``node_id`` (an unknown
        or mismatched identity never verifies)."""
        if not self.well_formed():
            return False
        if keyring is not None:
            expected = keyring.pubkey_of(self.node_id)
            if expected is None or expected != self.pubkey:
                return False
        return ed25519_verify(
            self.pubkey,
            _addr_message(self.node_id, self.host, self.port),
            self.signature)

    @property
    def endpoint(self) -> Tuple[str, int]:
        return (self.host, self.port)


def make_addr(identity: PeerIdentity, host: str, port: int) -> PeerAddr:
    """Self-sign this identity's reachable endpoint (what its HELLO
    carries and addr gossip relays)."""
    return PeerAddr(
        node_id=identity.node_id, host=host, port=port,
        pubkey=identity.pubkey,
        signature=identity.sign(
            _addr_message(identity.node_id, host, port)))


# ---------------------------------------------------------------------------
# origin-signed block announces
# ---------------------------------------------------------------------------

_ANN_DOMAIN = b"PNPANN1"


def _announce_message(origin: int, header: bytes, checksum: bytes) -> bytes:
    # domain-separated; the header is hashed so the signed message stays
    # fixed-size however large the block header grows
    return (_ANN_DOMAIN + struct.pack("<q", origin)
            + hashlib.sha256(header).digest() + checksum)


@dataclasses.dataclass(frozen=True)
class SignedAnnounce:
    """The authenticated core of a block announce: the canonical header
    bytes, the payload body checksum (its content address), the claimed
    origin, and the origin's signature over all three.  ``verify`` is
    the one origin-binding rule both the in-process ``Network`` and
    ``PeerNode`` enforce (``Node.receive`` calls it when the node holds
    a ``keyring``)."""
    header: bytes            # encode_block(block)
    checksum: bytes          # payload_checksum(payload), 16 bytes
    origin: int
    pubkey: bytes
    signature: bytes

    def verify_origin(self, keyring: KeyRing) -> bool:
        """Signature + ring check only (no body needed): the announce
        is signed by the key the ring holds for its claimed origin."""
        expected = keyring.pubkey_of(self.origin)
        if expected is None or expected != self.pubkey:
            return False
        return ed25519_verify(
            self.pubkey,
            _announce_message(self.origin, self.header, self.checksum),
            self.signature)

    def verify(self, keyring: KeyRing, block: Block,
               payload: BlockPayload) -> bool:
        """Full origin binding for a concrete (block, payload) pair:
        the signed header is *this* block, the signed checksum is
        *this* payload's canonical encoding, the payload claims the
        signing origin, and the signature verifies under the ring's
        key for that origin."""
        if payload.origin != self.origin:
            return False
        if self.header != encode_block(block):
            return False
        if self.checksum != payload_checksum(payload):
            return False
        return self.verify_origin(keyring)


def make_announce(identity: PeerIdentity, block: Block,
                  payload: BlockPayload) -> SignedAnnounce:
    """Sign a freshly mined block: binds (header, payload checksum,
    origin) under the miner's key.  Relayers pass the announce along
    unchanged — re-signing would break the origin binding."""
    header = encode_block(block)
    checksum = payload_checksum(payload)
    return SignedAnnounce(
        header=header, checksum=checksum, origin=identity.node_id,
        pubkey=identity.pubkey,
        signature=identity.sign(
            _announce_message(identity.node_id, header, checksum)))


def _encode_payload_body(payload: BlockPayload) -> bytes:
    """Canonical wire body of a payload (alias kept next to
    ``payload_checksum`` so the pair reads as one content-address
    scheme)."""
    return encode_payload(payload)
