"""``repro.chain.net.messages`` — the typed, versioned wire catalogue.

Nine message types carry the whole peer protocol (DESIGN.md §13–15):

    HELLO        version, node id, pubkey, chain height (introduction
                 + liveness beacon) + an optional self-signed listen
                 address (``PeerAddr``) — the discovery bootstrap —
                 and the remote endpoint the sender *observed* for the
                 receiver (how a NATed peer learns a routable
                 self-addr before signing its own ``PeerAddr``)
    ADDR         peer discovery gossip: a capped list of self-signed
                 ``PeerAddr`` records relayed verbatim (a relay cannot
                 forge an endpoint for someone else's identity)
    ANNOUNCE     compact block relay: canonical header bytes + payload
                 body checksum + the origin's signature; ``body`` is
                 optionally inlined (full-body relay, the baseline the
                 ``wire_relay`` bench compares against)
    GET_HEADERS  chain pull: give me your headers from a height
    TIP          the reply: (header bytes, body checksum) per height
    GET_BODIES   fetch payload bodies by content checksum
    BODIES       the bodies (canonical ``encode_payload`` bytes)
    PING         keepalive probe with an echo nonce (DESIGN §15): a
                 peer silent past the keepalive window is disconnected
    PONG         the echo — proof the peer is still processing frames

Framing reuses the journal's discipline (``chain/store.py``)::

    magic "PNPW" | u8 msgtype | u32 body_len (LE) | body | sha256[:16]

with two wire-specific hardenings: the checksum covers ``msgtype`` as
well as the body (a flipped type byte must not re-frame one message as
another), and a per-frame magic gives the stream decoder a resync
point after damage.  Bodies are encoded with the same ``_W``/``_R``
canonical primitives the journal uses; block headers and payloads
travel as ``encode_block``/``encode_payload`` bytes verbatim.

Decoding **never raises** — ``decode_message`` returns ``None`` for
anything damaged, and ``FrameBuffer`` (the stream reassembler behind
the TCP transport) quarantines malformed frames and rescans for the
next magic instead of dying, exactly the ``read_chain`` truncate-not-
crash contract.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Callable, Dict, List, Optional, Tuple, Union

# the journal's canonical encoding primitives ARE the wire body format
# (one encoding discipline across disk and wire, by design)
from repro.chain.net.identity import (MAX_HOST_LEN, PeerAddr,
                                      well_formed_endpoint)
from repro.chain.store import _Corrupt, _R, _W
from repro.chain.workload import ChainError

__all__ = [
    "Addr",
    "Announce",
    "Bodies",
    "FrameBuffer",
    "GetBodies",
    "GetHeaders",
    "Hello",
    "MAX_ADDRS",
    "MAX_BODY",
    "PROTOCOL_VERSION",
    "Ping",
    "Pong",
    "Tip",
    "WIRE_MAGIC",
    "decode_message",
    "encode_message",
]

# v2: HELLO carries an optional PeerAddr; v3: PING/PONG keepalive +
# HELLO echoes the observed remote endpoint
PROTOCOL_VERSION = 3
WIRE_MAGIC = b"PNPW"
MAX_BODY = 1 << 27            # 128 MiB: anything larger is damage/abuse
CHECKSUM_LEN = 16
MAX_ADDRS = 32                # per ADDR message: more is abuse

MSG_HELLO = 1
MSG_ANNOUNCE = 2
MSG_GET_HEADERS = 3
MSG_TIP = 4
MSG_GET_BODIES = 5
MSG_BODIES = 6
MSG_ADDR = 7
MSG_PING = 8
MSG_PONG = 9

_U8 = struct.Struct("<B")
_U32 = struct.Struct("<I")
_HEAD_LEN = len(WIRE_MAGIC) + 1 + 4      # magic | msgtype | body_len


@dataclasses.dataclass(frozen=True)
class Hello:
    """Introduction + liveness beacon: who I am (claimed — only a
    signature proves it), which protocol I speak, how tall my chain
    is.  A peer at a greater height is a sync trigger.  ``addr`` is
    the sender's self-signed listen endpoint (``identity.PeerAddr``)
    — how a node bootstrapped from one seed address becomes
    discoverable by the whole mesh; ``None`` for unreachable peers.
    ``observed`` is the (host, port) the *sender* saw this connection
    arrive from — observed-address feedback: a NATed receiver with no
    configured self-addr collects these echoes and, once enough
    distinct peers agree, signs the consensus endpoint as its own
    ``PeerAddr`` (a single lying peer cannot steer it)."""
    version: int
    node_id: int
    pubkey: bytes
    height: int
    addr: Optional[PeerAddr] = None
    observed: Optional[Tuple[str, int]] = None


@dataclasses.dataclass(frozen=True)
class Addr:
    """Peer-discovery gossip: self-signed ``PeerAddr`` records relayed
    verbatim (re-signing would let relays forge endpoints).  Capped at
    ``MAX_ADDRS`` per message — a longer list never decodes."""
    addrs: Tuple[PeerAddr, ...]


@dataclasses.dataclass(frozen=True)
class Announce:
    """Compact relay of one block: canonical header bytes, the payload
    body's content checksum, and the origin's signature binding both
    to ``origin`` (see ``identity.SignedAnnounce``).  ``body`` is
    ``None`` in compact mode — receivers fetch it by checksum only if
    they don't already hold it — or inlined for full-body relay."""
    header: bytes
    checksum: bytes
    origin: int
    pubkey: bytes
    signature: bytes
    body: Optional[bytes] = None


@dataclasses.dataclass(frozen=True)
class GetHeaders:
    from_height: int


@dataclasses.dataclass(frozen=True)
class Tip:
    """Chain-pull reply: ``entries[i]`` is (canonical header bytes,
    payload body checksum) for height ``start + i`` up to the sender's
    tip.  A zero checksum means the sender pruned that body at
    finalization (the puller substitutes its own retained evidence
    below the fork point — ``Node.consider_chain``)."""
    start: int
    entries: Tuple[Tuple[bytes, bytes], ...]


@dataclasses.dataclass(frozen=True)
class GetBodies:
    checksums: Tuple[bytes, ...]


@dataclasses.dataclass(frozen=True)
class Bodies:
    bodies: Tuple[bytes, ...]


@dataclasses.dataclass(frozen=True)
class Ping:
    """Keepalive probe (DESIGN §15).  ``nonce`` is an arbitrary echo
    token: the matching ``Pong`` must return it exactly, so a pong
    cannot be replayed from an earlier probe."""
    nonce: int


@dataclasses.dataclass(frozen=True)
class Pong:
    """Keepalive echo: proof the peer decoded and processed our PING
    after we sent it — a one-sided TCP half-open or a wedged process
    cannot produce one."""
    nonce: int


Message = Union[Hello, Addr, Announce, GetHeaders, Tip, GetBodies,
                Bodies, Ping, Pong]


# -- per-type body codecs ---------------------------------------------------


def _enc_peer_addr(w: _W, a: PeerAddr) -> None:
    w.i64(a.node_id)
    w.s(a.host)
    w.u32(a.port)
    w.bstr(a.pubkey)
    w.bstr(a.signature)


def _dec_peer_addr(r: _R) -> PeerAddr:
    a = PeerAddr(node_id=r.i64(), host=r.s(), port=r.u32(),
                 pubkey=r.bstr(), signature=r.bstr())
    # structural validation at the decoder: a malformed addr is frame
    # damage, not something for the PeerBook to see
    if not a.well_formed():
        raise _Corrupt("malformed peer addr")
    return a


def _enc_endpoint(w: _W, e: Tuple[str, int]) -> None:
    w.s(e[0])
    w.u32(e[1])


def _dec_endpoint(r: _R) -> Tuple[str, int]:
    host, port = r.s(), r.u32()
    # same structural rule as PeerAddr endpoints: a malformed observed
    # endpoint is frame damage, never something the peer layer sees
    if not well_formed_endpoint(host, port):
        raise _Corrupt("malformed observed endpoint")
    return (host, port)


def _enc_hello(w: _W, m: Hello) -> None:
    w.u32(m.version)
    w.i64(m.node_id)
    w.bstr(m.pubkey)
    w.u64(m.height)
    w.opt(m.addr, lambda a: _enc_peer_addr(w, a))
    w.opt(m.observed, lambda e: _enc_endpoint(w, e))


def _dec_hello(r: _R) -> Hello:
    return Hello(version=r.u32(), node_id=r.i64(), pubkey=r.bstr(),
                 height=r.u64(), addr=r.opt(lambda: _dec_peer_addr(r)),
                 observed=r.opt(lambda: _dec_endpoint(r)))


def _enc_addr(w: _W, m: Addr) -> None:
    if len(m.addrs) > MAX_ADDRS:
        raise ChainError(
            f"addr message carries {len(m.addrs)} > {MAX_ADDRS} entries")
    w.u32(len(m.addrs))
    for a in m.addrs:
        _enc_peer_addr(w, a)


def _dec_addr(r: _R) -> Addr:
    n = r.u32()
    if n > MAX_ADDRS:
        raise _Corrupt(f"addr message claims {n} > {MAX_ADDRS} entries")
    return Addr(addrs=tuple(_dec_peer_addr(r) for _ in range(n)))


def _enc_announce(w: _W, m: Announce) -> None:
    w.bstr(m.header)
    w.bstr(m.checksum)
    w.i64(m.origin)
    w.bstr(m.pubkey)
    w.bstr(m.signature)
    w.opt(m.body, w.bstr)


def _dec_announce(r: _R) -> Announce:
    m = Announce(header=r.bstr(), checksum=r.bstr(), origin=r.i64(),
                 pubkey=r.bstr(), signature=r.bstr(),
                 body=r.opt(r.bstr))
    if len(m.checksum) != CHECKSUM_LEN:
        raise _Corrupt(f"announce checksum is {len(m.checksum)} bytes")
    return m


def _enc_get_headers(w: _W, m: GetHeaders) -> None:
    w.u64(m.from_height)


def _dec_get_headers(r: _R) -> GetHeaders:
    return GetHeaders(from_height=r.u64())


def _enc_tip(w: _W, m: Tip) -> None:
    w.u64(m.start)
    w.u32(len(m.entries))
    for header, checksum in m.entries:
        w.bstr(header)
        w.bstr(checksum)


def _dec_tip(r: _R) -> Tip:
    start = r.u64()
    n = r.u32()
    entries = []
    for _ in range(n):
        header = r.bstr()
        checksum = r.bstr()
        if len(checksum) != CHECKSUM_LEN:
            raise _Corrupt(f"tip checksum is {len(checksum)} bytes")
        entries.append((header, checksum))
    return Tip(start=start, entries=tuple(entries))


def _enc_get_bodies(w: _W, m: GetBodies) -> None:
    w.u32(len(m.checksums))
    for ck in m.checksums:
        w.bstr(ck)


def _dec_get_bodies(r: _R) -> GetBodies:
    n = r.u32()
    cks = []
    for _ in range(n):
        ck = r.bstr()
        if len(ck) != CHECKSUM_LEN:
            raise _Corrupt(f"get_bodies checksum is {len(ck)} bytes")
        cks.append(ck)
    return GetBodies(checksums=tuple(cks))


def _enc_bodies(w: _W, m: Bodies) -> None:
    w.u32(len(m.bodies))
    for body in m.bodies:
        w.bstr(body)


def _dec_bodies(r: _R) -> Bodies:
    n = r.u32()
    return Bodies(bodies=tuple(r.bstr() for _ in range(n)))


def _enc_ping(w: _W, m: Ping) -> None:
    w.u64(m.nonce)


def _dec_ping(r: _R) -> Ping:
    return Ping(nonce=r.u64())


def _enc_pong(w: _W, m: Pong) -> None:
    w.u64(m.nonce)


def _dec_pong(r: _R) -> Pong:
    return Pong(nonce=r.u64())


_CODECS: Dict[type, Tuple[int, Callable]] = {
    Hello: (MSG_HELLO, _enc_hello),
    Announce: (MSG_ANNOUNCE, _enc_announce),
    GetHeaders: (MSG_GET_HEADERS, _enc_get_headers),
    Tip: (MSG_TIP, _enc_tip),
    GetBodies: (MSG_GET_BODIES, _enc_get_bodies),
    Bodies: (MSG_BODIES, _enc_bodies),
    Addr: (MSG_ADDR, _enc_addr),
    Ping: (MSG_PING, _enc_ping),
    Pong: (MSG_PONG, _enc_pong),
}

_DECODERS: Dict[int, Callable[[_R], Message]] = {
    MSG_HELLO: _dec_hello,
    MSG_ANNOUNCE: _dec_announce,
    MSG_GET_HEADERS: _dec_get_headers,
    MSG_TIP: _dec_tip,
    MSG_GET_BODIES: _dec_get_bodies,
    MSG_BODIES: _dec_bodies,
    MSG_ADDR: _dec_addr,
    MSG_PING: _dec_ping,
    MSG_PONG: _dec_pong,
}


def _frame_checksum(msgtype: int, body: bytes) -> bytes:
    # covers the type byte too: a bit-flip in msgtype must fail the
    # frame, not re-parse the body as a different message
    return hashlib.sha256(_U8.pack(msgtype) + body).digest()[:CHECKSUM_LEN]


def encode_message(msg: Message) -> bytes:
    """One complete wire frame:
    ``magic | u8 type | u32 len | body | sha256(type|body)[:16]``."""
    try:
        msgtype, enc = _CODECS[type(msg)]
    except KeyError:
        raise ChainError(f"not a wire message: {type(msg).__name__}")
    w = _W()
    enc(w, msg)
    body = bytes(w.buf)
    return (WIRE_MAGIC + _U8.pack(msgtype) + _U32.pack(len(body))
            + body + _frame_checksum(msgtype, body))


def _decode_body(msgtype: int, body: bytes) -> Optional[Message]:
    dec = _DECODERS.get(msgtype)
    if dec is None:
        return None
    r = _R(body)
    try:
        msg = dec(r)
        r.done()
    except (_Corrupt, ChainError, struct.error, ValueError,
            OverflowError):
        return None
    return msg


def decode_message(frame: bytes) -> Optional[Message]:
    """Decode exactly one frame.  Returns ``None`` — never raises — on
    any damage: wrong magic, truncation, trailing bytes, oversized
    length, checksum mismatch, unknown type, or an undecodable body."""
    if len(frame) < _HEAD_LEN + CHECKSUM_LEN:
        return None
    if frame[:len(WIRE_MAGIC)] != WIRE_MAGIC:
        return None
    msgtype = frame[len(WIRE_MAGIC)]
    (body_len,) = _U32.unpack_from(frame, len(WIRE_MAGIC) + 1)
    if body_len > MAX_BODY:
        return None
    if len(frame) != _HEAD_LEN + body_len + CHECKSUM_LEN:
        return None
    body = frame[_HEAD_LEN:_HEAD_LEN + body_len]
    if _frame_checksum(msgtype, body) != frame[_HEAD_LEN + body_len:]:
        return None
    return _decode_body(msgtype, body)


class FrameBuffer:
    """Stream reassembler with malformed-frame quarantine (what the
    TCP transport reads through).  ``feed`` returns every complete,
    valid message and never raises: a frame that fails its checksum,
    declares an absurd length, or won't decode is *quarantined*
    (counted, dropped) and the buffer rescans from the next per-frame
    magic — so a corrupted byte costs one frame, not the connection.

    ``feed(..., eof=True)`` (connection closed) additionally treats
    any incomplete pending frame as damage and rescans the remainder,
    recovering valid frames that a lying length prefix had swallowed.
    """

    def __init__(self, *, max_body: int = MAX_BODY) -> None:
        self._buf = bytearray()
        self.max_body = max_body
        self.quarantined = 0          # damaged frames / garbage runs
        self.decoded = 0

    def pending(self) -> int:
        """Bytes buffered but not yet framed."""
        return len(self._buf)

    def _resync(self) -> bool:
        """Drop one damaged byte run: skip past the current (bad) magic
        and cut to the next one.  Returns False when no further magic
        exists (the tail keeps only a possible magic *prefix*)."""
        i = self._buf.find(WIRE_MAGIC, 1)
        if i >= 0:
            del self._buf[:i]
            return True
        self._keep_magic_tail()
        return False

    def _keep_magic_tail(self) -> None:
        # keep the longest buffer suffix that could begin a magic
        for k in range(min(len(WIRE_MAGIC) - 1, len(self._buf)), 0, -1):
            if self._buf[-k:] == WIRE_MAGIC[:k]:
                del self._buf[:-k]
                return
        self._buf.clear()

    def feed(self, data: bytes = b"", *, eof: bool = False
             ) -> List[Message]:
        self._buf += data
        out: List[Message] = []
        self._drain(out)
        if eof:
            # connection closed: whatever is left is damage, but a
            # lying length prefix may have swallowed complete valid
            # frames — force past the head magic and re-drain until
            # nothing remains (each resync drops >= 1 byte, so this
            # terminates)
            while self._buf:
                self.quarantined += 1
                if not self._resync():
                    self._buf.clear()
                    break
                self._drain(out)
        return out

    def _drain(self, out: List[Message]) -> None:
        """Consume every complete frame at the buffer head; stop at the
        first incomplete one (or a magic-prefix tail) to wait for more
        bytes."""
        while True:
            buf = self._buf
            if not buf:
                return
            head = bytes(buf[:len(WIRE_MAGIC)])
            if not WIRE_MAGIC.startswith(head):
                # garbage at the head: one quarantine event per run
                self.quarantined += 1
                if not self._resync():
                    return
                continue
            if len(buf) < _HEAD_LEN:
                return                      # plausible prefix: wait
            msgtype = buf[len(WIRE_MAGIC)]
            (body_len,) = _U32.unpack_from(buf, len(WIRE_MAGIC) + 1)
            if body_len > self.max_body:
                self.quarantined += 1
                if not self._resync():
                    return
                continue
            total = _HEAD_LEN + body_len + CHECKSUM_LEN
            if len(buf) < total:
                return                      # wait for the rest
            body = bytes(buf[_HEAD_LEN:_HEAD_LEN + body_len])
            check = bytes(buf[_HEAD_LEN + body_len:total])
            if _frame_checksum(msgtype, body) != check:
                self.quarantined += 1
                if not self._resync():
                    return
                continue
            msg = _decode_body(msgtype, body)
            del self._buf[:total]           # frame consumed either way
            if msg is None:
                self.quarantined += 1       # well-framed, undecodable
            else:
                self.decoded += 1
                out.append(msg)
