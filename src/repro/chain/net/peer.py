"""``repro.chain.net.peer`` — ``PeerNode``: an unmodified ``Node``
driven over a wire.

``PeerNode`` is sans-IO protocol logic: it consumes typed messages
from any transport port (loopback or TCP — ``attach`` wires the
callback) and sends replies through the same port.  The consensus
object underneath is a stock ``Node`` — nothing about mining,
verification, fork choice, finality, or the journal changes when a
node goes out-of-process; that is the whole point of the oracle test
(wire-connected peers must reconverge bit-identically with the
in-process ``Network``).

Compact relay (BIP-152 shaped, DESIGN.md §13): a freshly mined block
is announced as *header + payload content checksum + origin
signature*.  A receiver that already holds the body (from an earlier
announce, a sync, or its own chain evidence) commits without fetching
— already-seen payloads never cross the wire twice; otherwise it
fetches the body by checksum (``GET_BODIES``/``BODIES``, served from
the announcer's body store with a fallback scan over its journal/
evidence payloads).  An announce that does not extend the local tip
triggers a chain pull (``GET_HEADERS``/``TIP``) and ``Node.
consider_chain`` fork choice, substituting locally held bodies per
checksum so only the genuinely missing ones are transferred.

``loopback_scenario`` is the N-peer deterministic convergence harness
(the sim CLI's ``--scenario wire`` and the ``wire_relay`` bench run
it); the two-OS-process TCP flavor lives in ``__main__``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.chain.net.identity import (KeyRing, PeerAddr, PeerIdentity,
                                      SignedAnnounce, make_addr,
                                      make_announce, make_identities)
from repro.chain.net.messages import (MAX_ADDRS, PROTOCOL_VERSION, Addr,
                                      Announce, Bodies, GetBodies,
                                      GetHeaders, Hello, Message, Tip)
from repro.chain.net.peerbook import (BAN_THRESHOLD, PeerBook, PeerScore,
                                      TokenBucket, eviction_order)
from repro.chain.net.transport import LoopbackHub
from repro.chain.node import BlockReceipt, Node
from repro.chain.store import (collect_jash_fns, decode_block, decode_payload,
                               encode_block, encode_payload,
                               payload_checksum)
from repro.chain.workload import BlockPayload, ChainError
from repro.core.ledger import Block

__all__ = [
    "PeerNode",
    "PeerStats",
    "chain_digest",
    "loopback_scenario",
    "mesh_scenario",
]

_ZERO_CK = b"\x00" * 16          # "body pruned at finalization" sentinel


def chain_digest(node: Node) -> str:
    """Canonical digest of a node's whole chain: SHA-256 over the
    concatenated ``encode_block`` bytes, genesis -> tip.  Two nodes
    share a digest iff their ledgers are bit-identical under the
    canonical (timestamp-free) encoding — the oracle-parity
    comparison."""
    h = hashlib.sha256()
    for blk in node.ledger.blocks:
        h.update(encode_block(blk))
    return h.hexdigest()


@dataclasses.dataclass
class PeerStats:
    """Protocol-level counters for one ``PeerNode`` (the transport's
    ``WireStats`` counts bytes; this counts decisions)."""
    announces_sent: int = 0
    announces_recv: int = 0
    dup_announces: int = 0
    sig_rejects: int = 0          # forged/unsigned origin, bad binding
    malformed: int = 0            # undecodable header/body content
    compact_hits: int = 0         # body already held — nothing fetched
    body_requests: int = 0
    bodies_served: int = 0
    bodies_recv: int = 0
    sync_pulls: int = 0
    reorgs: int = 0
    blocks_committed: int = 0
    version_rejects: int = 0
    addrs_recv: int = 0           # addr records seen in HELLO/ADDR
    addrs_added: int = 0          # newly learned (relayed onward once)
    addr_rejects: int = 0         # forged/mismatched addr records
    rate_violations: int = 0      # serve-path limits we enforced
    unsolicited: int = 0          # bodies nobody asked this peer for
    evictions: int = 0            # connections dropped at max_peers
    bans: int = 0                 # peers banned for misbehavior

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _SyncState:
    blocks: List[Block]
    entries: Tuple[Tuple[bytes, bytes], ...]
    missing: set


class PeerNode:
    """Drives one unmodified ``Node`` over a transport port.

    ``identity`` signs this peer's own announces; ``keyring`` (shared
    out of band) verifies everyone's.  When the underlying node has no
    keyring of its own it adopts this one, so ``Node.receive`` applies
    the identical signature rule the in-process ``Network`` uses —
    origin binding is enforced once, in the node, not per transport.
    ``keyring=None`` runs unsigned (announces still carry the origin's
    key, receivers just don't require a registered one).

    ``compact=True`` announces header+checksum and serves bodies on
    demand; ``compact=False`` inlines every body (the bandwidth
    baseline the ``wire_relay`` bench compares against).

    Mesh additions (DESIGN.md §14): ``addr`` is this peer's own
    self-signed listen endpoint (carried in HELLO and gossiped);
    ``peerbook`` collects verified addrs and yields
    ``dial_candidates`` for the driver to connect; per-connection
    ``PeerScore`` tracks behavior, bans at ``ban_threshold``
    misbehavior points, and evicts the worst-scored connection past
    ``max_peers``; token buckets rate-limit the GET_HEADERS /
    GET_BODIES serve path (violations feed the score)."""

    def __init__(self, node: Node, identity: PeerIdentity,
                 keyring: Optional[KeyRing] = None, *,
                 compact: bool = True,
                 jash_fns: Optional[Dict[str, object]] = None,
                 max_bodies: int = 4096,
                 addr: Optional[PeerAddr] = None,
                 peerbook: Optional[PeerBook] = None,
                 max_peers: int = 8,
                 ban_threshold: int = BAN_THRESHOLD,
                 bodies_rate: float = 16.0, bodies_burst: float = 64.0,
                 headers_rate: float = 8.0, headers_burst: float = 32.0,
                 max_bodies_per_request: int = 64,
                 max_pending: int = 256,
                 clock=None) -> None:
        if keyring is None:
            keyring = getattr(node, "keyring", None)
        elif node.keyring is None:
            node.keyring = keyring      # one rule: the node enforces it
        if max_peers < 1:
            raise ValueError(f"max_peers must be >= 1, got {max_peers}")
        self.node = node
        self.identity = identity
        self.keyring = keyring
        self.compact = compact
        self.stats = PeerStats()
        self.port = None
        self._fns = collect_jash_fns(node.workloads, jash_fns)
        # checksum -> canonical body bytes: own mined payloads, fetched
        # bodies, and lazily indexed journal/evidence payloads.  LRU-
        # bounded; the node's own evidence store remains the fallback.
        self._bodies: "collections.OrderedDict[bytes, bytes]" = \
            collections.OrderedDict()
        self.max_bodies = max_bodies
        # block hash -> original signed announce (re-gossip relays the
        # miner's signature; re-signing would break origin binding)
        self._anns: Dict[str, Announce] = {}
        # checksum -> (block, announce, src) awaiting its body —
        # bounded: past max_pending the oldest entry is dropped (its
        # block arrives later via an ordinary chain pull)
        self._pending: "collections.OrderedDict[bytes, Tuple[Block, Announce, str]]" = \
            collections.OrderedDict()
        self.max_pending = max_pending
        self._sync: Dict[str, _SyncState] = {}
        self.peer_heights: Dict[str, int] = {}
        # -- mesh state (discovery, scoring, rate limits) -------------
        self.addr = addr
        self.peerbook = peerbook if peerbook is not None else PeerBook(
            self_id=identity.node_id, keyring=keyring)
        self.max_peers = max_peers
        self.ban_threshold = ban_threshold
        self.scores: Dict[str, PeerScore] = {}
        self.conn_ids: Dict[str, int] = {}   # conn name -> hello node id
        self._clock = clock
        self._bucket_cfg = {"bodies": (bodies_rate, bodies_burst),
                            "headers": (headers_rate, headers_burst)}
        self._buckets: Dict[Tuple[str, str], TokenBucket] = {}
        self.max_bodies_per_request = max_bodies_per_request
        self._helloed: set = set()       # conns our HELLO already went to
        self._addr_sent: set = set()     # conns that got our addr gossip
        self._banned_conns: set = set()
        self._dialing: set = set()       # node ids with a dial in flight
        # conn -> checksums we asked it for (bounded; solicited-reply
        # check for unsolicited-body scoring)
        self._asked: Dict[str, "collections.OrderedDict[bytes, bool]"] = {}

    # -- wiring -------------------------------------------------------
    def attach(self, port) -> None:
        """Connect to a transport port (``LoopbackPort``/
        ``TcpTransport``): its messages flow into ``on_message``;
        transport-level quarantine events feed the sender's score."""
        self.port = port
        port.on_message = self.on_message
        if hasattr(port, "on_quarantine"):
            port.on_quarantine = self._on_quarantine

    def _peers(self) -> List[str]:
        if self.port is None:
            return []
        return [n for n in self.port.peer_names()
                if n not in self._banned_conns]

    def _send(self, dst: str, msg: Message) -> None:
        if self.port is not None and dst not in self._banned_conns:
            self.port.send(dst, msg)

    def _now(self) -> float:
        if self._clock is not None:
            return self._clock()
        if self.port is not None and hasattr(self.port, "now"):
            return self.port.now()
        return time.monotonic()

    # -- scoring, banning, eviction (DESIGN §14) ----------------------
    def _score(self, src: str) -> PeerScore:
        sc = self.scores.get(src)
        if sc is None:
            sc = self.scores[src] = PeerScore()
        return sc

    def _punish(self, src: str, field: str, n: int = 1) -> None:
        """Charge ``n`` misbehavior events of ``field`` against the
        connection; ban (disconnect + PeerBook blacklist) past the
        threshold.  Banning is by identity too when the conn completed
        HELLO, so a banned peer cannot redial under a fresh name."""
        sc = self._score(src)
        setattr(sc, field, getattr(sc, field) + n)
        if (sc.banned(self.ban_threshold)
                and src not in self._banned_conns):
            self._ban(src)

    def _ban(self, src: str) -> None:
        self.stats.bans += 1
        self._banned_conns.add(src)
        nid = self.conn_ids.get(src)
        if nid is not None:
            self.peerbook.ban(nid)
        self._disconnect(src)

    def _disconnect(self, src: str) -> None:
        if self.port is not None and hasattr(self.port, "disconnect"):
            self.port.disconnect(src)
        self._sync.pop(src, None)
        self._asked.pop(src, None)

    def _on_quarantine(self, src: str) -> None:
        """Transport saw a malformed frame from this connection."""
        self._punish(src, "invalid_frames")

    def _note_conn(self, src: str) -> None:
        """First sign of life from a connection: create its score and
        enforce the connection cap by evicting the worst-scored peer
        (deterministic ordering — ``peerbook.eviction_order``)."""
        if src in self.scores:
            return
        self._score(src)
        names = self._peers()
        while len(names) > self.max_peers:
            ranked = eviction_order(
                {n: self._score(n) for n in names})
            victim = ranked[0]
            self.stats.evictions += 1
            self._disconnect(victim)
            names = [n for n in names if n != victim]

    def _bucket(self, src: str, kind: str) -> TokenBucket:
        b = self._buckets.get((src, kind))
        if b is None:
            rate, burst = self._bucket_cfg[kind]
            b = self._buckets[(src, kind)] = TokenBucket(rate, burst)
        return b

    def _note_asked(self, src: str, cks) -> None:
        asked = self._asked.setdefault(src, collections.OrderedDict())
        for ck in cks:
            asked[ck] = True
            asked.move_to_end(ck)
        while len(asked) > 4 * self.max_pending:
            asked.popitem(last=False)

    # -- discovery (PeerBook-driven dialing) --------------------------
    def known_heights(self) -> Dict[int, int]:
        """Peer chain heights by *node id* (HELLO-mapped) — what the
        N-process demo's exit condition reads."""
        out: Dict[int, int] = {}
        for name, h in self.peer_heights.items():
            nid = self.conn_ids.get(name)
            if nid is not None:
                out[nid] = max(h, out.get(nid, -1))
        return out

    def dial_candidates(self) -> List[PeerAddr]:
        """Who the driver should dial next: PeerBook selection minus
        everyone already connected (by HELLO-mapped id) or mid-dial,
        bounded by the connection cap."""
        connected = {self.conn_ids[n] for n in self._peers()
                     if n in self.conn_ids}
        room = self.max_peers - len(self._peers())
        if room <= 0:
            return []
        return self.peerbook.select(
            room, exclude=connected | self._dialing)

    def note_dialing(self, node_id: int) -> None:
        self._dialing.add(node_id)

    def note_dial_failed(self, node_id: int) -> None:
        self._dialing.discard(node_id)
        self.peerbook.mark_failed(node_id)

    def on_dialed(self, conn: str, addr: PeerAddr) -> None:
        """A dial to ``addr`` produced connection ``conn``: introduce
        ourselves and promote the addr to the tried bucket."""
        self._dialing.discard(addr.node_id)
        self.conn_ids[conn] = addr.node_id
        self.peerbook.mark_connected(addr.node_id)
        self._note_conn(conn)
        self._helloed.add(conn)
        self._send(conn, self.hello())

    # -- body store ---------------------------------------------------
    def _remember_body(self, ck: bytes, body: bytes) -> None:
        self._bodies[ck] = body
        self._bodies.move_to_end(ck)
        while len(self._bodies) > self.max_bodies:
            self._bodies.popitem(last=False)

    def _lookup_body(self, ck: bytes) -> Optional[bytes]:
        """Serve a body by content checksum: the hot store first, then
        a scan over the node's retained journal/evidence payloads
        (indexing them as it goes)."""
        body = self._bodies.get(ck)
        if body is not None:
            return body
        found = None
        for payload in self.node.chain_payloads():
            if payload is None:
                continue
            b = encode_payload(payload)
            c = hashlib.sha256(b).digest()[:16]
            self._remember_body(c, b)
            if c == ck:
                found = b
        return found

    def _ck_of_height(self, height: int) -> bytes:
        payload = self.node._payloads.get(height)
        if payload is None:
            return _ZERO_CK                # pruned at finalization
        body = encode_payload(payload)
        ck = hashlib.sha256(body).digest()[:16]
        self._remember_body(ck, body)
        return ck

    # -- outbound -----------------------------------------------------
    def hello(self) -> Hello:
        return Hello(version=PROTOCOL_VERSION,
                     node_id=self.identity.node_id,
                     pubkey=self.identity.pubkey,
                     height=self.node.ledger.height,
                     addr=self.addr)

    def broadcast_hello(self) -> None:
        m = self.hello()
        for dst in self._peers():
            self._helloed.add(dst)
            self._send(dst, m)

    def _gossip_addrs(self, dst: str) -> None:
        """Send everything the book knows to one (new) connection —
        once per conn, chunked at the per-message cap."""
        if dst in self._addr_sent:
            return
        self._addr_sent.add(dst)
        known = self.peerbook.known()
        if self.addr is not None:
            known = [self.addr] + known
        for i in range(0, len(known), MAX_ADDRS):
            self._send(dst, Addr(addrs=tuple(known[i:i + MAX_ADDRS])))

    def _relay_addr(self, addr: PeerAddr, exclude: str) -> None:
        """Flood one newly learned addr to every other connection
        (each addr is relayed at most once — ``PeerBook.add`` returns
        True only on first admission)."""
        m = Addr(addrs=(addr,))
        for dst in self._peers():
            if dst != exclude:
                self._send(dst, m)

    def _admit_addr(self, src: str, addr: PeerAddr, *,
                    claimed_id: Optional[int] = None) -> None:
        """One addr record from HELLO or ADDR gossip: fast-path exact
        duplicates (no re-verification), verify + admit the rest, relay
        genuinely new knowledge, and score forged records."""
        self.stats.addrs_recv += 1
        if addr.node_id == self.identity.node_id:
            return                         # our own addr echoed back
        if self.peerbook.has_exact(addr):
            return                         # already known: no crypto
        if claimed_id is not None and addr.node_id != claimed_id:
            # a HELLO advertising someone else's addr as its own
            self.stats.addr_rejects += 1
            self._punish(src, "invalid_frames")
            return
        if not addr.verify(self.peerbook.keyring or self.keyring):
            self.stats.addr_rejects += 1
            self._punish(src, "invalid_frames")
            return
        if self.peerbook.add(addr, verified=True):
            self.stats.addrs_added += 1
            self._relay_addr(addr, exclude=src)

    def mine_and_announce(self, workload: Optional[str] = None
                          ) -> BlockReceipt:
        """Mine one block on the wrapped node and announce it to every
        peer — compact (header + checksum) or full-body per config."""
        receipt = self.node.mine_block(workload)
        block = receipt.record.to_block()
        body = encode_payload(receipt.payload)
        sa = make_announce(self.identity, block, receipt.payload)
        self._remember_body(sa.checksum, body)
        ann = Announce(header=sa.header, checksum=sa.checksum,
                       origin=sa.origin, pubkey=sa.pubkey,
                       signature=sa.signature,
                       body=None if self.compact else body)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        for dst in self._peers():
            self._send(dst, ann)
            self.stats.announces_sent += 1
        return receipt

    def _regossip(self, block: Block, ann: Announce,
                  exclude: str) -> None:
        out = ann if not self.compact else dataclasses.replace(
            ann, body=None)
        if not self.compact and out.body is None:
            body = self._bodies.get(ann.checksum)
            if body is not None:
                out = dataclasses.replace(out, body=body)
        for dst in self._peers():
            if dst != exclude:
                self._send(dst, out)
                self.stats.announces_sent += 1

    def _request_sync(self, src: str) -> None:
        if src in self._sync:
            return                         # one pull in flight per peer
        self.stats.sync_pulls += 1
        self._send(src, GetHeaders(from_height=0))

    # -- inbound dispatch ---------------------------------------------
    def on_message(self, src: str, msg: Message) -> None:
        if src in self._banned_conns:
            return                         # dead to us
        nid = self.conn_ids.get(src)
        if nid is not None and nid in self.peerbook.banned:
            return
        self._note_conn(src)
        if isinstance(msg, Hello):
            self._on_hello(src, msg)
        elif isinstance(msg, Addr):
            self._on_addr(src, msg)
        elif isinstance(msg, Announce):
            self._on_announce(src, msg)
        elif isinstance(msg, GetHeaders):
            self._on_get_headers(src, msg)
        elif isinstance(msg, Tip):
            self._on_tip(src, msg)
        elif isinstance(msg, GetBodies):
            self._on_get_bodies(src, msg)
        elif isinstance(msg, Bodies):
            self._on_bodies(src, msg)

    def _on_hello(self, src: str, m: Hello) -> None:
        if m.version != PROTOCOL_VERSION:
            self.stats.version_rejects += 1
            self._punish(src, "invalid_frames")
            return
        self.conn_ids[src] = m.node_id
        self.peer_heights[src] = m.height
        if m.node_id in self.peerbook.banned:
            self._ban(src)                 # banned identity redialing
            return
        if m.addr is not None:
            self._admit_addr(src, m.addr, claimed_id=m.node_id)
        if src not in self._helloed:       # introduce ourselves back
            self._helloed.add(src)
            self._send(src, self.hello())
        self._gossip_addrs(src)            # once per conn
        if self.conn_ids.get(src) == m.node_id:
            self.peerbook.mark_connected(m.node_id)
        if m.height > self.node.ledger.height:
            self._request_sync(src)

    def _on_addr(self, src: str, m: Addr) -> None:
        for addr in m.addrs:
            self._admit_addr(src, addr)

    def _on_announce(self, src: str, a: Announce) -> None:
        self.stats.announces_recv += 1
        try:
            block = decode_block(a.header)
        except Exception:
            self.stats.malformed += 1
            return
        if self.node.has_block(block.block_hash):
            self.stats.dup_announces += 1
            return
        sa = SignedAnnounce(header=a.header, checksum=a.checksum,
                            origin=a.origin, pubkey=a.pubkey,
                            signature=a.signature)
        if self.keyring is not None and not sa.verify_origin(self.keyring):
            # forged or unsigned origin: dropped before any body fetch
            self.stats.sig_rejects += 1
            return
        body = a.body
        if body is not None:
            if hashlib.sha256(body).digest()[:16] != a.checksum:
                self.stats.malformed += 1
                return
        else:
            body = self._lookup_body(a.checksum)
            if body is not None:
                self.stats.compact_hits += 1    # nothing crosses the wire
        if body is None:
            self._pending[a.checksum] = (block, a, src)
            self._pending.move_to_end(a.checksum)
            while len(self._pending) > self.max_pending:
                # bounded in-flight table: the dropped block arrives
                # later via an ordinary chain pull
                self._pending.popitem(last=False)
            self.stats.body_requests += 1
            self._note_asked(src, (a.checksum,))
            self._send(src, GetBodies(checksums=(a.checksum,)))
            return
        self._process(src, block, a, body)

    def _process(self, src: str, block: Block, ann: Announce,
                 body: bytes) -> None:
        """Body in hand: decode, hand to the node's ordinary receive
        path (which re-checks the signature binding against this exact
        payload), fall back to a chain pull on tip mismatch."""
        try:
            payload = decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return
        self._remember_body(ann.checksum, body)
        sa = SignedAnnounce(header=ann.header, checksum=ann.checksum,
                            origin=ann.origin, pubkey=ann.pubkey,
                            signature=ann.signature)
        ok = self.node.receive(block, payload, announce=sa)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        if ok:
            self.stats.blocks_committed += 1
            self._score(src).useful_blocks += 1
            self._regossip(block, ann, exclude=src)
        elif not self.node.has_block(block.block_hash):
            self._request_sync(src)

    def _on_get_headers(self, src: str, g: GetHeaders) -> None:
        if not self._bucket(src, "headers").allow(self._now()):
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return                         # throttled: no reply
        entries = tuple(
            (encode_block(blk), self._ck_of_height(h))
            for h, blk in enumerate(self.node.ledger.blocks)
            if h >= g.from_height)
        self._send(src, Tip(start=g.from_height, entries=entries))

    def _on_tip(self, src: str, t: Tip) -> None:
        self._sync.pop(src, None)
        if t.start != 0:
            return                         # we only ever pull from 0
        if len(t.entries) < self.node.ledger.height:
            # strictly shorter than us: the peer advertised a height it
            # cannot deliver (equality is the honest caught-up-while-
            # pulling race and goes unscored)
            self._punish(src, "stale_tips")
            return
        if len(t.entries) <= self.node.ledger.height:
            return                         # not longer: no fork choice
        try:
            blocks = [decode_block(header) for header, _ in t.entries]
        except Exception:
            self.stats.malformed += 1
            return
        missing = set()
        for i, (_, ck) in enumerate(t.entries):
            if self._have_payload_for(i, blocks[i], ck):
                continue
            if ck == _ZERO_CK:
                return    # sender pruned a body we'd need: can't adopt
            missing.add(ck)
        state = _SyncState(blocks=blocks, entries=t.entries,
                           missing=missing)
        if missing:
            self._sync[src] = state
            self.stats.body_requests += len(missing)
            self._note_asked(src, missing)
            self._send(src, GetBodies(checksums=tuple(sorted(missing))))
            return
        self._finish_sync(src, state)

    def _have_payload_for(self, height: int, block: Block,
                          ck: bytes) -> bool:
        """True iff fork choice at this height needs no wire transfer:
        our own chain holds the identical block (its retained evidence
        substitutes below the fork point) or the body store already
        has the checksum."""
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            return True
        return self._bodies.get(ck) is not None

    def _resolve_payload(self, height: int, block: Block,
                         ck: bytes) -> Optional[BlockPayload]:
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            # common prefix: consider_chain substitutes our evidence
            # anyway; pass it directly (may be None below the floor)
            return self.node._payloads.get(height)
        body = self._bodies.get(ck)
        if body is None:
            return None
        try:
            return decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return None

    def _finish_sync(self, src: str, state: _SyncState) -> None:
        payloads = [self._resolve_payload(i, blk, ck)
                    for i, (blk, (_, ck))
                    in enumerate(zip(state.blocks, state.entries))]
        try:
            ok = self.node.consider_chain(state.blocks, payloads)
        except ChainError:
            self.stats.malformed += 1
            return
        if ok:
            self.stats.reorgs += 1
            self.stats.blocks_committed += 1

    def _on_get_bodies(self, src: str, g: GetBodies) -> None:
        """DoS-hardened body serving: a per-request count cap, a
        token-bucket rate limit charging one token per requested body,
        and an *always-reply* discipline — an admitted request gets a
        ``Bodies`` even when nothing was found, so an honest requester
        holding an unknown or finality-pruned checksum detects the
        miss and falls back to headers-first sync instead of waiting
        forever.  Violations feed the requester's score; a throttled
        request is never served."""
        if len(g.checksums) > self.max_bodies_per_request:
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return
        if not self._bucket(src, "bodies").allow(
                self._now(), cost=float(max(len(g.checksums), 1))):
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return
        bodies = []
        for ck in g.checksums:
            if ck == _ZERO_CK:
                continue                   # pruned-body sentinel: skip
            body = self._lookup_body(ck)
            if body is not None:
                bodies.append(body)
        self.stats.bodies_served += len(bodies)
        self._send(src, Bodies(bodies=tuple(bodies)))

    def _on_bodies(self, src: str, b: Bodies) -> None:
        asked = self._asked.get(src, collections.OrderedDict())
        got = set()
        for body in b.bodies:
            ck = hashlib.sha256(body).digest()[:16]
            if ck not in asked:
                # a body nobody asked this peer for: unmetered push
                self.stats.unsolicited += 1
                self._punish(src, "unsolicited")
                continue
            asked.pop(ck, None)
            self._remember_body(ck, body)
            got.add(ck)
            self.stats.bodies_recv += 1
            pend = self._pending.pop(ck, None)
            if pend is not None:
                block, ann, _ = pend
                self._process(src, block, ann, body)
        state = self._sync.get(src)
        if state is not None:
            state.missing -= got
            if not state.missing:
                del self._sync[src]
                self._finish_sync(src, state)
            elif not got:
                # the peer answered but could not serve what the sync
                # still needs (unknown/pruned over there): abandon this
                # pull — ordinary announce flow or another peer's
                # headers will cover it
                del self._sync[src]
        # announce-path fetches this reply failed to cover (unknown or
        # pruned on the serving side): drop them and fall back to a
        # headers-first pull from the same peer
        stranded = [ck for ck, (_, _, who) in self._pending.items()
                    if who == src and ck in asked and ck not in got]
        for ck in stranded:
            self._pending.pop(ck, None)
            asked.pop(ck, None)
        if stranded:
            self._request_sync(src)


# ---------------------------------------------------------------------------
# the N-peer loopback convergence scenario (sim CLI + bench + tests)
# ---------------------------------------------------------------------------

_SUITE_DIMS = dict(sat={"n_vars": 10, "n_clauses": 40},
                   gan={"grid_bits": 8},
                   docking={"n_r": 16, "n_p": 16})
_SUITE_SCHEDULE = ("sat", "gan", "docking", "classic",
                   "sat", "gan", "docking", "sat")


def _suite_node(i: int, *, suite_seed: int = 7,
                classic_arg_bits: int = 6,
                keyring: Optional[KeyRing] = None) -> Node:
    """One heterogeneous-suite node (same dims as the sim's
    ``heterogeneous_scenario`` — small enough for CI, every family
    represented)."""
    from repro.chain.workloads import default_suite
    return Node(node_id=i, classic_arg_bits=classic_arg_bits,
                workloads=default_suite(seed=suite_seed, **_SUITE_DIMS),
                keyring=keyring)


def loopback_scenario(n_peers: int = 4, seed: int = 0, *,
                      compact: bool = True,
                      signed: bool = True,
                      drop_prob: float = 0.0,
                      suite_seed: int = 7,
                      schedule: Sequence[str] = _SUITE_SCHEDULE,
                      oracle: bool = True) -> Dict[str, object]:
    """N wire-connected peers mine the heterogeneous workload suite
    round-robin over a deterministic loopback transport, then the
    result is compared bit-for-bit against the in-process ``Network``
    mining the same schedule on the same seeds — tips, ledgers
    (canonical chain digest), and credit books must all be equal.

    Returns a JSON-able report: convergence, oracle parity, bytes on
    wire, and per-peer protocol counters.  ``compact=False`` runs the
    full-body relay baseline the ``wire_relay`` bench compares
    against; ``drop_prob`` exercises retry + pull-based resync."""
    identities, ring = make_identities(n_peers)
    used_ring = ring if signed else None
    hub = LoopbackHub(seed=seed, drop_prob=drop_prob)
    peers: List[PeerNode] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=used_ring)
        pn = PeerNode(node, identities[i], used_ring, compact=compact)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    for pn in peers:
        pn.broadcast_hello()
    hub.pump()
    for b, family in enumerate(schedule):
        peers[b % n_peers].mine_and_announce(family)
        hub.pump()
    # lossy links can strand a peer: height beacons trigger pull resync
    for _ in range(8):
        heights = {pn.node.ledger.height for pn in peers}
        if len(heights) == 1:
            break
        for pn in peers:
            pn.broadcast_hello()
        hub.pump()
    elapsed = time.perf_counter() - t0
    digests = [chain_digest(pn.node) for pn in peers]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in peers]
    converged = (len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in peers))
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "compact": compact,
        "signed": signed,
        "drop_prob": drop_prob,
        "converged": converged,
        "height": peers[0].node.ledger.height,
        "chain_digest": digests[0],
        "bytes_on_wire": hub.total_bytes(),
        "frames_delivered": sum(p.stats.frames_recv
                                for p in hub.ports.values()),
        "quarantined": sum(p.stats.quarantined
                           for p in hub.ports.values()),
        "elapsed_s": round(elapsed, 3),
        "blocks_per_s": round(len(schedule) / elapsed, 3) if elapsed else 0.0,
        "peer_stats": [pn.stats.to_dict() for pn in peers],
    }
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=used_ring),
            identities=identities if signed else None)
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report


# ---------------------------------------------------------------------------
# the N-peer single-seed mesh scenario (discovery + scoring, DESIGN §14)
# ---------------------------------------------------------------------------


def _mesh_complete(peers: List[PeerNode]) -> bool:
    """Every peer is connected to every other (or holds its cap)."""
    want = len(peers) - 1
    return all(len(pn._peers()) >= min(want, pn.max_peers)
               for pn in peers)


def drive_discovery(hub: LoopbackHub, peers: List[PeerNode],
                    *, max_rounds: int = 16) -> int:
    """Deterministic discovery driver for loopback meshes: each round
    pumps gossip, then dials every PeerBook candidate (the loopback
    "address" of node ``i`` is the port name ``peer{i}``).  Returns
    the number of rounds until no peer wants another connection."""
    for rounds in range(1, max_rounds + 1):
        dialed = 0
        for pn in peers:
            for cand in pn.dial_candidates():
                dst = f"peer{cand.node_id}"
                if hub.connect(pn.port.name, dst):
                    pn.on_dialed(dst, cand)
                    dialed += 1
                else:
                    # the other side dialed us first — same link
                    pn.conn_ids.setdefault(dst, cand.node_id)
                    pn.peerbook.mark_connected(cand.node_id)
        hub.pump()
        if not dialed and _mesh_complete(peers):
            return rounds
    return max_rounds


def mesh_scenario(n_peers: int = 5, seed: int = 0, *,
                  compact: bool = True,
                  drop_prob: float = 0.0,
                  suite_seed: int = 7,
                  schedule: Sequence[str] = _SUITE_SCHEDULE,
                  oracle: bool = True,
                  max_peers: Optional[int] = None,
                  max_rounds: int = 16) -> Dict[str, object]:
    """N peers bootstrapped from a **single seed address**: every peer
    starts linked only to ``peer0``, learns the rest of the mesh from
    HELLO addr payloads and ADDR gossip, dials it full, then mines the
    heterogeneous suite round-robin — and must still reconverge
    bit-identically with the in-process ``Network`` oracle (tips,
    ledgers, credit books).  The report adds discovery metrics (rounds
    and wall-clock to full mesh — the ``mesh_discovery`` bench row)
    and per-peer score/book state."""
    identities, ring = make_identities(n_peers)
    hub = LoopbackHub(seed=seed, drop_prob=drop_prob, full_mesh=False)
    cap = max_peers if max_peers is not None else n_peers + 2
    peers: List[PeerNode] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=ring)
        pn = PeerNode(node, identities[i], ring, compact=compact,
                      addr=make_addr(identities[i], "loopback", 9000 + i),
                      max_peers=cap)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    # single-seed bootstrap: the only links are peer{i} -> peer0
    for i in range(1, n_peers):
        hub.connect(f"peer{i}", "peer0")
        peers[i].conn_ids["peer0"] = 0
        peers[i].broadcast_hello()
    hub.pump()
    rounds = drive_discovery(hub, peers, max_rounds=max_rounds)
    discovery_s = time.perf_counter() - t0
    full_mesh = _mesh_complete(peers)
    # mine the suite round-robin over the discovered topology
    for b, family in enumerate(schedule):
        peers[b % n_peers].mine_and_announce(family)
        hub.pump()
    for _ in range(8):
        heights = {pn.node.ledger.height for pn in peers}
        if len(heights) == 1:
            break
        for pn in peers:
            pn.broadcast_hello()
        hub.pump()
    elapsed = time.perf_counter() - t0
    digests = [chain_digest(pn.node) for pn in peers]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in peers]
    converged = (len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in peers))
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "compact": compact,
        "drop_prob": drop_prob,
        "converged": converged,
        "full_mesh": full_mesh,
        "discovery_rounds": rounds,
        "discovery_s": round(discovery_s, 4),
        "links": {pn.port.name: pn.port.peer_names() for pn in peers},
        "height": peers[0].node.ledger.height,
        "chain_digest": digests[0],
        "bytes_on_wire": hub.total_bytes(),
        "addrs_added": sum(pn.stats.addrs_added for pn in peers),
        "elapsed_s": round(elapsed, 3),
        "peer_stats": [pn.stats.to_dict() for pn in peers],
        "peerbooks": [pn.peerbook.to_dict() for pn in peers],
    }
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=ring),
            identities=identities)
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report
