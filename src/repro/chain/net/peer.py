"""``repro.chain.net.peer`` — ``PeerNode``: an unmodified ``Node``
driven over a wire.

``PeerNode`` is sans-IO protocol logic: it consumes typed messages
from any transport port (loopback or TCP — ``attach`` wires the
callback) and sends replies through the same port.  The consensus
object underneath is a stock ``Node`` — nothing about mining,
verification, fork choice, finality, or the journal changes when a
node goes out-of-process; that is the whole point of the oracle test
(wire-connected peers must reconverge bit-identically with the
in-process ``Network``).

Compact relay (BIP-152 shaped, DESIGN.md §13): a freshly mined block
is announced as *header + payload content checksum + origin
signature*.  A receiver that already holds the body (from an earlier
announce, a sync, or its own chain evidence) commits without fetching
— already-seen payloads never cross the wire twice; otherwise it
fetches the body by checksum (``GET_BODIES``/``BODIES``, served from
the announcer's body store with a fallback scan over its journal/
evidence payloads).  An announce that does not extend the local tip
triggers a chain pull (``GET_HEADERS``/``TIP``) and ``Node.
consider_chain`` fork choice, substituting locally held bodies per
checksum so only the genuinely missing ones are transferred.

Liveness (DESIGN.md §15): every pull this peer issues — an
announce-path body fetch or a headers-first sync — carries a deadline
on the explicit clock (hub simulated time on loopback,
``time.monotonic`` on TCP).  ``tick()`` sweeps expired requests:
the silent peer is charged a ``timeouts`` score, the request *fails
over* to the next-best-scored connection with exponential backoff,
and past the retry cap a headers-first pull from the best peer
recovers the block — sync degrades, it never hangs.  PING/PONG
keepalive probes idle connections; a peer silent past the keepalive
window is disconnected.  ``anchor_ids`` are protected connections
(the first outbound dials) that connection-cap eviction never
touches — the eclipse defense's guarantee that a victim keeps at
least one honest link no matter how many attacker addrs flood its
book (the ``PeerBook`` per-source quota bounds that flood too).

``loopback_scenario`` is the N-peer deterministic convergence harness
(the sim CLI's ``--scenario wire`` and the ``wire_relay`` bench run
it); ``mesh_chaos_scenario`` composes crashes + restarts + journal
corruption + an eclipse attacker + frame corruption over one seed;
the two-OS-process TCP flavor lives in ``__main__``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.chain.net.identity import (KeyRing, PeerAddr, PeerIdentity,
                                      SignedAnnounce, make_addr,
                                      make_announce, make_identities)
from repro.chain.net.messages import (MAX_ADDRS, PROTOCOL_VERSION, Addr,
                                      Announce, Bodies, GetBodies,
                                      GetHeaders, Hello, Message, Ping,
                                      Pong, Tip, encode_message)
from repro.chain.net.peerbook import (BAN_THRESHOLD, PeerBook, PeerScore,
                                      TokenBucket, eviction_order)
from repro.chain.net.transport import LoopbackHub
from repro.chain.node import BlockReceipt, Node
from repro.chain.store import (ChainStore, collect_jash_fns, decode_block,
                               decode_payload, encode_block, encode_payload,
                               payload_checksum)
from repro.chain.workload import BlockPayload, ChainError
from repro.core.ledger import Block

__all__ = [
    "EclipseAttacker",
    "PeerNode",
    "PeerStats",
    "chain_digest",
    "loopback_scenario",
    "mesh_chaos_scenario",
    "mesh_scenario",
]

_ZERO_CK = b"\x00" * 16          # "body pruned at finalization" sentinel


def chain_digest(node: Node) -> str:
    """Canonical digest of a node's whole chain: SHA-256 over the
    concatenated ``encode_block`` bytes, genesis -> tip.  Two nodes
    share a digest iff their ledgers are bit-identical under the
    canonical (timestamp-free) encoding — the oracle-parity
    comparison."""
    h = hashlib.sha256()
    for blk in node.ledger.blocks:
        h.update(encode_block(blk))
    return h.hexdigest()


@dataclasses.dataclass
class PeerStats:
    """Protocol-level counters for one ``PeerNode`` (the transport's
    ``WireStats`` counts bytes; this counts decisions)."""
    announces_sent: int = 0
    announces_recv: int = 0
    dup_announces: int = 0
    sig_rejects: int = 0          # forged/unsigned origin, bad binding
    malformed: int = 0            # undecodable header/body content
    compact_hits: int = 0         # body already held — nothing fetched
    body_requests: int = 0
    bodies_served: int = 0
    bodies_recv: int = 0
    sync_pulls: int = 0
    reorgs: int = 0
    blocks_committed: int = 0
    version_rejects: int = 0
    addrs_recv: int = 0           # addr records seen in HELLO/ADDR
    addrs_added: int = 0          # newly learned (relayed onward once)
    addr_rejects: int = 0         # forged/mismatched addr records
    rate_violations: int = 0      # serve-path limits we enforced
    unsolicited: int = 0          # bodies nobody asked this peer for
    evictions: int = 0            # connections dropped at max_peers
    bans: int = 0                 # peers banned for misbehavior
    pings_sent: int = 0           # keepalive probes issued
    pongs_recv: int = 0           # matching echoes
    timeouts: int = 0             # request deadlines that expired
    failovers: int = 0            # expired pulls re-targeted elsewhere
    keepalive_drops: int = 0      # conns silent past the window
    observed_echoes: int = 0      # HELLO observed-endpoint reports seen
    addrs_adopted: int = 0        # self-addrs signed from observations

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _SyncState:
    """One in-flight headers-first pull past the Tip stage: the
    decoded candidate chain plus the body checksums still missing —
    and the deadline/attempt pair the liveness sweep enforces."""
    blocks: List[Block]
    entries: Tuple[Tuple[bytes, bytes], ...]
    missing: set
    deadline: float = 0.0
    attempt: int = 0


@dataclasses.dataclass
class _PendingBody:
    """One announce whose body is being fetched: who we asked, when
    the answer is due, and how many times the fetch already failed
    over (``tick`` re-targets it with exponential backoff)."""
    block: Block
    ann: Announce
    src: str
    deadline: float = 0.0
    attempt: int = 0


class PeerNode:
    """Drives one unmodified ``Node`` over a transport port.

    ``identity`` signs this peer's own announces; ``keyring`` (shared
    out of band) verifies everyone's.  When the underlying node has no
    keyring of its own it adopts this one, so ``Node.receive`` applies
    the identical signature rule the in-process ``Network`` uses —
    origin binding is enforced once, in the node, not per transport.
    ``keyring=None`` runs unsigned (announces still carry the origin's
    key, receivers just don't require a registered one).

    ``compact=True`` announces header+checksum and serves bodies on
    demand; ``compact=False`` inlines every body (the bandwidth
    baseline the ``wire_relay`` bench compares against).

    Mesh additions (DESIGN.md §14): ``addr`` is this peer's own
    self-signed listen endpoint (carried in HELLO and gossiped);
    ``peerbook`` collects verified addrs and yields
    ``dial_candidates`` for the driver to connect; per-connection
    ``PeerScore`` tracks behavior, bans at ``ban_threshold``
    misbehavior points, and evicts the worst-scored connection past
    ``max_peers``; token buckets rate-limit the GET_HEADERS /
    GET_BODIES serve path (violations feed the score).

    Liveness additions (DESIGN.md §15): every pull carries a deadline
    of ``request_timeout * backoff ** attempt`` seconds on the
    explicit clock, enforced by ``tick()`` — drivers call it between
    pumps (loopback) or each loop iteration (TCP).  ``max_retries``
    caps failover attempts per request; ``ping_interval`` /
    ``keepalive_timeout`` bound how long an idle or silent connection
    lives; ``anchors`` pre-seeds protected node ids (otherwise the
    first ``n_anchors`` outbound dials become anchors); ``min_observed``
    distinct peers must echo the same observed endpoint before an
    addr-less peer signs it as its own (``listen_port`` overrides the
    observed source port — on real TCP an outbound source port is
    ephemeral, only the host part is routable knowledge)."""

    def __init__(self, node: Node, identity: PeerIdentity,
                 keyring: Optional[KeyRing] = None, *,
                 compact: bool = True,
                 jash_fns: Optional[Dict[str, object]] = None,
                 max_bodies: int = 4096,
                 addr: Optional[PeerAddr] = None,
                 peerbook: Optional[PeerBook] = None,
                 max_peers: int = 8,
                 ban_threshold: int = BAN_THRESHOLD,
                 bodies_rate: float = 16.0, bodies_burst: float = 64.0,
                 headers_rate: float = 8.0, headers_burst: float = 32.0,
                 max_bodies_per_request: int = 64,
                 max_pending: int = 256,
                 request_timeout: float = 5.0,
                 max_retries: int = 3,
                 backoff: float = 2.0,
                 ping_interval: float = 10.0,
                 keepalive_timeout: float = 30.0,
                 anchors: Sequence[int] = (),
                 n_anchors: int = 2,
                 min_observed: int = 2,
                 listen_port: Optional[int] = None,
                 clock=None) -> None:
        if keyring is None:
            keyring = getattr(node, "keyring", None)
        elif node.keyring is None:
            node.keyring = keyring      # one rule: the node enforces it
        if max_peers < 1:
            raise ValueError(f"max_peers must be >= 1, got {max_peers}")
        self.node = node
        self.identity = identity
        self.keyring = keyring
        self.compact = compact
        self.stats = PeerStats()
        self.port = None
        self._fns = collect_jash_fns(node.workloads, jash_fns)
        # checksum -> canonical body bytes: own mined payloads, fetched
        # bodies, and lazily indexed journal/evidence payloads.  LRU-
        # bounded; the node's own evidence store remains the fallback.
        self._bodies: "collections.OrderedDict[bytes, bytes]" = \
            collections.OrderedDict()
        self.max_bodies = max_bodies
        # block hash -> original signed announce (re-gossip relays the
        # miner's signature; re-signing would break origin binding)
        self._anns: Dict[str, Announce] = {}
        # checksum -> _PendingBody awaiting its body — bounded: past
        # max_pending the oldest entry is dropped (its block arrives
        # later via an ordinary chain pull)
        self._pending: "collections.OrderedDict[bytes, _PendingBody]" = \
            collections.OrderedDict()
        self.max_pending = max_pending
        self._sync: Dict[str, _SyncState] = {}
        # conn -> (deadline, attempt) of a GET_HEADERS with no Tip yet
        self._sync_req: Dict[str, Tuple[float, int]] = {}
        self.peer_heights: Dict[str, int] = {}
        # -- mesh state (discovery, scoring, rate limits) -------------
        self.addr = addr
        self.peerbook = peerbook if peerbook is not None else PeerBook(
            self_id=identity.node_id, keyring=keyring)
        self.max_peers = max_peers
        self.ban_threshold = ban_threshold
        self.scores: Dict[str, PeerScore] = {}
        self.conn_ids: Dict[str, int] = {}   # conn name -> hello node id
        self._clock = clock
        self._bucket_cfg = {"bodies": (bodies_rate, bodies_burst),
                            "headers": (headers_rate, headers_burst)}
        self._buckets: Dict[Tuple[str, str], TokenBucket] = {}
        self.max_bodies_per_request = max_bodies_per_request
        self._helloed: set = set()       # conns our HELLO already went to
        self._addr_sent: set = set()     # conns that got our addr gossip
        self._banned_conns: set = set()
        self._dialing: set = set()       # node ids with a dial in flight
        # conn -> checksums we asked it for (bounded; solicited-reply
        # check for unsolicited-body scoring)
        self._asked: Dict[str, "collections.OrderedDict[bytes, bool]"] = {}
        # -- liveness state (deadlines, keepalive, anchors — §15) -----
        self.request_timeout = float(request_timeout)
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.ping_interval = float(ping_interval)
        self.keepalive_timeout = float(keepalive_timeout)
        self.anchor_ids: set = set(anchors)
        self.n_anchors = int(n_anchors)
        self.min_observed = int(min_observed)
        self.listen_port = listen_port
        self._last_recv: Dict[str, float] = {}
        self._ping_sent: Dict[str, Tuple[int, float]] = {}
        self._ping_nonce = 0
        # observed endpoint -> distinct reporters who echoed it
        self._observed: Dict[Tuple[str, int], set] = {}

    # -- wiring -------------------------------------------------------
    def attach(self, port) -> None:
        """Connect to a transport port (``LoopbackPort``/
        ``TcpTransport``): its messages flow into ``on_message``;
        transport-level quarantine events feed the sender's score."""
        self.port = port
        port.on_message = self.on_message
        if hasattr(port, "on_quarantine"):
            port.on_quarantine = self._on_quarantine

    def _peers(self) -> List[str]:
        if self.port is None:
            return []
        return [n for n in self.port.peer_names()
                if n not in self._banned_conns]

    def _send(self, dst: str, msg: Message) -> None:
        if self.port is not None and dst not in self._banned_conns:
            self.port.send(dst, msg)

    def _now(self) -> float:
        if self._clock is not None:
            return self._clock()
        if self.port is not None and hasattr(self.port, "now"):
            return self.port.now()
        return time.monotonic()

    # -- scoring, banning, eviction (DESIGN §14) ----------------------
    def _score(self, src: str) -> PeerScore:
        sc = self.scores.get(src)
        if sc is None:
            sc = self.scores[src] = PeerScore()
        return sc

    def _punish(self, src: str, field: str, n: int = 1) -> None:
        """Charge ``n`` misbehavior events of ``field`` against the
        connection; ban (disconnect + PeerBook blacklist) past the
        threshold.  Banning is by identity too when the conn completed
        HELLO, so a banned peer cannot redial under a fresh name."""
        sc = self._score(src)
        setattr(sc, field, getattr(sc, field) + n)
        if (sc.banned(self.ban_threshold)
                and src not in self._banned_conns):
            self._ban(src)

    def _ban(self, src: str) -> None:
        self.stats.bans += 1
        self._banned_conns.add(src)
        nid = self.conn_ids.get(src)
        if nid is not None:
            self.peerbook.ban(nid)
        self._disconnect(src)

    def _disconnect(self, src: str) -> None:
        if self.port is not None and hasattr(self.port, "disconnect"):
            self.port.disconnect(src)
        self._sync.pop(src, None)
        self._sync_req.pop(src, None)
        self._asked.pop(src, None)
        self._ping_sent.pop(src, None)
        self._last_recv.pop(src, None)
        self.peer_heights.pop(src, None)
        # body fetches still waiting on this conn are orphaned — the
        # next tick() re-targets them (their src is no longer alive)

    def _on_quarantine(self, src: str) -> None:
        """Transport saw a malformed frame from this connection."""
        self._punish(src, "invalid_frames")

    def _note_conn(self, src: str) -> None:
        """First sign of life from a connection: create its score and
        enforce the connection cap by evicting the worst-scored peer
        (deterministic ordering — ``peerbook.eviction_order``).
        Anchored connections are exempt from cap eviction — the
        eclipse defense's protected links — unless every connection
        is an anchor."""
        if src in self.scores:
            return
        self._score(src)
        self._last_recv.setdefault(src, self._now())
        names = self._peers()
        while len(names) > self.max_peers:
            pool = [n for n in names
                    if self.conn_ids.get(n) not in self.anchor_ids]
            ranked = eviction_order(
                {n: self._score(n) for n in (pool or names)})
            victim = ranked[0]
            self.stats.evictions += 1
            self._disconnect(victim)
            names = [n for n in names if n != victim]

    def _bucket(self, src: str, kind: str) -> TokenBucket:
        b = self._buckets.get((src, kind))
        if b is None:
            rate, burst = self._bucket_cfg[kind]
            b = self._buckets[(src, kind)] = TokenBucket(rate, burst)
        return b

    def _note_asked(self, src: str, cks) -> None:
        asked = self._asked.setdefault(src, collections.OrderedDict())
        for ck in cks:
            asked[ck] = True
            asked.move_to_end(ck)
        while len(asked) > 4 * self.max_pending:
            asked.popitem(last=False)

    # -- discovery (PeerBook-driven dialing) --------------------------
    def known_heights(self) -> Dict[int, int]:
        """Peer chain heights by *node id* (HELLO-mapped) — what the
        N-process demo's exit condition reads."""
        out: Dict[int, int] = {}
        for name, h in self.peer_heights.items():
            nid = self.conn_ids.get(name)
            if nid is not None:
                out[nid] = max(h, out.get(nid, -1))
        return out

    def dial_candidates(self) -> List[PeerAddr]:
        """Who the driver should dial next: PeerBook selection minus
        everyone already connected (by HELLO-mapped id) or mid-dial,
        bounded by the connection cap."""
        connected = {self.conn_ids[n] for n in self._peers()
                     if n in self.conn_ids}
        room = self.max_peers - len(self._peers())
        if room <= 0:
            return []
        return self.peerbook.select(
            room, exclude=connected | self._dialing)

    def note_dialing(self, node_id: int) -> None:
        self._dialing.add(node_id)

    def note_dial_failed(self, node_id: int) -> None:
        self._dialing.discard(node_id)
        self.peerbook.mark_failed(node_id)

    def on_dialed(self, conn: str, addr: PeerAddr) -> None:
        """A dial to ``addr`` produced connection ``conn``: introduce
        ourselves and promote the addr to the tried bucket.  The first
        ``n_anchors`` outbound dials become **anchor** connections —
        endpoints this peer chose (not ones gossip pushed at it), so
        an addr-flooding adversary cannot occupy them."""
        self._dialing.discard(addr.node_id)
        self.conn_ids[conn] = addr.node_id
        self.peerbook.mark_connected(addr.node_id)
        if len(self.anchor_ids) < self.n_anchors:
            self.anchor_ids.add(addr.node_id)
        self._note_conn(conn)
        self._helloed.add(conn)
        self._send(conn, self.hello(observed=self._observed_of(conn)))

    # -- body store ---------------------------------------------------
    def _remember_body(self, ck: bytes, body: bytes) -> None:
        self._bodies[ck] = body
        self._bodies.move_to_end(ck)
        while len(self._bodies) > self.max_bodies:
            self._bodies.popitem(last=False)

    def _lookup_body(self, ck: bytes) -> Optional[bytes]:
        """Serve a body by content checksum: the hot store first, then
        a scan over the node's retained journal/evidence payloads
        (indexing them as it goes)."""
        body = self._bodies.get(ck)
        if body is not None:
            return body
        found = None
        for payload in self.node.chain_payloads():
            if payload is None:
                continue
            b = encode_payload(payload)
            c = hashlib.sha256(b).digest()[:16]
            self._remember_body(c, b)
            if c == ck:
                found = b
        return found

    def _ck_of_height(self, height: int) -> bytes:
        payload = self.node._payloads.get(height)
        if payload is None:
            return _ZERO_CK                # pruned at finalization
        body = encode_payload(payload)
        ck = hashlib.sha256(body).digest()[:16]
        self._remember_body(ck, body)
        return ck

    # -- outbound -----------------------------------------------------
    def hello(self, observed: Optional[Tuple[str, int]] = None) -> Hello:
        return Hello(version=PROTOCOL_VERSION,
                     node_id=self.identity.node_id,
                     pubkey=self.identity.pubkey,
                     height=self.node.ledger.height,
                     addr=self.addr,
                     observed=observed)

    def _observed_of(self, conn: str) -> Optional[Tuple[str, int]]:
        """The endpoint we see ``conn`` arriving from (observed-address
        feedback: echoed back in our HELLO so a NATed peer learns how
        the world routes to it)."""
        if self.port is not None and hasattr(self.port, "peer_endpoint"):
            return self.port.peer_endpoint(conn)
        return None

    def broadcast_hello(self) -> None:
        for dst in self._peers():
            self._helloed.add(dst)
            self._send(dst, self.hello(observed=self._observed_of(dst)))

    def _gossip_addrs(self, dst: str) -> None:
        """Send everything the book knows to one (new) connection —
        once per conn, chunked at the per-message cap."""
        if dst in self._addr_sent:
            return
        self._addr_sent.add(dst)
        known = self.peerbook.known()
        if self.addr is not None:
            known = [self.addr] + known
        for i in range(0, len(known), MAX_ADDRS):
            self._send(dst, Addr(addrs=tuple(known[i:i + MAX_ADDRS])))

    def _relay_addr(self, addr: PeerAddr, exclude: str) -> None:
        """Flood one newly learned addr to every other connection
        (each addr is relayed at most once — ``PeerBook.add`` returns
        True only on first admission)."""
        m = Addr(addrs=(addr,))
        for dst in self._peers():
            if dst != exclude:
                self._send(dst, m)

    def _admit_addr(self, src: str, addr: PeerAddr, *,
                    claimed_id: Optional[int] = None) -> None:
        """One addr record from HELLO or ADDR gossip: fast-path exact
        duplicates (no re-verification), verify + admit the rest, relay
        genuinely new knowledge, and score forged records.  Third-party
        gossip is charged against the relaying identity's PeerBook
        quota (eclipse defense); a peer's own HELLO addr is first-hand
        and uncharged."""
        self.stats.addrs_recv += 1
        if addr.node_id == self.identity.node_id:
            return                         # our own addr echoed back
        if self.peerbook.has_exact(addr):
            return                         # already known: no crypto
        if claimed_id is not None and addr.node_id != claimed_id:
            # a HELLO advertising someone else's addr as its own
            self.stats.addr_rejects += 1
            self._punish(src, "invalid_frames")
            return
        if not addr.verify(self.peerbook.keyring or self.keyring):
            self.stats.addr_rejects += 1
            self._punish(src, "invalid_frames")
            return
        first_hand = (claimed_id is not None
                      and addr.node_id == claimed_id)
        source = None if first_hand else self.conn_ids.get(src, -1)
        if self.peerbook.add(addr, verified=True, source=source):
            self.stats.addrs_added += 1
            self._relay_addr(addr, exclude=src)

    def _note_observed(self, src: str, endpoint: Tuple[str, int]) -> None:
        """A peer echoed where our connection appears to come from.
        With no configured self-addr, collect the echoes; once
        ``min_observed`` *distinct* peers agree on an endpoint, sign
        it as our own ``PeerAddr`` — one lying peer cannot steer the
        adoption.  ``listen_port`` replaces the observed source port
        (ephemeral on real TCP); the observed host is the routable
        part."""
        self.stats.observed_echoes += 1
        if self.addr is not None:
            return                         # already know who we are
        host = endpoint[0]
        port = self.listen_port if self.listen_port else endpoint[1]
        if not (0 < port < 65536):
            return
        reporter = self.conn_ids.get(src, src)
        reporters = self._observed.setdefault((host, port), set())
        reporters.add(reporter)
        if len(reporters) >= self.min_observed:
            self.addr = make_addr(self.identity, host, port)
            self.stats.addrs_adopted += 1

    def mine_and_announce(self, workload: Optional[str] = None
                          ) -> BlockReceipt:
        """Mine one block on the wrapped node and announce it to every
        peer — compact (header + checksum) or full-body per config."""
        receipt = self.node.mine_block(workload)
        block = receipt.record.to_block()
        body = encode_payload(receipt.payload)
        sa = make_announce(self.identity, block, receipt.payload)
        self._remember_body(sa.checksum, body)
        ann = Announce(header=sa.header, checksum=sa.checksum,
                       origin=sa.origin, pubkey=sa.pubkey,
                       signature=sa.signature,
                       body=None if self.compact else body)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        for dst in self._peers():
            self._send(dst, ann)
            self.stats.announces_sent += 1
        return receipt

    def _regossip(self, block: Block, ann: Announce,
                  exclude: str) -> None:
        out = ann if not self.compact else dataclasses.replace(
            ann, body=None)
        if not self.compact and out.body is None:
            body = self._bodies.get(ann.checksum)
            if body is not None:
                out = dataclasses.replace(out, body=body)
        for dst in self._peers():
            if dst != exclude:
                self._send(dst, out)
                self.stats.announces_sent += 1

    def _deadline(self, now: float, attempt: int) -> float:
        """Exponential backoff: each failover waits longer before
        declaring the next target silent too."""
        return now + self.request_timeout * (self.backoff ** attempt)

    def _request_sync(self, src: str, *, attempt: int = 0) -> None:
        if src in self._sync or src in self._sync_req:
            return                         # one pull in flight per peer
        self.stats.sync_pulls += 1
        self._sync_req[src] = (self._deadline(self._now(), attempt),
                               attempt)
        self._send(src, GetHeaders(from_height=0))

    # -- inbound dispatch ---------------------------------------------
    def on_message(self, src: str, msg: Message) -> None:
        if src in self._banned_conns:
            return                         # dead to us
        nid = self.conn_ids.get(src)
        if nid is not None and nid in self.peerbook.banned:
            return
        self._note_conn(src)
        self._last_recv[src] = self._now()
        if not isinstance(msg, Pong):
            # any inbound frame proves the peer is processing: an
            # outstanding keepalive probe is satisfied (PONG itself is
            # nonce-checked in its handler)
            self._ping_sent.pop(src, None)
        if isinstance(msg, Hello):
            self._on_hello(src, msg)
        elif isinstance(msg, Addr):
            self._on_addr(src, msg)
        elif isinstance(msg, Announce):
            self._on_announce(src, msg)
        elif isinstance(msg, GetHeaders):
            self._on_get_headers(src, msg)
        elif isinstance(msg, Tip):
            self._on_tip(src, msg)
        elif isinstance(msg, GetBodies):
            self._on_get_bodies(src, msg)
        elif isinstance(msg, Bodies):
            self._on_bodies(src, msg)
        elif isinstance(msg, Ping):
            self._on_ping(src, msg)
        elif isinstance(msg, Pong):
            self._on_pong(src, msg)

    def _on_ping(self, src: str, m: Ping) -> None:
        self._send(src, Pong(nonce=m.nonce))

    def _on_pong(self, src: str, m: Pong) -> None:
        sent = self._ping_sent.pop(src, None)
        if sent is None or sent[0] != m.nonce:
            # an echo nobody asked for, or a stale/forged nonce
            self.stats.unsolicited += 1
            self._punish(src, "unsolicited")
            return
        self.stats.pongs_recv += 1

    def _on_hello(self, src: str, m: Hello) -> None:
        if m.version != PROTOCOL_VERSION:
            self.stats.version_rejects += 1
            self._punish(src, "invalid_frames")
            return
        self.conn_ids[src] = m.node_id
        self.peer_heights[src] = m.height
        if m.node_id in self.peerbook.banned:
            self._ban(src)                 # banned identity redialing
            return
        if m.addr is not None:
            self._admit_addr(src, m.addr, claimed_id=m.node_id)
        if m.observed is not None:
            self._note_observed(src, m.observed)
        if src not in self._helloed:       # introduce ourselves back
            self._helloed.add(src)
            self._send(src, self.hello(observed=self._observed_of(src)))
        self._gossip_addrs(src)            # once per conn
        if self.conn_ids.get(src) == m.node_id:
            self.peerbook.mark_connected(m.node_id)
        if m.height > self.node.ledger.height:
            self._request_sync(src)

    def _on_addr(self, src: str, m: Addr) -> None:
        for addr in m.addrs:
            self._admit_addr(src, addr)    # relayed: charged to src

    def _on_announce(self, src: str, a: Announce) -> None:
        self.stats.announces_recv += 1
        try:
            block = decode_block(a.header)
        except Exception:
            self.stats.malformed += 1
            return
        if self.node.has_block(block.block_hash):
            self.stats.dup_announces += 1
            return
        sa = SignedAnnounce(header=a.header, checksum=a.checksum,
                            origin=a.origin, pubkey=a.pubkey,
                            signature=a.signature)
        if self.keyring is not None and not sa.verify_origin(self.keyring):
            # forged or unsigned origin: dropped before any body fetch
            self.stats.sig_rejects += 1
            return
        body = a.body
        if body is not None:
            if hashlib.sha256(body).digest()[:16] != a.checksum:
                self.stats.malformed += 1
                return
        else:
            body = self._lookup_body(a.checksum)
            if body is not None:
                self.stats.compact_hits += 1    # nothing crosses the wire
        if body is None:
            self._pending[a.checksum] = _PendingBody(
                block=block, ann=a, src=src,
                deadline=self._deadline(self._now(), 0))
            self._pending.move_to_end(a.checksum)
            while len(self._pending) > self.max_pending:
                # bounded in-flight table: the dropped block arrives
                # later via an ordinary chain pull
                self._pending.popitem(last=False)
            self.stats.body_requests += 1
            self._note_asked(src, (a.checksum,))
            self._send(src, GetBodies(checksums=(a.checksum,)))
            return
        self._process(src, block, a, body)

    def _process(self, src: str, block: Block, ann: Announce,
                 body: bytes) -> None:
        """Body in hand: decode, hand to the node's ordinary receive
        path (which re-checks the signature binding against this exact
        payload), fall back to a chain pull on tip mismatch."""
        try:
            payload = decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return
        self._remember_body(ann.checksum, body)
        sa = SignedAnnounce(header=ann.header, checksum=ann.checksum,
                            origin=ann.origin, pubkey=ann.pubkey,
                            signature=ann.signature)
        ok = self.node.receive(block, payload, announce=sa)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        if ok:
            self.stats.blocks_committed += 1
            self._score(src).useful_blocks += 1
            self._regossip(block, ann, exclude=src)
        elif not self.node.has_block(block.block_hash):
            self._request_sync(src)

    def _on_get_headers(self, src: str, g: GetHeaders) -> None:
        if not self._bucket(src, "headers").allow(self._now()):
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return                         # throttled: no reply
        entries = tuple(
            (encode_block(blk), self._ck_of_height(h))
            for h, blk in enumerate(self.node.ledger.blocks)
            if h >= g.from_height)
        self._send(src, Tip(start=g.from_height, entries=entries))

    def _on_tip(self, src: str, t: Tip) -> None:
        req = self._sync_req.pop(src, None)
        attempt = req[1] if req is not None else 0
        self._sync.pop(src, None)
        if t.start != 0:
            return                         # we only ever pull from 0
        if len(t.entries) < self.node.ledger.height:
            # strictly shorter than us: the peer advertised a height it
            # cannot deliver (equality is the honest caught-up-while-
            # pulling race and goes unscored)
            self._punish(src, "stale_tips")
            return
        if len(t.entries) <= self.node.ledger.height:
            return                         # not longer: no fork choice
        try:
            blocks = [decode_block(header) for header, _ in t.entries]
        except Exception:
            self.stats.malformed += 1
            return
        missing = set()
        for i, (_, ck) in enumerate(t.entries):
            if self._have_payload_for(i, blocks[i], ck):
                continue
            if ck == _ZERO_CK:
                return    # sender pruned a body we'd need: can't adopt
            missing.add(ck)
        state = _SyncState(blocks=blocks, entries=t.entries,
                           missing=missing,
                           deadline=self._deadline(self._now(), attempt),
                           attempt=attempt)
        if missing:
            self._sync[src] = state
            self.stats.body_requests += len(missing)
            self._note_asked(src, missing)
            self._send(src, GetBodies(checksums=tuple(sorted(missing))))
            return
        self._finish_sync(src, state)

    def _have_payload_for(self, height: int, block: Block,
                          ck: bytes) -> bool:
        """True iff fork choice at this height needs no wire transfer:
        our own chain holds the identical block (its retained evidence
        substitutes below the fork point) or the body store already
        has the checksum."""
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            return True
        return self._bodies.get(ck) is not None

    def _resolve_payload(self, height: int, block: Block,
                         ck: bytes) -> Optional[BlockPayload]:
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            # common prefix: consider_chain substitutes our evidence
            # anyway; pass it directly (may be None below the floor)
            return self.node._payloads.get(height)
        body = self._bodies.get(ck)
        if body is None:
            return None
        try:
            return decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return None

    def _finish_sync(self, src: str, state: _SyncState) -> None:
        payloads = [self._resolve_payload(i, blk, ck)
                    for i, (blk, (_, ck))
                    in enumerate(zip(state.blocks, state.entries))]
        try:
            ok = self.node.consider_chain(state.blocks, payloads)
        except ChainError:
            self.stats.malformed += 1
            return
        if ok:
            self.stats.reorgs += 1
            self.stats.blocks_committed += 1

    def _on_get_bodies(self, src: str, g: GetBodies) -> None:
        """DoS-hardened body serving: a per-request count cap, a
        token-bucket rate limit charging one token per requested body,
        and an *always-reply* discipline — an admitted request gets a
        ``Bodies`` even when nothing was found, so an honest requester
        holding an unknown or finality-pruned checksum detects the
        miss and falls back to headers-first sync instead of waiting
        forever.  Violations feed the requester's score; a throttled
        request is never served."""
        if len(g.checksums) > self.max_bodies_per_request:
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return
        if not self._bucket(src, "bodies").allow(
                self._now(), cost=float(max(len(g.checksums), 1))):
            self.stats.rate_violations += 1
            self._punish(src, "rate_violations")
            return
        bodies = []
        for ck in g.checksums:
            if ck == _ZERO_CK:
                continue                   # pruned-body sentinel: skip
            body = self._lookup_body(ck)
            if body is not None:
                bodies.append(body)
        self.stats.bodies_served += len(bodies)
        self._send(src, Bodies(bodies=tuple(bodies)))

    def _on_bodies(self, src: str, b: Bodies) -> None:
        asked = self._asked.get(src, collections.OrderedDict())
        got = set()
        for body in b.bodies:
            ck = hashlib.sha256(body).digest()[:16]
            if ck not in asked:
                # a body nobody asked this peer for: unmetered push
                self.stats.unsolicited += 1
                self._punish(src, "unsolicited")
                continue
            asked.pop(ck, None)
            self._remember_body(ck, body)
            got.add(ck)
            self.stats.bodies_recv += 1
            pend = self._pending.pop(ck, None)
            if pend is not None:
                self._process(src, pend.block, pend.ann, body)
        state = self._sync.get(src)
        if state is not None:
            state.missing -= got
            if not state.missing:
                del self._sync[src]
                self._finish_sync(src, state)
            elif not got:
                # the peer answered but could not serve what the sync
                # still needs (unknown/pruned over there): abandon this
                # pull — ordinary announce flow or another peer's
                # headers will cover it
                del self._sync[src]
        # announce-path fetches this reply failed to cover (unknown or
        # pruned on the serving side): drop them and fall back to a
        # headers-first pull from the same peer
        stranded = [ck for ck, pend in self._pending.items()
                    if pend.src == src and ck in asked
                    and ck not in got]
        for ck in stranded:
            self._pending.pop(ck, None)
            asked.pop(ck, None)
        if stranded:
            self._request_sync(src)

    # -- liveness sweep (DESIGN §15) ----------------------------------
    def _next_best_peer(self, exclude=()) -> Optional[str]:
        """The failover target: the best-scored live connection not in
        ``exclude`` (deterministic — score descending, name as the
        tie-break via ``eviction_order``)."""
        cands = [n for n in self._peers() if n not in exclude]
        if not cands:
            return None
        return eviction_order({n: self._score(n) for n in cands})[-1]

    def _expire_pending(self, now: float, alive: set) -> None:
        for ck in list(self._pending):
            ent = self._pending.get(ck)
            if ent is None:
                continue
            if ent.src in alive and ent.deadline > now:
                continue
            # expired — or its connection is gone entirely
            self._pending.pop(ck, None)
            asked = self._asked.get(ent.src)
            if asked is not None:
                asked.pop(ck, None)
            if ent.src in alive:
                self.stats.timeouts += 1
                self._punish(ent.src, "timeouts")
            nxt = self._next_best_peer(exclude={ent.src})
            if nxt is None:
                continue                   # nobody left to ask — drop
            if ent.attempt < self.max_retries:
                attempt = ent.attempt + 1
                self._pending[ck] = dataclasses.replace(
                    ent, src=nxt, attempt=attempt,
                    deadline=self._deadline(now, attempt))
                self.stats.failovers += 1
                self.stats.body_requests += 1
                self._note_asked(nxt, (ck,))
                self._send(nxt, GetBodies(checksums=(ck,)))
            else:
                # retry cap: stop chasing the checksum — a headers-
                # first pull from the best peer recovers the block
                self._request_sync(nxt)

    def _expire_sync(self, now: float, alive: set) -> None:
        for src in list(self._sync_req):
            req = self._sync_req.get(src)
            if req is None:
                continue
            deadline, attempt = req
            if src in alive and deadline > now:
                continue
            self._sync_req.pop(src, None)
            if src in alive:
                self.stats.timeouts += 1
                self._punish(src, "timeouts")
            nxt = self._next_best_peer(exclude={src})
            if nxt is not None and attempt < self.max_retries:
                self.stats.failovers += 1
                self._request_sync(nxt, attempt=attempt + 1)
        for src in list(self._sync):
            state = self._sync.get(src)
            if state is None or (src in alive and state.deadline > now):
                continue
            self._sync.pop(src, None)
            if src in alive:
                self.stats.timeouts += 1
                self._punish(src, "timeouts")
            nxt = self._next_best_peer(exclude={src})
            if nxt is not None and state.attempt < self.max_retries:
                self.stats.failovers += 1
                self._request_sync(nxt, attempt=state.attempt + 1)

    def _keepalive(self, now: float) -> None:
        for conn in list(self._peers()):
            last = self._last_recv.setdefault(conn, now)
            sent = self._ping_sent.get(conn)
            if sent is not None and now - sent[1] >= self.keepalive_timeout:
                # silent past the window: graceful drop, never a hang
                self.stats.keepalive_drops += 1
                nid = self.conn_ids.get(conn)
                if nid is not None:
                    self.peerbook.mark_failed(nid)
                self._disconnect(conn)
                continue
            if sent is None and now - last >= self.ping_interval:
                self._ping_nonce += 1
                self._ping_sent[conn] = (self._ping_nonce, now)
                self.stats.pings_sent += 1
                self._send(conn, Ping(nonce=self._ping_nonce))

    def tick(self, now: Optional[float] = None) -> None:
        """The liveness sweep — drivers call it between pumps
        (loopback) or once per loop iteration (TCP):

        1. expire announce-path body fetches whose deadline passed or
           whose connection vanished: charge the silent peer, re-ask
           the next-best-scored connection with exponential backoff,
           and past ``max_retries`` fall back to a headers-first pull
           (the stranded-checksum bugfix: entries for a dead peer
           re-enter the pull queue instead of leaking);
        2. the same for headers-first pulls (GET_HEADERS awaiting a
           Tip, and Tip-stage pulls awaiting bodies);
        3. keepalive: PING idle connections, disconnect those silent
           past ``keepalive_timeout`` after a probe.

        Never raises, never blocks — graceful degradation only."""
        if now is None:
            now = self._now()
        alive = set(self._peers())
        # sweep solicited-checksum tables of vanished connections so a
        # banned/disconnected peer's entries cannot linger until the
        # max_pending bound evicts them
        for conn in list(self._asked):
            if conn not in alive:
                self._asked.pop(conn, None)
        self._expire_pending(now, alive)
        self._expire_sync(now, alive)
        self._keepalive(now)


# ---------------------------------------------------------------------------
# the N-peer loopback convergence scenario (sim CLI + bench + tests)
# ---------------------------------------------------------------------------

_SUITE_DIMS = dict(sat={"n_vars": 10, "n_clauses": 40},
                   gan={"grid_bits": 8},
                   docking={"n_r": 16, "n_p": 16})
_SUITE_SCHEDULE = ("sat", "gan", "docking", "classic",
                   "sat", "gan", "docking", "sat")


def _suite_node(i: int, *, suite_seed: int = 7,
                classic_arg_bits: int = 6,
                keyring: Optional[KeyRing] = None,
                store: Optional[ChainStore] = None) -> Node:
    """One heterogeneous-suite node (same dims as the sim's
    ``heterogeneous_scenario`` — small enough for CI, every family
    represented).  ``store`` attaches a durable journal (the chaos
    scenarios' crash/restart faults recover from it)."""
    from repro.chain.workloads import default_suite
    return Node(node_id=i, classic_arg_bits=classic_arg_bits,
                workloads=default_suite(seed=suite_seed, **_SUITE_DIMS),
                keyring=keyring, store=store)


def loopback_scenario(n_peers: int = 4, seed: int = 0, *,
                      compact: bool = True,
                      signed: bool = True,
                      drop_prob: float = 0.0,
                      suite_seed: int = 7,
                      schedule: Sequence[str] = _SUITE_SCHEDULE,
                      oracle: bool = True) -> Dict[str, object]:
    """N wire-connected peers mine the heterogeneous workload suite
    round-robin over a deterministic loopback transport, then the
    result is compared bit-for-bit against the in-process ``Network``
    mining the same schedule on the same seeds — tips, ledgers
    (canonical chain digest), and credit books must all be equal.

    Returns a JSON-able report: convergence, oracle parity, bytes on
    wire, and per-peer protocol counters.  ``compact=False`` runs the
    full-body relay baseline the ``wire_relay`` bench compares
    against; ``drop_prob`` exercises retry + pull-based resync."""
    identities, ring = make_identities(n_peers)
    used_ring = ring if signed else None
    hub = LoopbackHub(seed=seed, drop_prob=drop_prob)
    peers: List[PeerNode] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=used_ring)
        pn = PeerNode(node, identities[i], used_ring, compact=compact)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    for pn in peers:
        pn.broadcast_hello()
    hub.pump()
    for b, family in enumerate(schedule):
        peers[b % n_peers].mine_and_announce(family)
        hub.pump()
    # lossy links can strand a peer: height beacons trigger pull resync
    for _ in range(8):
        heights = {pn.node.ledger.height for pn in peers}
        if len(heights) == 1:
            break
        for pn in peers:
            pn.broadcast_hello()
        hub.pump()
    elapsed = time.perf_counter() - t0
    digests = [chain_digest(pn.node) for pn in peers]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in peers]
    converged = (len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in peers))
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "compact": compact,
        "signed": signed,
        "drop_prob": drop_prob,
        "converged": converged,
        "height": peers[0].node.ledger.height,
        "chain_digest": digests[0],
        "bytes_on_wire": hub.total_bytes(),
        "frames_delivered": sum(p.stats.frames_recv
                                for p in hub.ports.values()),
        "quarantined": sum(p.stats.quarantined
                           for p in hub.ports.values()),
        "elapsed_s": round(elapsed, 3),
        "blocks_per_s": round(len(schedule) / elapsed, 3) if elapsed else 0.0,
        "peer_stats": [pn.stats.to_dict() for pn in peers],
    }
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=used_ring),
            identities=identities if signed else None)
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report


# ---------------------------------------------------------------------------
# the N-peer single-seed mesh scenario (discovery + scoring, DESIGN §14)
# ---------------------------------------------------------------------------


def _mesh_complete(peers: List[PeerNode]) -> bool:
    """Every peer is connected to every other (or holds its cap)."""
    want = len(peers) - 1
    return all(len(pn._peers()) >= min(want, pn.max_peers)
               for pn in peers)


def drive_discovery(hub: LoopbackHub, peers: List[PeerNode],
                    *, max_rounds: int = 16) -> int:
    """Deterministic discovery driver for loopback meshes: each round
    pumps gossip, then dials every PeerBook candidate (the loopback
    "address" of node ``i`` is the port name ``peer{i}``).  Returns
    the number of rounds until no peer wants another connection."""
    for rounds in range(1, max_rounds + 1):
        dialed = 0
        for pn in peers:
            for cand in pn.dial_candidates():
                dst = f"peer{cand.node_id}"
                if hub.connect(pn.port.name, dst):
                    pn.on_dialed(dst, cand)
                    dialed += 1
                else:
                    # the other side dialed us first — same link
                    pn.conn_ids.setdefault(dst, cand.node_id)
                    pn.peerbook.mark_connected(cand.node_id)
        hub.pump()
        if not dialed and _mesh_complete(peers):
            return rounds
    return max_rounds


# -- crash/restart/corrupt_store fault events (wire-level recovery) ---------
#
# A fault event is ``(block_idx, kind, peer_idx)`` — or
# ``(block_idx, kind, peer_idx, mode)`` for ``corrupt_store`` — applied
# *before* block ``block_idx`` is mined.  ``crash`` unregisters the
# peer's hub port (frames in flight are lost, links drop, the journal
# survives); ``corrupt_store`` damages the surviving journal's tail;
# ``restart`` replays the journal through ``Node.recover``, registers a
# fresh ``PeerNode`` under the same identity, and re-bootstraps from the
# lowest-numbered live peer — headers-first resync recovers the tail the
# journal lost.  This mirrors the in-process simulator's fault schedule
# (``crash_fault_scenario``), one layer down: here the *wire* is part of
# the recovery path.


def _fault_map(faults: Sequence[Sequence[object]]
               ) -> Dict[int, List[Tuple[object, ...]]]:
    out: Dict[int, List[Tuple[object, ...]]] = {}
    for ev in faults:
        out.setdefault(int(ev[0]), []).append(tuple(ev))
    return out


def _apply_fault(ev: Tuple[object, ...], *, hub: LoopbackHub,
                 peers: List[Optional[PeerNode]],
                 identities: Dict[int, PeerIdentity], ring: KeyRing,
                 stores: List[ChainStore], cap: int, compact: bool,
                 suite_seed: int, liveness: Dict[str, object],
                 recoveries: List[Dict[str, object]],
                 frng: random.Random) -> str:
    kind, idx = str(ev[1]), int(ev[2])
    if kind == "crash":
        if peers[idx] is None:
            raise ValueError(f"fault crashes peer{idx} twice")
        hub.unregister(f"peer{idx}")
        peers[idx] = None
        return f"crash peer{idx}"
    if kind == "corrupt_store":
        mode = str(ev[3]) if len(ev) > 3 else "bitflip"
        what = stores[idx].corrupt_tail(frng, mode)
        return f"corrupt_store peer{idx}: {what or 'nothing to damage'}"
    if kind != "restart":
        raise ValueError(f"unknown fault kind {kind!r}")
    if peers[idx] is not None:
        raise ValueError(f"fault restarts live peer{idx}")
    shell = _suite_node(idx, suite_seed=suite_seed, keyring=ring)
    node = Node.recover(stores[idx], node=shell)
    rec = node.last_recovery
    recoveries.append({"peer": idx, "replayed": rec.replayed,
                       "adopted_height": rec.adopted_height,
                       "truncated_records": rec.truncated_records})
    pn = PeerNode(node, identities[idx], ring, compact=compact,
                  addr=make_addr(identities[idx], "loopback", 9000 + idx),
                  max_peers=cap, **liveness)
    pn.attach(hub.register(f"peer{idx}"))
    peers[idx] = pn
    # re-bootstrap: dial the lowest-numbered live peer (a fresh anchor),
    # then beacon heights both ways so headers-first resync starts now
    reseed = next((j for j, p in enumerate(peers)
                   if p is not None and j != idx), None)
    if reseed is not None:
        seed_addr = make_addr(identities[reseed], "loopback", 9000 + reseed)
        pn.peerbook.add(seed_addr, verified=True)
        if hub.connect(f"peer{idx}", f"peer{reseed}"):
            pn.on_dialed(f"peer{reseed}", seed_addr)
    hub.pump()
    for other in peers:
        if other is not None:
            other.broadcast_hello()
    hub.pump()
    return (f"restart peer{idx}: replayed={rec.replayed} "
            f"adopted={rec.adopted_height} resynced={rec.resynced_height}")


def _settle(hub: LoopbackHub, peers: List[Optional[PeerNode]], *,
            rounds: int, tick_dt: float) -> int:
    """Height-beacon rounds (hello + pump + advance + tick) until every
    live peer reports one height; returns the rounds it took."""
    for r in range(rounds):
        live = [pn for pn in peers if pn is not None]
        if len({pn.node.ledger.height for pn in live}) <= 1:
            return r
        for pn in live:
            pn.broadcast_hello()
        hub.pump()
        hub.advance(tick_dt)
        for pn in live:
            pn.tick()
        hub.pump()
    return rounds


def mesh_scenario(n_peers: int = 5, seed: int = 0, *,
                  compact: bool = True,
                  drop_prob: float = 0.0,
                  suite_seed: int = 7,
                  schedule: Sequence[str] = _SUITE_SCHEDULE,
                  oracle: bool = True,
                  max_peers: Optional[int] = None,
                  max_rounds: int = 16,
                  faults: Sequence[Sequence[object]] = (),
                  tick_dt: float = 1.0) -> Dict[str, object]:
    """N peers bootstrapped from a **single seed address**: every peer
    starts linked only to ``peer0``, learns the rest of the mesh from
    HELLO addr payloads and ADDR gossip, dials it full, then mines the
    heterogeneous suite round-robin — and must still reconverge
    bit-identically with the in-process ``Network`` oracle (tips,
    ledgers, credit books).  The report adds discovery metrics (rounds
    and wall-clock to full mesh — the ``mesh_discovery`` bench row)
    and per-peer score/book state.

    ``faults`` injects crash/restart/corrupt_store events keyed by
    block index (see ``_apply_fault``): every peer then journals to a
    ``ChainStore`` and each mined block is followed by one simulated
    second (``tick_dt``) plus a liveness sweep, so pulls targeted at a
    crashed peer time out and fail over instead of stranding."""
    identities, ring = make_identities(n_peers)
    hub = LoopbackHub(seed=seed, drop_prob=drop_prob, full_mesh=False)
    cap = max_peers if max_peers is not None else n_peers + 2
    fmap = _fault_map(faults)
    frng = random.Random(seed ^ 0x5DEECE66)
    stores = [ChainStore() for _ in range(n_peers)]
    recoveries: List[Dict[str, object]] = []
    fault_log: List[str] = []
    peers: List[Optional[PeerNode]] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=ring,
                           store=stores[i] if faults else None)
        pn = PeerNode(node, identities[i], ring, compact=compact,
                      addr=make_addr(identities[i], "loopback", 9000 + i),
                      max_peers=cap)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    # single-seed bootstrap: the only links are peer{i} -> peer0
    for i in range(1, n_peers):
        hub.connect(f"peer{i}", "peer0")
        peers[i].conn_ids["peer0"] = 0
        peers[i].broadcast_hello()
    hub.pump()
    rounds = drive_discovery(hub, peers, max_rounds=max_rounds)
    discovery_s = time.perf_counter() - t0
    full_mesh = _mesh_complete(peers)
    # mine the suite round-robin over the discovered topology
    for b, family in enumerate(schedule):
        for ev in fmap.get(b, ()):
            fault_log.append(_apply_fault(
                ev, hub=hub, peers=peers, identities=identities,
                ring=ring, stores=stores, cap=cap, compact=compact,
                suite_seed=suite_seed, liveness={},
                recoveries=recoveries, frng=frng))
        miner = peers[b % n_peers]
        if miner is None:
            raise ValueError(
                f"fault schedule leaves block-{b} miner peer{b % n_peers} "
                "crashed — restart it before its round-robin turn")
        miner.mine_and_announce(family)
        hub.pump()
        if faults:
            hub.advance(tick_dt)
            for pn in peers:
                if pn is not None:
                    pn.tick()
            hub.pump()
    _settle(hub, peers, rounds=8, tick_dt=tick_dt)
    elapsed = time.perf_counter() - t0
    live = [pn for pn in peers if pn is not None]
    digests = [chain_digest(pn.node) for pn in live]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in live]
    converged = (len(live) == n_peers
                 and len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in live))
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "compact": compact,
        "drop_prob": drop_prob,
        "converged": converged,
        "full_mesh": full_mesh,
        "discovery_rounds": rounds,
        "discovery_s": round(discovery_s, 4),
        "links": {pn.port.name: pn.port.peer_names() for pn in live},
        "height": live[0].node.ledger.height,
        "chain_digest": digests[0],
        "bytes_on_wire": hub.total_bytes(),
        "addrs_added": sum(pn.stats.addrs_added for pn in live),
        "elapsed_s": round(elapsed, 3),
        "peer_stats": [pn.stats.to_dict() for pn in live],
        "peerbooks": [pn.peerbook.to_dict() for pn in live],
    }
    if faults:
        report["faults"] = fault_log
        report["recoveries"] = recoveries
        report["n_alive"] = len(live)
        report["timeouts"] = sum(pn.stats.timeouts for pn in live)
        report["failovers"] = sum(pn.stats.failovers for pn in live)
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=ring),
            identities=identities)
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report


# ---------------------------------------------------------------------------
# the eclipse adversary + the all-faults chaos scenario (DESIGN §15)
# ---------------------------------------------------------------------------


class EclipseAttacker:
    """A sybil fleet trying to monopolize a victim's connections.

    Each attacker identity registers a hub port under its canonical
    loopback name (``peer{node_id}``), so victim dials of gossiped
    attacker addrs land on the adversary.  The attack surface, in
    rising order of subtlety:

    * **addr flood** — every sybil connection pushes the whole fleet's
      self-signed addrs (a 10:1 flood at the default ratio), trying to
      fill the victim's ``PeerBook`` new bucket and win every future
      dial.  Countered by the book's per-source quota.
    * **bait-and-starve** — sybils HELLO with an enormous fake height
      to capture the victim's headers-first pulls, then never answer a
      GET: each pull burns a deadline.  Countered by the liveness
      sweep (timeout -> score -> failover to the next-best peer).
    * **keepalive mimicry** — sybils answer PING with a well-formed
      PONG, so naive keepalive never drops them.  This is deliberate:
      the defense the scenario pins is anchors + quotas + timeout
      scoring, not "attackers forget to pong".

    The one thing the adversary can never do is evict an **anchor**:
    connection-cap eviction skips ``anchor_ids``, so a victim whose
    first dial was honest keeps that link no matter the flood."""

    def __init__(self, hub: LoopbackHub,
                 identities: Sequence[PeerIdentity], *,
                 host: str = "attacker", base_port: int = 19000,
                 bait_height: int = 1_000_000) -> None:
        self.hub = hub
        self.identities = list(identities)
        self.bait_height = bait_height
        self.addrs = [make_addr(ident, host, base_port + k)
                      for k, ident in enumerate(self.identities)]
        self.ports: Dict[str, object] = {}
        self._ident_of: Dict[str, Tuple[PeerIdentity, PeerAddr]] = {}
        self._flooded: set = set()
        self.stats = {"conns": 0, "hellos_recv": 0, "pings_answered": 0,
                      "pulls_starved": 0, "addr_frames": 0}
        for ident, addr in zip(self.identities, self.addrs):
            name = f"peer{ident.node_id}"
            port = hub.register(name)
            port.on_message = self._handler(name)
            self.ports[name] = port
            self._ident_of[name] = (ident, addr)

    def _handler(self, name: str):
        return lambda src, msg: self.on_message(name, src, msg)

    def _hello(self, name: str) -> Hello:
        ident, addr = self._ident_of[name]
        return Hello(version=PROTOCOL_VERSION, node_id=ident.node_id,
                     pubkey=ident.pubkey, height=self.bait_height,
                     addr=addr)

    def engage(self, victim: str, n_conns: int = 2) -> int:
        """Open ``n_conns`` direct links to the victim, introduce those
        sybils, and flood the fleet's addrs; returns links opened."""
        opened = 0
        for name in list(self.ports)[:n_conns]:
            if self.hub.connect(name, victim):
                opened += 1
                self.stats["conns"] += 1
                self.ports[name].send(victim, self._hello(name))
                self.flood(name, victim)
        return opened

    def flood(self, src_name: str, dst: str) -> None:
        for i in range(0, len(self.addrs), MAX_ADDRS):
            self.ports[src_name].send(
                dst, Addr(addrs=tuple(self.addrs[i:i + MAX_ADDRS])))
            self.stats["addr_frames"] += 1

    def on_message(self, name: str, src: str, msg: Optional[Message]
                   ) -> None:
        if isinstance(msg, Hello):
            self.stats["hellos_recv"] += 1
            self.ports[name].send(src, self._hello(name))
            if (name, src) not in self._flooded:
                self._flooded.add((name, src))
                self.flood(name, src)
        elif isinstance(msg, Ping):
            self.stats["pings_answered"] += 1
            self.ports[name].send(src, Pong(nonce=msg.nonce))
        elif isinstance(msg, (GetHeaders, GetBodies)):
            # the starvation half of bait-and-starve: dead silence
            self.stats["pulls_starved"] += 1


_CHAOS_SCHEDULE = ("classic", "sat", "classic", "gan", "classic",
                   "classic", "sat", "classic", "gan", "classic",
                   "classic", "sat", "classic", "gan", "classic")

_CHAOS_FAULTS = ((3, "crash", 2), (3, "corrupt_store", 2),
                 (6, "restart", 2),
                 (9, "crash", 3), (12, "restart", 3))


def mesh_chaos_scenario(n_peers: int = 5, seed: int = 0, *,
                        compact: bool = True,
                        suite_seed: int = 7,
                        schedule: Sequence[str] = _CHAOS_SCHEDULE,
                        faults: Sequence[Sequence[object]] = _CHAOS_FAULTS,
                        oracle: bool = True,
                        max_peers: Optional[int] = None,
                        attacker_ratio: int = 10,
                        n_attacker_conns: int = 2,
                        corrupt_frames_per_block: int = 1,
                        victim: int = 1,
                        max_rounds: int = 16,
                        tick_dt: float = 1.0) -> Dict[str, object]:
    """Everything at once, one seed: an N-peer single-seed mesh mines
    the suite while peers **crash** (port unregistered, frames in
    flight lost), their journals get **corrupted**, they **restart**
    through ``Node.recover`` + headers-first wire resync, an
    ``EclipseAttacker`` with ``attacker_ratio * n_peers`` sybil
    identities floods addr gossip and bait-and-starves the victim, and
    every block a **corrupted frame** is injected at an honest port —
    and the honest mesh must still reconverge with a chain digest
    byte-identical to the in-process ``Network`` oracle mining the
    same schedule.

    The acceptance surface (``test_net_chaos`` pins it): ``converged``
    and ``oracle_match`` true, every crash recovered, the victim holds
    at least one honest **anchor** connection at the end, and no
    gossip source ever charged the victim's book past its per-source
    quota (a dial-confirmed first-hand addr is uncharged by design —
    admitting a peer who just proved its identity is not a flood)."""
    n_att = attacker_ratio * n_peers
    identities, ring = make_identities(n_peers + n_att)
    hub = LoopbackHub(seed=seed, full_mesh=False)
    frng = random.Random(seed ^ 0x0DDBA11)
    cap = max_peers if max_peers is not None else n_peers + 2
    # tight liveness windows on the simulated clock: one block == one
    # second, so a starved pull fails over within a block or two
    liveness: Dict[str, object] = dict(
        request_timeout=1.0, max_retries=3, backoff=2.0,
        ping_interval=2.0, keepalive_timeout=4.0, n_anchors=2)
    stores = [ChainStore() for _ in range(n_peers)]
    recoveries: List[Dict[str, object]] = []
    fault_log: List[str] = []
    fmap = _fault_map(faults)
    peers: List[Optional[PeerNode]] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=ring,
                           store=stores[i])
        pn = PeerNode(node, identities[i], ring, compact=compact,
                      addr=make_addr(identities[i], "loopback", 9000 + i),
                      max_peers=cap, **liveness)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    # single-seed bootstrap through the *dial* path, so peer0 becomes
    # every peer's first anchor — the honest link eviction cannot touch
    seed_addr = make_addr(identities[0], "loopback", 9000)
    for i in range(1, n_peers):
        peers[i].peerbook.add(seed_addr, verified=True)
        if hub.connect(f"peer{i}", "peer0"):
            peers[i].on_dialed("peer0", seed_addr)
    hub.pump()
    # the adversary engages the victim *before* discovery fills the
    # mesh — the flood is in the book when dial selection happens
    attacker = EclipseAttacker(
        hub, [identities[n_peers + k] for k in range(n_att)])
    attacker.engage(f"peer{victim}", n_conns=n_attacker_conns)
    hub.pump()
    rounds = drive_discovery(hub, peers, max_rounds=max_rounds)
    # chaos loop: faults before the block, one corrupted frame per
    # block, one simulated second + liveness sweep after it
    for b, family in enumerate(schedule):
        for ev in fmap.get(b, ()):
            fault_log.append(_apply_fault(
                ev, hub=hub, peers=peers, identities=identities,
                ring=ring, stores=stores, cap=cap, compact=compact,
                suite_seed=suite_seed, liveness=liveness,
                recoveries=recoveries, frng=frng))
        miner = peers[b % n_peers]
        if miner is None:
            raise ValueError(
                f"fault schedule leaves block-{b} miner peer{b % n_peers} "
                "crashed — restart it before its round-robin turn")
        for k in range(corrupt_frames_per_block):
            tgt = f"peer{(b + k) % n_peers}"
            if tgt in hub.ports:
                raw = bytearray(encode_message(Ping(nonce=b * 997 + k)))
                raw[frng.randrange(len(raw))] ^= 1 << frng.randrange(8)
                hub.inject("chaos", tgt, bytes(raw))
        miner.mine_and_announce(family)
        hub.pump()
        hub.advance(tick_dt)
        for pn in peers:
            if pn is not None:
                pn.tick()
        hub.pump()
    settle_rounds = _settle(hub, peers, rounds=12, tick_dt=tick_dt)
    elapsed = time.perf_counter() - t0
    live = [pn for pn in peers if pn is not None]
    digests = [chain_digest(pn.node) for pn in live]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in live]
    converged = (len(live) == n_peers
                 and len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in live))
    vic = peers[victim]
    vic_conns = vic._peers() if vic is not None else []
    honest_conns = [c for c in vic_conns
                    if 0 <= vic.conn_ids.get(c, -1) < n_peers]
    attacker_conns = [c for c in vic_conns
                      if vic.conn_ids.get(c, -1) >= n_peers]
    honest_anchors = ([nid for nid in vic.anchor_ids
                       if nid < n_peers and f"peer{nid}" in vic_conns]
                      if vic is not None else [])
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_attackers": n_att,
        "n_blocks": len(schedule),
        "converged": converged,
        "n_alive": len(live),
        "height": live[0].node.ledger.height if live else 0,
        "chain_digest": digests[0] if digests else "",
        "discovery_rounds": rounds,
        "settle_rounds": settle_rounds,
        "faults": fault_log,
        "recoveries": recoveries,
        "victim": {
            "peer": victim,
            "honest_conns": len(honest_conns),
            "attacker_conns": len(attacker_conns),
            "honest_anchors": len(honest_anchors),
            "attacker_addrs_admitted": sum(
                1 for a in vic.peerbook.known()
                if a.node_id >= n_peers) if vic is not None else 0,
            "per_source_quota": (vic.peerbook.max_new_per_source
                                 if vic is not None else 0),
            "max_source_charge": (max(collections.Counter(
                vic.peerbook.sources.values()).values(), default=0)
                                  if vic is not None else 0),
        },
        "attacker": dict(attacker.stats),
        "timeouts": sum(pn.stats.timeouts for pn in live),
        "failovers": sum(pn.stats.failovers for pn in live),
        "keepalive_drops": sum(pn.stats.keepalive_drops for pn in live),
        "bans": sum(pn.stats.bans for pn in live),
        "quarantined": sum(p.stats.quarantined
                           for p in hub.ports.values()),
        "bytes_on_wire": hub.total_bytes(),
        "elapsed_s": round(elapsed, 3),
    }
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=ring),
            identities={i: identities[i] for i in range(n_peers)})
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report
