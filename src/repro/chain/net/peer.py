"""``repro.chain.net.peer`` — ``PeerNode``: an unmodified ``Node``
driven over a wire.

``PeerNode`` is sans-IO protocol logic: it consumes typed messages
from any transport port (loopback or TCP — ``attach`` wires the
callback) and sends replies through the same port.  The consensus
object underneath is a stock ``Node`` — nothing about mining,
verification, fork choice, finality, or the journal changes when a
node goes out-of-process; that is the whole point of the oracle test
(wire-connected peers must reconverge bit-identically with the
in-process ``Network``).

Compact relay (BIP-152 shaped, DESIGN.md §13): a freshly mined block
is announced as *header + payload content checksum + origin
signature*.  A receiver that already holds the body (from an earlier
announce, a sync, or its own chain evidence) commits without fetching
— already-seen payloads never cross the wire twice; otherwise it
fetches the body by checksum (``GET_BODIES``/``BODIES``, served from
the announcer's body store with a fallback scan over its journal/
evidence payloads).  An announce that does not extend the local tip
triggers a chain pull (``GET_HEADERS``/``TIP``) and ``Node.
consider_chain`` fork choice, substituting locally held bodies per
checksum so only the genuinely missing ones are transferred.

``loopback_scenario`` is the N-peer deterministic convergence harness
(the sim CLI's ``--scenario wire`` and the ``wire_relay`` bench run
it); the two-OS-process TCP flavor lives in ``__main__``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.chain.net.identity import (KeyRing, PeerIdentity, SignedAnnounce,
                                      make_announce, make_identities)
from repro.chain.net.messages import (PROTOCOL_VERSION, Announce, Bodies,
                                      GetBodies, GetHeaders, Hello, Message,
                                      Tip)
from repro.chain.net.transport import LoopbackHub
from repro.chain.node import BlockReceipt, Node
from repro.chain.store import (collect_jash_fns, decode_block, decode_payload,
                               encode_block, encode_payload,
                               payload_checksum)
from repro.chain.workload import BlockPayload, ChainError
from repro.core.ledger import Block

__all__ = [
    "PeerNode",
    "PeerStats",
    "chain_digest",
    "loopback_scenario",
]

_ZERO_CK = b"\x00" * 16          # "body pruned at finalization" sentinel


def chain_digest(node: Node) -> str:
    """Canonical digest of a node's whole chain: SHA-256 over the
    concatenated ``encode_block`` bytes, genesis -> tip.  Two nodes
    share a digest iff their ledgers are bit-identical under the
    canonical (timestamp-free) encoding — the oracle-parity
    comparison."""
    h = hashlib.sha256()
    for blk in node.ledger.blocks:
        h.update(encode_block(blk))
    return h.hexdigest()


@dataclasses.dataclass
class PeerStats:
    """Protocol-level counters for one ``PeerNode`` (the transport's
    ``WireStats`` counts bytes; this counts decisions)."""
    announces_sent: int = 0
    announces_recv: int = 0
    dup_announces: int = 0
    sig_rejects: int = 0          # forged/unsigned origin, bad binding
    malformed: int = 0            # undecodable header/body content
    compact_hits: int = 0         # body already held — nothing fetched
    body_requests: int = 0
    bodies_served: int = 0
    bodies_recv: int = 0
    sync_pulls: int = 0
    reorgs: int = 0
    blocks_committed: int = 0
    version_rejects: int = 0

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _SyncState:
    blocks: List[Block]
    entries: Tuple[Tuple[bytes, bytes], ...]
    missing: set


class PeerNode:
    """Drives one unmodified ``Node`` over a transport port.

    ``identity`` signs this peer's own announces; ``keyring`` (shared
    out of band) verifies everyone's.  When the underlying node has no
    keyring of its own it adopts this one, so ``Node.receive`` applies
    the identical signature rule the in-process ``Network`` uses —
    origin binding is enforced once, in the node, not per transport.
    ``keyring=None`` runs unsigned (announces still carry the origin's
    key, receivers just don't require a registered one).

    ``compact=True`` announces header+checksum and serves bodies on
    demand; ``compact=False`` inlines every body (the bandwidth
    baseline the ``wire_relay`` bench compares against)."""

    def __init__(self, node: Node, identity: PeerIdentity,
                 keyring: Optional[KeyRing] = None, *,
                 compact: bool = True,
                 jash_fns: Optional[Dict[str, object]] = None,
                 max_bodies: int = 4096) -> None:
        if keyring is None:
            keyring = getattr(node, "keyring", None)
        elif node.keyring is None:
            node.keyring = keyring      # one rule: the node enforces it
        self.node = node
        self.identity = identity
        self.keyring = keyring
        self.compact = compact
        self.stats = PeerStats()
        self.port = None
        self._fns = collect_jash_fns(node.workloads, jash_fns)
        # checksum -> canonical body bytes: own mined payloads, fetched
        # bodies, and lazily indexed journal/evidence payloads.  LRU-
        # bounded; the node's own evidence store remains the fallback.
        self._bodies: "collections.OrderedDict[bytes, bytes]" = \
            collections.OrderedDict()
        self.max_bodies = max_bodies
        # block hash -> original signed announce (re-gossip relays the
        # miner's signature; re-signing would break origin binding)
        self._anns: Dict[str, Announce] = {}
        # checksum -> (block, announce, src) awaiting its body
        self._pending: Dict[bytes, Tuple[Block, Announce, str]] = {}
        self._sync: Dict[str, _SyncState] = {}
        self.peer_heights: Dict[str, int] = {}

    # -- wiring -------------------------------------------------------
    def attach(self, port) -> None:
        """Connect to a transport port (``LoopbackPort``/
        ``TcpTransport``): its messages flow into ``on_message``."""
        self.port = port
        port.on_message = self.on_message

    def _peers(self) -> List[str]:
        return self.port.peer_names() if self.port is not None else []

    def _send(self, dst: str, msg: Message) -> None:
        if self.port is not None:
            self.port.send(dst, msg)

    # -- body store ---------------------------------------------------
    def _remember_body(self, ck: bytes, body: bytes) -> None:
        self._bodies[ck] = body
        self._bodies.move_to_end(ck)
        while len(self._bodies) > self.max_bodies:
            self._bodies.popitem(last=False)

    def _lookup_body(self, ck: bytes) -> Optional[bytes]:
        """Serve a body by content checksum: the hot store first, then
        a scan over the node's retained journal/evidence payloads
        (indexing them as it goes)."""
        body = self._bodies.get(ck)
        if body is not None:
            return body
        found = None
        for payload in self.node.chain_payloads():
            if payload is None:
                continue
            b = encode_payload(payload)
            c = hashlib.sha256(b).digest()[:16]
            self._remember_body(c, b)
            if c == ck:
                found = b
        return found

    def _ck_of_height(self, height: int) -> bytes:
        payload = self.node._payloads.get(height)
        if payload is None:
            return _ZERO_CK                # pruned at finalization
        body = encode_payload(payload)
        ck = hashlib.sha256(body).digest()[:16]
        self._remember_body(ck, body)
        return ck

    # -- outbound -----------------------------------------------------
    def hello(self) -> Hello:
        return Hello(version=PROTOCOL_VERSION,
                     node_id=self.identity.node_id,
                     pubkey=self.identity.pubkey,
                     height=self.node.ledger.height)

    def broadcast_hello(self) -> None:
        m = self.hello()
        for dst in self._peers():
            self._send(dst, m)

    def mine_and_announce(self, workload: Optional[str] = None
                          ) -> BlockReceipt:
        """Mine one block on the wrapped node and announce it to every
        peer — compact (header + checksum) or full-body per config."""
        receipt = self.node.mine_block(workload)
        block = receipt.record.to_block()
        body = encode_payload(receipt.payload)
        sa = make_announce(self.identity, block, receipt.payload)
        self._remember_body(sa.checksum, body)
        ann = Announce(header=sa.header, checksum=sa.checksum,
                       origin=sa.origin, pubkey=sa.pubkey,
                       signature=sa.signature,
                       body=None if self.compact else body)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        for dst in self._peers():
            self._send(dst, ann)
            self.stats.announces_sent += 1
        return receipt

    def _regossip(self, block: Block, ann: Announce,
                  exclude: str) -> None:
        out = ann if not self.compact else dataclasses.replace(
            ann, body=None)
        if not self.compact and out.body is None:
            body = self._bodies.get(ann.checksum)
            if body is not None:
                out = dataclasses.replace(out, body=body)
        for dst in self._peers():
            if dst != exclude:
                self._send(dst, out)
                self.stats.announces_sent += 1

    def _request_sync(self, src: str) -> None:
        if src in self._sync:
            return                         # one pull in flight per peer
        self.stats.sync_pulls += 1
        self._send(src, GetHeaders(from_height=0))

    # -- inbound dispatch ---------------------------------------------
    def on_message(self, src: str, msg: Message) -> None:
        if isinstance(msg, Hello):
            self._on_hello(src, msg)
        elif isinstance(msg, Announce):
            self._on_announce(src, msg)
        elif isinstance(msg, GetHeaders):
            self._on_get_headers(src, msg)
        elif isinstance(msg, Tip):
            self._on_tip(src, msg)
        elif isinstance(msg, GetBodies):
            self._on_get_bodies(src, msg)
        elif isinstance(msg, Bodies):
            self._on_bodies(src, msg)

    def _on_hello(self, src: str, m: Hello) -> None:
        if m.version != PROTOCOL_VERSION:
            self.stats.version_rejects += 1
            return
        self.peer_heights[src] = m.height
        if m.height > self.node.ledger.height:
            self._request_sync(src)

    def _on_announce(self, src: str, a: Announce) -> None:
        self.stats.announces_recv += 1
        try:
            block = decode_block(a.header)
        except Exception:
            self.stats.malformed += 1
            return
        if self.node.has_block(block.block_hash):
            self.stats.dup_announces += 1
            return
        sa = SignedAnnounce(header=a.header, checksum=a.checksum,
                            origin=a.origin, pubkey=a.pubkey,
                            signature=a.signature)
        if self.keyring is not None and not sa.verify_origin(self.keyring):
            # forged or unsigned origin: dropped before any body fetch
            self.stats.sig_rejects += 1
            return
        body = a.body
        if body is not None:
            if hashlib.sha256(body).digest()[:16] != a.checksum:
                self.stats.malformed += 1
                return
        else:
            body = self._lookup_body(a.checksum)
            if body is not None:
                self.stats.compact_hits += 1    # nothing crosses the wire
        if body is None:
            self._pending[a.checksum] = (block, a, src)
            self.stats.body_requests += 1
            self._send(src, GetBodies(checksums=(a.checksum,)))
            return
        self._process(src, block, a, body)

    def _process(self, src: str, block: Block, ann: Announce,
                 body: bytes) -> None:
        """Body in hand: decode, hand to the node's ordinary receive
        path (which re-checks the signature binding against this exact
        payload), fall back to a chain pull on tip mismatch."""
        try:
            payload = decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return
        self._remember_body(ann.checksum, body)
        sa = SignedAnnounce(header=ann.header, checksum=ann.checksum,
                            origin=ann.origin, pubkey=ann.pubkey,
                            signature=ann.signature)
        ok = self.node.receive(block, payload, announce=sa)
        self._anns[block.block_hash] = dataclasses.replace(ann, body=None)
        if ok:
            self.stats.blocks_committed += 1
            self._regossip(block, ann, exclude=src)
        elif not self.node.has_block(block.block_hash):
            self._request_sync(src)

    def _on_get_headers(self, src: str, g: GetHeaders) -> None:
        entries = tuple(
            (encode_block(blk), self._ck_of_height(h))
            for h, blk in enumerate(self.node.ledger.blocks)
            if h >= g.from_height)
        self._send(src, Tip(start=g.from_height, entries=entries))

    def _on_tip(self, src: str, t: Tip) -> None:
        self._sync.pop(src, None)
        if t.start != 0:
            return                         # we only ever pull from 0
        if len(t.entries) <= self.node.ledger.height:
            return                         # not longer: no fork choice
        try:
            blocks = [decode_block(header) for header, _ in t.entries]
        except Exception:
            self.stats.malformed += 1
            return
        missing = set()
        for i, (_, ck) in enumerate(t.entries):
            if self._have_payload_for(i, blocks[i], ck):
                continue
            if ck == _ZERO_CK:
                return    # sender pruned a body we'd need: can't adopt
            missing.add(ck)
        state = _SyncState(blocks=blocks, entries=t.entries,
                           missing=missing)
        if missing:
            self._sync[src] = state
            self.stats.body_requests += len(missing)
            self._send(src, GetBodies(checksums=tuple(sorted(missing))))
            return
        self._finish_sync(src, state)

    def _have_payload_for(self, height: int, block: Block,
                          ck: bytes) -> bool:
        """True iff fork choice at this height needs no wire transfer:
        our own chain holds the identical block (its retained evidence
        substitutes below the fork point) or the body store already
        has the checksum."""
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            return True
        return self._bodies.get(ck) is not None

    def _resolve_payload(self, height: int, block: Block,
                         ck: bytes) -> Optional[BlockPayload]:
        ours = (self.node.ledger.blocks[height]
                if height < self.node.ledger.height else None)
        if ours is not None and ours.block_hash == block.block_hash:
            # common prefix: consider_chain substitutes our evidence
            # anyway; pass it directly (may be None below the floor)
            return self.node._payloads.get(height)
        body = self._bodies.get(ck)
        if body is None:
            return None
        try:
            return decode_payload(body, jash_fns=self._fns)
        except Exception:
            self.stats.malformed += 1
            return None

    def _finish_sync(self, src: str, state: _SyncState) -> None:
        payloads = [self._resolve_payload(i, blk, ck)
                    for i, (blk, (_, ck))
                    in enumerate(zip(state.blocks, state.entries))]
        try:
            ok = self.node.consider_chain(state.blocks, payloads)
        except ChainError:
            self.stats.malformed += 1
            return
        if ok:
            self.stats.reorgs += 1
            self.stats.blocks_committed += 1

    def _on_get_bodies(self, src: str, g: GetBodies) -> None:
        bodies = []
        for ck in g.checksums:
            body = self._lookup_body(ck)
            if body is not None:
                bodies.append(body)
        if bodies:
            self.stats.bodies_served += len(bodies)
            self._send(src, Bodies(bodies=tuple(bodies)))

    def _on_bodies(self, src: str, b: Bodies) -> None:
        got = set()
        for body in b.bodies:
            ck = hashlib.sha256(body).digest()[:16]
            self._remember_body(ck, body)
            got.add(ck)
            self.stats.bodies_recv += 1
            pend = self._pending.pop(ck, None)
            if pend is not None:
                block, ann, _ = pend
                self._process(src, block, ann, body)
        state = self._sync.get(src)
        if state is not None:
            state.missing -= got
            if not state.missing:
                del self._sync[src]
                self._finish_sync(src, state)


# ---------------------------------------------------------------------------
# the N-peer loopback convergence scenario (sim CLI + bench + tests)
# ---------------------------------------------------------------------------

_SUITE_DIMS = dict(sat={"n_vars": 10, "n_clauses": 40},
                   gan={"grid_bits": 8},
                   docking={"n_r": 16, "n_p": 16})
_SUITE_SCHEDULE = ("sat", "gan", "docking", "classic",
                   "sat", "gan", "docking", "sat")


def _suite_node(i: int, *, suite_seed: int = 7,
                classic_arg_bits: int = 6,
                keyring: Optional[KeyRing] = None) -> Node:
    """One heterogeneous-suite node (same dims as the sim's
    ``heterogeneous_scenario`` — small enough for CI, every family
    represented)."""
    from repro.chain.workloads import default_suite
    return Node(node_id=i, classic_arg_bits=classic_arg_bits,
                workloads=default_suite(seed=suite_seed, **_SUITE_DIMS),
                keyring=keyring)


def loopback_scenario(n_peers: int = 4, seed: int = 0, *,
                      compact: bool = True,
                      signed: bool = True,
                      drop_prob: float = 0.0,
                      suite_seed: int = 7,
                      schedule: Sequence[str] = _SUITE_SCHEDULE,
                      oracle: bool = True) -> Dict[str, object]:
    """N wire-connected peers mine the heterogeneous workload suite
    round-robin over a deterministic loopback transport, then the
    result is compared bit-for-bit against the in-process ``Network``
    mining the same schedule on the same seeds — tips, ledgers
    (canonical chain digest), and credit books must all be equal.

    Returns a JSON-able report: convergence, oracle parity, bytes on
    wire, and per-peer protocol counters.  ``compact=False`` runs the
    full-body relay baseline the ``wire_relay`` bench compares
    against; ``drop_prob`` exercises retry + pull-based resync."""
    identities, ring = make_identities(n_peers)
    used_ring = ring if signed else None
    hub = LoopbackHub(seed=seed, drop_prob=drop_prob)
    peers: List[PeerNode] = []
    t0 = time.perf_counter()
    for i in range(n_peers):
        node = _suite_node(i, suite_seed=suite_seed, keyring=used_ring)
        pn = PeerNode(node, identities[i], used_ring, compact=compact)
        pn.attach(hub.register(f"peer{i}"))
        peers.append(pn)
    for pn in peers:
        pn.broadcast_hello()
    hub.pump()
    for b, family in enumerate(schedule):
        peers[b % n_peers].mine_and_announce(family)
        hub.pump()
    # lossy links can strand a peer: height beacons trigger pull resync
    for _ in range(8):
        heights = {pn.node.ledger.height for pn in peers}
        if len(heights) == 1:
            break
        for pn in peers:
            pn.broadcast_hello()
        hub.pump()
    elapsed = time.perf_counter() - t0
    digests = [chain_digest(pn.node) for pn in peers]
    books = [tuple(sorted(pn.node.book.balances.items())) for pn in peers]
    converged = (len(set(digests)) == 1 and len(set(books)) == 1
                 and all(pn.node.ledger.verify_chain() for pn in peers))
    report: Dict[str, object] = {
        "n_peers": n_peers,
        "n_blocks": len(schedule),
        "compact": compact,
        "signed": signed,
        "drop_prob": drop_prob,
        "converged": converged,
        "height": peers[0].node.ledger.height,
        "chain_digest": digests[0],
        "bytes_on_wire": hub.total_bytes(),
        "frames_delivered": sum(p.stats.frames_recv
                                for p in hub.ports.values()),
        "quarantined": sum(p.stats.quarantined
                           for p in hub.ports.values()),
        "elapsed_s": round(elapsed, 3),
        "blocks_per_s": round(len(schedule) / elapsed, 3) if elapsed else 0.0,
        "peer_stats": [pn.stats.to_dict() for pn in peers],
    }
    if oracle:
        from repro.chain.network import Network
        net = Network.create(
            n_peers,
            node_factory=lambda i: _suite_node(
                i, suite_seed=suite_seed, keyring=used_ring),
            identities=identities if signed else None)
        net.run(len(schedule), list(schedule))
        oracle_digest = chain_digest(net.nodes[0])
        oracle_books = tuple(sorted(net.nodes[0].book.balances.items()))
        report["oracle_digest"] = oracle_digest
        report["oracle_match"] = bool(
            converged and digests[0] == oracle_digest
            and books[0] == oracle_books)
    return report
