"""``repro.chain.net.peerbook`` — who to dial, who to keep, who to
throttle (DESIGN.md §14).

Three small, deterministic pieces turn the point-to-point PR-7 wire
into an open(able) mesh:

* ``PeerBook`` — the address manager.  Verified ``PeerAddr`` records
  live in two capped buckets, Bitcoin-addrman style: ``new`` (gossip
  we have never connected to) and ``tried`` (endpoints that carried a
  live connection).  Eviction is *deterministic and order-free*: each
  bucket keeps the entries with the smallest salted-hash keys, so the
  retained set depends only on the set of ids ever added — never on
  arrival order — which is what makes discovery reproducible under a
  seeded transport.
* ``PeerScore`` — per-connection behavior ledger.  Useful blocks earn
  credit; invalid frames, unsolicited bodies, stale tips and rate
  violations cost misbehavior points.  ``banned`` trips at a fixed
  misbehavior threshold and is **monotone**: more misbehavior can
  never un-ban a peer (the property test pins this).
* ``TokenBucket`` — the serve-path rate limiter (GET_BODIES /
  GET_HEADERS).  Driven by an explicit clock (the loopback hub's
  simulated time in tests, ``time.monotonic`` on real TCP), so the
  admission bound — never more than ``burst + rate * elapsed`` cost in
  any window — is exactly testable.

Nothing here does IO: ``PeerNode`` consults the book for dial
candidates, feeds the scores, and asks the buckets before serving.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Tuple

from repro.chain.net.identity import KeyRing, PeerAddr

__all__ = [
    "PeerBook",
    "PeerScore",
    "TokenBucket",
]


# ---------------------------------------------------------------------------
# token bucket (serve-path rate limiting)
# ---------------------------------------------------------------------------


class TokenBucket:
    """Classic token bucket with an explicit clock.

    ``allow(now, cost)`` admits a request iff the bucket holds
    ``cost`` tokens after refilling at ``rate`` tokens/second since
    the last call, capped at ``burst``.  Time moving backwards (a
    hostile or buggy clock) refills nothing — the bucket clamps to
    monotone time, so for **any** event sequence the admitted cost
    through elapsed time ``t`` is bounded by ``burst + rate * t``
    (the Hypothesis property in ``tests/test_peerbook.py``)."""

    def __init__(self, rate: float, burst: float) -> None:
        if not (rate > 0.0):
            raise ValueError(f"rate must be positive, got {rate}")
        if not (burst >= 1.0):
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t_last: Optional[float] = None
        self.admitted = 0
        self.rejected = 0

    def _refill(self, now: float) -> None:
        if self._t_last is None:
            self._t_last = now
            return
        if now > self._t_last:
            self.tokens = min(self.burst,
                              self.tokens + self.rate * (now - self._t_last))
            self._t_last = now
        # now <= t_last: clock went backwards — no refill, no rewind

    def peek(self, now: float) -> float:
        """Tokens available at ``now`` (refills as a side effect)."""
        self._refill(now)
        return self.tokens

    def allow(self, now: float, cost: float = 1.0) -> bool:
        if cost < 0:
            raise ValueError(f"cost must be >= 0, got {cost}")
        self._refill(now)
        if self.tokens >= cost:
            self.tokens -= cost
            self.admitted += 1
            return True
        self.rejected += 1
        return False


# ---------------------------------------------------------------------------
# per-connection behavior scoring
# ---------------------------------------------------------------------------

# misbehavior weights (points per event); ban at >= BAN_THRESHOLD
W_INVALID = 20          # undecodable/forged frame content, bad signature
W_RATE = 10             # serve-path rate-limit / request-cap violation
W_STALE = 5             # advertised a height it could not deliver
W_TIMEOUT = 4           # a request deadline it let expire (DESIGN §15)
W_UNSOLICITED = 2       # bodies/addrs nobody asked for
W_USEFUL = 5            # credit per block this peer genuinely delivered
BAN_THRESHOLD = 100


@dataclasses.dataclass
class PeerScore:
    """Behavior ledger for one connection.  ``score`` ranks peers for
    eviction (higher = keep); ``misbehavior`` only ever grows, and
    ``banned`` is monotone in it — useful blocks buy eviction
    priority, **not** forgiveness for protocol abuse.  ``timeouts``
    counts expired request deadlines: cheaper than an invalid frame (a
    slow honest peer is not an attacker) but enough that a peer
    *baiting* pulls it never answers — the eclipse starvation pattern
    — bans itself within ``BAN_THRESHOLD / W_TIMEOUT`` expiries."""
    useful_blocks: int = 0
    invalid_frames: int = 0
    rate_violations: int = 0
    stale_tips: int = 0
    timeouts: int = 0
    unsolicited: int = 0

    def misbehavior(self) -> int:
        return (W_INVALID * self.invalid_frames
                + W_RATE * self.rate_violations
                + W_STALE * self.stale_tips
                + W_TIMEOUT * self.timeouts
                + W_UNSOLICITED * self.unsolicited)

    def score(self) -> int:
        return W_USEFUL * self.useful_blocks - self.misbehavior()

    def banned(self, threshold: int = BAN_THRESHOLD) -> bool:
        return self.misbehavior() >= threshold

    def to_dict(self) -> Dict[str, int]:
        d = dataclasses.asdict(self)
        d["score"] = self.score()
        d["misbehavior"] = self.misbehavior()
        return d


def eviction_order(scores: Dict[str, PeerScore]) -> List[str]:
    """Connection names worst-first — the deterministic eviction
    ranking: ascending score, name as the total tie-break (so the
    victim never depends on dict insertion order)."""
    return sorted(scores, key=lambda n: (scores[n].score(), n))


# ---------------------------------------------------------------------------
# the address manager
# ---------------------------------------------------------------------------


class PeerBook:
    """Capped two-bucket address manager driving outbound dialing.

    ``add`` admits only addrs that ``PeerAddr.verify`` accepts (under
    the book's ring, when set) — a malformed or forged addr never
    enters.  ``mark_connected`` promotes an id to ``tried``;
    ``mark_failed`` demotes it back to ``new`` (and drops it after
    ``max_failures`` consecutive failures); ``ban`` removes the id and
    refuses it forever.  ``select`` returns dial candidates tried-
    bucket-first in deterministic salted-hash order.

    Both buckets are capped.  Eviction keeps the ``max_*`` entries
    with the smallest ``sha256(salt | node_id)`` keys: deterministic,
    insertion-order-free, and uniform over ids — an attacker cannot
    choose arrival order to flush honest entries.

    **Eclipse defense** (DESIGN §15): gossip-relayed addrs are charged
    to the *relaying* connection's identity (``add(..., source=...)``)
    and each source may hold at most ``max_new_per_source`` entries of
    ``new`` — within a source's slice, eviction keeps the smallest
    ``sha256(salt | source | node_id)`` keys, a per-source salt the
    flooder cannot grind from another slice.  An attacker relaying
    thousands of self-signed addrs through one connection therefore
    caps out at one quota's worth of book space; first-hand records
    (a HELLO's own addr, a completed dial) carry ``source=None`` and
    are never charged to a relay."""

    def __init__(self, *, self_id: Optional[int] = None,
                 keyring: Optional[KeyRing] = None,
                 max_new: int = 64, max_tried: int = 32,
                 max_failures: int = 3, salt: int = 0,
                 max_new_per_source: Optional[int] = None) -> None:
        if max_new < 1 or max_tried < 1:
            raise ValueError("bucket caps must be >= 1")
        self.self_id = self_id
        self.keyring = keyring
        self.max_new = max_new
        self.max_tried = max_tried
        self.max_failures = max_failures
        self.salt = salt
        if max_new_per_source is None:
            max_new_per_source = max(max_new // 8, 4)
        if max_new_per_source < 1:
            raise ValueError("max_new_per_source must be >= 1")
        self.max_new_per_source = max_new_per_source
        self.new: Dict[int, PeerAddr] = {}
        self.tried: Dict[int, PeerAddr] = {}
        self.banned: set = set()
        self.failures: Dict[int, int] = {}
        # node id -> the relay (source id) its book space is charged to;
        # absent = first-hand knowledge, charged to nobody
        self.sources: Dict[int, int] = {}
        self.rejected = 0            # addrs refused admission
        self.evicted = 0

    # -- internals ----------------------------------------------------
    def _key(self, node_id: int) -> bytes:
        return hashlib.sha256(
            b"pnp-peerbook|" + struct.pack("<q", self.salt)
            + struct.pack("<q", node_id)).digest()

    def _skey(self, source: int, node_id: int) -> bytes:
        """Per-source-salted eviction key: which of one relay's entries
        survive its quota depends on (salt, source, id) only — not on
        arrival order, and not on anything the relay can grind against
        *other* sources' slices."""
        return hashlib.sha256(
            b"pnp-peerbook-src|" + struct.pack("<q", self.salt)
            + struct.pack("<q", source)
            + struct.pack("<q", node_id)).digest()

    def _source_slice(self, source: int) -> List[int]:
        return [nid for nid in self.new
                if self.sources.get(nid) == source]

    def _trim_source(self, source: int) -> None:
        """Enforce one relay's quota: evict the largest per-source-
        salted keys until its slice fits."""
        slice_ = self._source_slice(source)
        while len(slice_) > self.max_new_per_source:
            worst = max(slice_, key=lambda nid: self._skey(source, nid))
            slice_.remove(worst)
            self._drop(worst)
            self.evicted += 1

    def _drop(self, node_id: int) -> None:
        self.new.pop(node_id, None)
        self.sources.pop(node_id, None)

    def _trim(self, bucket: Dict[int, PeerAddr], cap: int) -> None:
        while len(bucket) > cap:
            worst = max(bucket, key=self._key)
            del bucket[worst]
            self.sources.pop(worst, None)
            self.evicted += 1

    # -- admission ----------------------------------------------------
    def has_exact(self, addr: PeerAddr) -> bool:
        """True iff this exact record (endpoint AND signature) is
        already held — the gossip fast path that skips re-verifying
        a signature we have verified before."""
        nid = addr.node_id
        return self.tried.get(nid) == addr or self.new.get(nid) == addr

    def add(self, addr: PeerAddr, *, verified: bool = False,
            source: Optional[int] = None) -> bool:
        """Admit a gossiped addr into ``new`` (or refresh an existing
        entry).  Returns True iff the addr is *newly learned* — the
        caller's cue to relay it onward exactly once.  ``verified``
        skips the (slow) signature check when the caller already ran
        ``addr.verify`` against this book's ring; structural sanity is
        never skipped — a malformed addr cannot enter.

        ``source`` is the relaying identity for third-party gossip:
        the entry is charged against that relay's
        ``max_new_per_source`` quota (eclipse defense).  ``None``
        means first-hand knowledge — a peer's own HELLO addr or a
        dialed endpoint — which is never charged, and *discharges* an
        entry previously learned through a relay."""
        if not isinstance(addr, PeerAddr):
            self.rejected += 1
            return False
        if verified:
            if not addr.well_formed():
                self.rejected += 1
                return False
        elif not addr.verify(self.keyring):
            self.rejected += 1
            return False
        nid = addr.node_id
        if nid == self.self_id or nid in self.banned:
            self.rejected += 1
            return False
        if nid in self.tried:
            if self.tried[nid].endpoint != addr.endpoint:
                self.tried[nid] = addr      # endpoint moved: refresh
            return False
        novel = nid not in self.new
        known = self.new.get(nid)
        if known is None or known.endpoint != addr.endpoint:
            self.new[nid] = addr
        if source is None:
            # first-hand: uncharged (and discharges a relay claim —
            # even when the endpoint is unchanged, hearing it from the
            # peer itself upgrades the entry's provenance)
            self.sources.pop(nid, None)
        elif novel:
            # charged to the first relay only — re-gossip through
            # other connections cannot move an entry between slices
            self.sources[nid] = source
            self._trim_source(source)
        if novel:
            self._trim(self.new, self.max_new)
        return novel and nid in self.new

    # -- lifecycle ----------------------------------------------------
    def mark_connected(self, node_id: int) -> None:
        """A live connection reached this id: promote to ``tried``."""
        addr = self.new.pop(node_id, None)
        if addr is None:
            addr = self.tried.get(node_id)
        if addr is None:
            return
        self.failures.pop(node_id, None)
        self.sources.pop(node_id, None)    # a live conn is first-hand
        self.tried[node_id] = addr
        self._trim(self.tried, self.max_tried)

    def mark_failed(self, node_id: int) -> None:
        """A dial to this id failed: demote tried -> new; drop entirely
        after ``max_failures`` consecutive failures."""
        n = self.failures.get(node_id, 0) + 1
        self.failures[node_id] = n
        addr = self.tried.pop(node_id, None)
        if addr is not None and n < self.max_failures:
            self.new[node_id] = addr
            self._trim(self.new, self.max_new)
        elif n >= self.max_failures:
            self._drop(node_id)
            self.failures.pop(node_id, None)

    def ban(self, node_id: int) -> None:
        """Remove and permanently refuse this id (misbehavior ban)."""
        self.banned.add(node_id)
        self._drop(node_id)
        self.tried.pop(node_id, None)
        self.failures.pop(node_id, None)

    # -- selection ----------------------------------------------------
    def select(self, n: int, exclude: Iterable[int] = ()) -> List[PeerAddr]:
        """Up to ``n`` dial candidates, tried bucket first, each bucket
        in deterministic salted-hash order, skipping ``exclude`` (the
        ids already connected or being dialed)."""
        skip = set(exclude) | self.banned
        if self.self_id is not None:
            skip.add(self.self_id)
        out: List[PeerAddr] = []
        for bucket in (self.tried, self.new):
            for nid in sorted(bucket, key=self._key):
                if len(out) >= n:
                    return out
                if nid not in skip:
                    out.append(bucket[nid])
                    skip.add(nid)
        return out

    def known(self) -> List[PeerAddr]:
        """Every addr the book holds (tried first, deterministic order)
        — what HELLO-triggered addr gossip sends a new peer."""
        out = []
        for bucket in (self.tried, self.new):
            out.extend(bucket[nid] for nid in sorted(bucket, key=self._key))
        return out

    def __contains__(self, node_id: int) -> bool:
        return node_id in self.new or node_id in self.tried

    def __len__(self) -> int:
        return len(self.new) + len(self.tried)

    def to_dict(self) -> Dict[str, object]:
        return {"new": sorted(self.new), "tried": sorted(self.tried),
                "banned": sorted(self.banned),
                "rejected": self.rejected, "evicted": self.evicted,
                "charged": {s: len(self._source_slice(s))
                            for s in sorted(set(self.sources.values()))}}
