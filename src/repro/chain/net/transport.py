"""``repro.chain.net.transport`` — how frames move between peers.

Two implementations of one small port interface (``send(dst, msg)``,
``peer_names()``, an ``on_message(src, msg)`` callback, a ``WireStats``
counter):

* ``LoopbackHub`` — deterministic in-memory transport: every message
  is genuinely encoded to frame bytes and decoded on delivery (so
  bytes-on-wire numbers are real and malformed frames are really
  quarantined), delivery order is a seeded (latency, seq) heap like
  the ``Sim``'s event queue, and lossy links retry with backoff.
  ``pump()`` drains the queue deterministically — usable inside a
  discrete-event simulation or a plain test loop.

* ``TcpTransport`` — real asyncio TCP: length-framed stream, per-
  connection ``FrameBuffer`` reassembly (malformed frames quarantined,
  never raising — a connection exceeding ``quarantine_limit`` is
  dropped), and per-peer connect retry with backoff.

The transport is deliberately dumb: it moves frames and counts bytes.
All protocol logic — identity checks, compact relay, sync — lives in
``PeerNode`` (sans-IO, so both transports drive the identical code).
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
import random
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.chain.net.messages import (FrameBuffer, Message, decode_message,
                                      encode_message)

__all__ = [
    "LoopbackHub",
    "LoopbackPort",
    "TcpTransport",
    "WireStats",
]


@dataclasses.dataclass
class WireStats:
    """Bytes and frames through one port (both directions), plus the
    malformed-frame quarantine count.  ``bytes_sent`` counts every
    transmission attempt that reached the wire — retries included —
    which is what a bandwidth bill would count."""
    frames_sent: int = 0
    frames_recv: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    quarantined: int = 0
    drops: int = 0
    retries: int = 0

    def note_sent(self, n_bytes: int) -> None:
        self.frames_sent += 1
        self.bytes_sent += n_bytes

    def note_recv(self, n_bytes: int) -> None:
        self.frames_recv += 1
        self.bytes_recv += n_bytes

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class LoopbackPort:
    """One peer's endpoint on a ``LoopbackHub``.  Assign
    ``on_message(src_name, msg)`` (``PeerNode.attach`` does) before
    pumping.  ``on_quarantine(src_name)`` (optional) fires once per
    malformed frame so the protocol layer can score the sender."""

    def __init__(self, hub: "LoopbackHub", name: str) -> None:
        self.hub = hub
        self.name = name
        self.stats = WireStats()
        self.on_message: Optional[Callable[[str, Message], None]] = None
        self.on_quarantine: Optional[Callable[[str], None]] = None

    def peer_names(self) -> List[str]:
        return self.hub.links_of(self.name)

    def now(self) -> float:
        """The hub's simulated clock (drives the peer's rate buckets
        and request deadlines deterministically)."""
        return self.hub.now

    def peer_endpoint(self, conn: str) -> Optional[Tuple[str, int]]:
        """The (host, port) this connection appears to come from —
        what HELLO's observed-address echo carries.  Loopback peers
        have one only if the hub was told (``set_endpoint``)."""
        return self.hub.endpoints.get(conn)

    def send(self, dst: str, msg: Message) -> None:
        frame = encode_message(msg)
        self.hub._transmit(self.name, dst, frame, self.stats)

    def disconnect(self, dst: str) -> None:
        """Tear down the link to ``dst`` (eviction/ban): both ends stop
        listing each other and in-flight frames on the link are dropped
        at delivery."""
        self.hub.disconnect(self.name, dst)

    def _deliver(self, src: str, frame: bytes) -> None:
        self.stats.note_recv(len(frame))
        msg = decode_message(frame)
        if msg is None:
            self.stats.quarantined += 1
            if self.on_quarantine is not None:
                self.on_quarantine(src)
            return
        if self.on_message is not None:
            self.on_message(src, msg)


class LoopbackHub:
    """Deterministic in-memory wire: seeded latency jitter, optional
    loss with bounded retry/backoff, (time, seq)-ordered delivery.

    ``inject`` pushes raw bytes (adversarial tests corrupt frames with
    it); ``pump`` drains the queue, running receive handlers — which
    may enqueue more sends — until quiet."""

    def __init__(self, *, seed: int = 0, min_latency: float = 0.01,
                 max_latency: float = 0.05, drop_prob: float = 0.0,
                 max_retries: int = 2,
                 retry_backoff: float = 0.05,
                 full_mesh: bool = True) -> None:
        self.ports: Dict[str, LoopbackPort] = {}
        self.rng = random.Random(seed)
        self.min_latency = min_latency
        self.max_latency = max_latency
        self.drop_prob = drop_prob
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.full_mesh = full_mesh
        self.now = 0.0
        self._seq = 0
        self._queue: List[Tuple[float, int, str, str, bytes]] = []
        self._links: Dict[str, set] = {}
        # port name -> the (host, port) other peers observe it at
        # (observed-address feedback in loopback tests/scenarios)
        self.endpoints: Dict[str, Tuple[str, int]] = {}

    def register(self, name: str) -> LoopbackPort:
        if name in self.ports:
            raise ValueError(f"peer name {name!r} already registered")
        port = LoopbackPort(self, name)
        self._links[name] = set()
        if self.full_mesh:
            # the PR-7 contract: every port sees every other (the mesh
            # scenarios pass full_mesh=False and connect explicitly)
            for other in self.ports:
                self._links[name].add(other)
                self._links[other].add(name)
        self.ports[name] = port
        return port

    def unregister(self, name: str) -> None:
        """A process crash: the port vanishes, every link to it drops,
        and frames already in flight toward it are lost at delivery.
        The name becomes free — a restarted process ``register``s it
        again and redials from scratch."""
        self.ports.pop(name, None)
        for other in self._links.pop(name, set()):
            self._links.get(other, set()).discard(name)
        self.endpoints.pop(name, None)

    def set_endpoint(self, name: str, host: str, port: int) -> None:
        """Declare where peers observe ``name`` connecting from (feeds
        ``LoopbackPort.peer_endpoint`` / HELLO observed echoes)."""
        self.endpoints[name] = (host, port)

    def advance(self, dt: float) -> float:
        """Move simulated time forward by ``dt`` (never backwards) —
        how scenarios and tests expire request deadlines and keepalive
        windows between pumps."""
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        self.now += dt
        return self.now

    # -- explicit topology (mesh mode) --------------------------------
    def links_of(self, name: str) -> List[str]:
        return sorted(self._links.get(name, ()))

    def connect(self, a: str, b: str) -> bool:
        """Create the bidirectional link a<->b (a discovery dial).
        Returns False if it already exists or either end is unknown."""
        if a == b or a not in self.ports or b not in self.ports:
            return False
        if b in self._links[a]:
            return False
        self._links[a].add(b)
        self._links[b].add(a)
        return True

    def disconnect(self, a: str, b: str) -> None:
        self._links.get(a, set()).discard(b)
        self._links.get(b, set()).discard(a)

    def _transmit(self, src: str, dst: str, frame: bytes,
                  stats: WireStats) -> None:
        """Send with loss + bounded retry: each attempt that reaches
        the wire costs bytes; a frame dropped ``max_retries + 1`` times
        is lost (the protocol above resyncs via chain pull)."""
        if dst not in self._links.get(src, ()):
            stats.drops += 1
            return                         # no link: nothing to send on
        delay = 0.0
        for attempt in range(self.max_retries + 1):
            stats.note_sent(len(frame))    # every attempt costs bytes
            if attempt > 0:
                stats.retries += 1
            if self.rng.random() >= self.drop_prob:
                latency = self.rng.uniform(self.min_latency,
                                           self.max_latency)
                self._push(self.now + delay + latency, src, dst, frame)
                return
            stats.drops += 1
            delay += self.retry_backoff * (attempt + 1)
        # every attempt dropped: the frame is lost

    def inject(self, src: str, dst: str, raw: bytes) -> None:
        """Deliver raw bytes as-if from ``src`` — the adversarial hook
        (corrupt frames, replays, garbage)."""
        latency = self.rng.uniform(self.min_latency, self.max_latency)
        self._push(self.now + latency, src, dst, raw)

    def _push(self, t: float, src: str, dst: str, frame: bytes) -> None:
        self._seq += 1
        heapq.heappush(self._queue, (t, self._seq, src, dst, frame))

    def pump(self, max_frames: int = 100_000) -> int:
        """Deliver queued frames in deterministic (time, seq) order —
        handlers may send more; keep going until the wire is quiet.
        Returns the number of frames delivered."""
        delivered = 0
        while self._queue and delivered < max_frames:
            t, _, src, dst, frame = heapq.heappop(self._queue)
            self.now = max(self.now, t)
            delivered += 1
            port = self.ports.get(dst)
            if port is None:
                continue
            if src in self.ports and src not in self._links.get(dst, ()):
                continue                   # link torn down in flight
            port._deliver(src, frame)
        return delivered

    def total_bytes(self) -> int:
        """Bytes that crossed the wire, summed over all ports."""
        return sum(p.stats.bytes_sent for p in self.ports.values())


class TcpTransport:
    """Asyncio TCP with the same port interface as ``LoopbackPort``.

    Peers are addressed by connection name (``"in#3"`` / ``"out#1"``)
    — the protocol layer maps names to node identities via HELLO.
    Each connection reads through its own ``FrameBuffer``: malformed
    frames are quarantined (never raising), and a connection that
    exceeds ``quarantine_limit`` malformed frames is closed (the
    outbound side may then ``connect`` again — per-peer retry/backoff
    lives there)."""

    def __init__(self, *, quarantine_limit: int = 32) -> None:
        self.stats = WireStats()
        self.handler_errors: List[str] = []
        self.quarantine_limit = quarantine_limit
        self.on_message: Optional[Callable[[str, Message], None]] = None
        self.on_quarantine: Optional[Callable[[str], None]] = None
        self._writers: Dict[str, asyncio.StreamWriter] = {}
        self._endpoints: Dict[str, Tuple[str, int]] = {}
        self._server: Optional[asyncio.AbstractServer] = None
        self._tasks: List[asyncio.Task] = []
        self._n_in = 0
        self._n_out = 0

    # -- port interface -----------------------------------------------
    def peer_names(self) -> List[str]:
        return list(self._writers)

    def now(self) -> float:
        """Monotonic wall clock (drives the peer's rate buckets and
        request deadlines)."""
        return time.monotonic()

    def peer_endpoint(self, conn: str) -> Optional[Tuple[str, int]]:
        """The TCP peername this connection arrived from — what
        HELLO's observed-address echo carries back to a NATed peer."""
        return self._endpoints.get(conn)

    def send(self, dst: str, msg: Message) -> None:
        writer = self._writers.get(dst)
        if writer is None or writer.is_closing():
            return
        frame = encode_message(msg)
        self.stats.note_sent(len(frame))
        writer.write(frame)

    def disconnect(self, dst: str) -> None:
        """Close one connection (eviction/ban): its reader task winds
        down and the name disappears from ``peer_names``."""
        writer = self._writers.pop(dst, None)
        if writer is not None:
            try:
                writer.close()
            except Exception:
                pass

    # -- lifecycle ----------------------------------------------------
    async def listen(self, host: str = "127.0.0.1",
                     port: int = 0) -> int:
        """Accept inbound peers; returns the bound port (``port=0``
        picks a free one)."""
        self._server = await asyncio.start_server(
            self._accept, host=host, port=port)
        return self._server.sockets[0].getsockname()[1]

    async def _accept(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._n_in += 1
        await self._run_conn(f"in#{self._n_in}", reader, writer)

    async def connect(self, host: str, port: int, *,
                      retries: int = 20,
                      backoff: float = 0.25) -> str:
        """Dial a peer with per-peer retry/backoff (linear, capped —
        the other process may still be starting up).  Returns the
        connection name; raises ``ConnectionError`` after the final
        attempt fails."""
        last: Optional[Exception] = None
        for attempt in range(retries + 1):
            try:
                reader, writer = await asyncio.open_connection(host, port)
            except OSError as e:
                last = e
                self.stats.retries += 1
                await asyncio.sleep(min(backoff * (attempt + 1), 2.0))
                continue
            self._n_out += 1
            name = f"out#{self._n_out}"
            task = asyncio.ensure_future(
                self._run_conn(name, reader, writer))
            self._tasks.append(task)
            # give _run_conn a tick to register the writer
            await asyncio.sleep(0)
            return name
        raise ConnectionError(
            f"could not reach {host}:{port} after {retries + 1} "
            f"attempts: {last}")

    async def _run_conn(self, name: str, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter) -> None:
        self._writers[name] = writer
        peername = writer.get_extra_info("peername")
        if isinstance(peername, (tuple, list)) and len(peername) >= 2:
            self._endpoints[name] = (str(peername[0]), int(peername[1]))
        fb = FrameBuffer()
        seen_quarantined = 0
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    for msg in fb.feed(b"", eof=True):
                        self._dispatch(name, msg)
                    self.stats.quarantined += \
                        fb.quarantined - seen_quarantined
                    break
                self.stats.bytes_recv += len(data)
                for msg in fb.feed(data):
                    self.stats.frames_recv += 1
                    self._dispatch(name, msg)
                fresh = fb.quarantined - seen_quarantined
                self.stats.quarantined += fresh
                seen_quarantined = fb.quarantined
                if fresh and self.on_quarantine is not None:
                    for _ in range(fresh):
                        self.on_quarantine(name)
                if fb.quarantined > self.quarantine_limit:
                    break                  # hostile/broken peer: drop
        finally:
            self._writers.pop(name, None)
            self._endpoints.pop(name, None)
            try:
                writer.close()
            except Exception:
                pass

    def _dispatch(self, name: str, msg: Message) -> None:
        if self.on_message is None:
            return
        try:
            self.on_message(name, msg)
        except Exception:
            # a handler bug must not kill the reader task (the
            # connection would die silently and every later send
            # becomes a no-op) — record it and keep reading
            import traceback
            err = traceback.format_exc()
            self.handler_errors.append(err)
            print(f"[net] handler error on {name}:\n{err}",
                  file=sys.stderr)

    async def drain(self) -> None:
        for writer in list(self._writers.values()):
            try:
                await writer.drain()
            except Exception:
                pass

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for writer in list(self._writers.values()):
            try:
                writer.close()
            except Exception:
                pass
        for task in self._tasks:
            task.cancel()
