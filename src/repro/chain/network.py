"""In-process multi-node PNPCoin network: broadcast, re-verify, fork
choice.

The paper's nodes "communicate the hash of the chain" (§3.1); here N
``Node`` instances share blocks by value.  On every broadcast the peer
re-verifies the payload **bit-exactly** (full: quorum re-execution +
independent Merkle recomputation; optimal/classic: deterministic argmin
replay; training: re-running the train step and comparing state
digests) — §3 req. 2 is what makes any node able to audit any miner.
When a peer's tip diverges, longest-valid-chain fork choice applies:
the strictly longer chain whose every payload re-verifies wins, and the
loser's ledger *and credit book* are rebuilt from the adopted chain.

Because §3.3 makes *every* peer re-verify *every* block, an N-node
network pays N-1 verifications per block — the dominant compute once
gossip works.  A ``Network`` therefore forms one **trust domain**: a
shared content-addressed ``VerifyCache`` in which each unique (block
hash, payload object) is verified once and every other member skips
straight to the cheap header/consensus checks (DESIGN.md §10).
Stateful (training) payloads never use it — their verification doubles
as state sync.  Pass ``shared_verify_cache=False`` (or construct nodes
with ``use_verify_cache=False``) to make every node re-verify
everything itself, the adversarial-analysis configuration.

This network is deliberately *synchronous and honest*: broadcasts are
instantaneous, nothing is dropped, and every sender is who it claims to
be.  For latency, message loss, partitions, churn and adversarial
miners, layer ``repro.chain.sim`` (a seeded discrete-event simulator)
over the same ``Node`` API.

Run a 2-node smoke simulation (used by CI)::

    PYTHONPATH=src python -m repro.chain.network --nodes 2 --blocks 4
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

from repro.chain.node import BlockReceipt, Node, VerifyCache
from repro.chain.workload import BlockPayload
from repro.core.ledger import Block


@dataclasses.dataclass
class BroadcastResult:
    receipt: BlockReceipt
    accepted_by: List[int]
    rejected_by: List[int]


class Network:
    """N nodes, block broadcast, longest-valid-chain convergence."""

    def __init__(self, nodes: Sequence[Node], *,
                 shared_verify_cache: bool = True,
                 identities: Optional[dict] = None) -> None:
        if not nodes:
            raise ValueError("a network needs at least one node")
        self.nodes = list(nodes)
        # node_id -> PeerIdentity: when set, every deliver carries a
        # signed announce, so member nodes with a keyring enforce the
        # same cryptographic origin binding as wire-connected PeerNodes
        # (one rule, both transports — repro.chain.net.identity)
        self.identities = dict(identities) if identities else None
        self.log: List[BroadcastResult] = []
        # one trust domain: a node that verified a payload spares every
        # other member the §3 req. 2 re-execution.  Constructing a
        # Network around existing nodes NEVER mutates them (a read-only
        # wrapper must not enroll live nodes into a new domain behind
        # the caller's back) — only ``create``, which builds the nodes
        # itself, enrolls via ``enroll_nodes``.
        self.verify_cache = VerifyCache() if shared_verify_cache else None

    def enroll_nodes(self) -> None:
        """Enroll member nodes into this network's trust domain.
        Explicit and opt-in: nodes that opted out
        (``use_verify_cache=False``) or already belong to a domain
        (e.g. a ``Sim``'s) keep their configuration."""
        if self.verify_cache is None:
            return
        for node in self.nodes:
            if node.use_verify_cache and node.verify_cache is None:
                node.verify_cache = self.verify_cache

    @classmethod
    def create(cls, n_nodes: int,
               node_factory: Optional[Callable[[int], Node]] = None,
               shared_verify_cache: bool = True,
               identities: Optional[dict] = None,
               **node_kwargs) -> "Network":
        if node_factory is None and "workloads" in node_kwargs:
            # one shared Workload instance across nodes would make every
            # "re-verification" compare a stateful workload's history
            # against itself — each node needs its own instances
            raise ValueError(
                "pass workloads via node_factory=lambda i: Node(node_id=i, "
                "workloads={...fresh instances...}) so every node gets its "
                "own Workload objects — sharing one instance across nodes "
                "voids independent re-verification")
        factory = node_factory or (lambda i: Node(node_id=i, **node_kwargs))
        net = cls([factory(i) for i in range(n_nodes)],
                  shared_verify_cache=shared_verify_cache,
                  identities=identities)
        net.enroll_nodes()       # create owns these nodes — see __init__
        return net

    # -- mining + gossip ----------------------------------------------
    def mine(self, origin: int = 0,
             workload: Optional[str] = None) -> BroadcastResult:
        """One node mines one block and broadcasts it to all peers."""
        receipt = self.nodes[origin].mine_block(workload)
        return self.broadcast(origin, receipt.record.to_block(), receipt)

    def broadcast(self, origin: int, block: Block,
                  receipt: BlockReceipt) -> BroadcastResult:
        result = BroadcastResult(receipt=receipt, accepted_by=[origin],
                                 rejected_by=[])
        for i, peer in enumerate(self.nodes):
            if i == origin:
                continue
            if self.deliver(origin, i, block, receipt.payload):
                result.accepted_by.append(i)
            else:
                result.rejected_by.append(i)
        self.log.append(result)
        return result

    def deliver(self, origin: int, dest: int, block: Block,
                payload: BlockPayload) -> bool:
        """Deliver one block to one peer: fast path appends to the tip;
        on tip mismatch the peer pulls the origin's whole chain and runs
        longest-valid-chain fork choice.  Duplicate deliveries (the
        block hash is already in the peer's chain — gossip is
        at-least-once) are an idempotent no-op, skipping the pointless
        full-chain re-verification a chain pull would cost."""
        peer = self.nodes[dest]
        announce = None
        if self.identities is not None and payload.origin in self.identities:
            # lazy import: net builds on chain, never the reverse
            from repro.chain.net.identity import make_announce
            announce = make_announce(
                self.identities[payload.origin], block, payload)
        if peer.receive(block, payload, origin=origin, announce=announce):
            return True
        if peer.has_block(block.block_hash):
            return False
        src = self.nodes[origin]
        if not src.ledger.blocks:
            # nothing to pull (consider_chain treats an empty candidate
            # as a caller bug and raises)
            return False
        return peer.consider_chain(src.ledger.blocks, src.chain_payloads())

    def run(self, n_blocks: int,
            schedule: Optional[Sequence[Optional[str]]] = None
            ) -> List[BroadcastResult]:
        """Round-robin mining: block i is mined by node ``i % N`` with the
        workload named by ``schedule[i]`` (None -> default policy)."""
        out = []
        for i in range(n_blocks):
            wl = schedule[i] if schedule else None
            out.append(self.mine(origin=i % len(self.nodes), workload=wl))
        return out

    # -- convergence checks -------------------------------------------
    @property
    def tips(self) -> List[str]:
        return [n.ledger.tip_hash for n in self.nodes]

    @property
    def heights(self) -> List[int]:
        return [n.ledger.height for n in self.nodes]

    def converged(self) -> bool:
        """One chain: equal tips, every chain valid, and every Merkle
        root bit-identical across nodes at every height."""
        tips = set(self.tips)
        if len(tips) != 1:
            return False
        if not all(n.ledger.verify_chain() for n in self.nodes):
            return False
        roots = {tuple(b.merkle_root for b in n.ledger.blocks)
                 for n in self.nodes}
        return len(roots) == 1


def smoke(n_nodes: int = 2, n_blocks: int = 4, verbose: bool = True) -> int:
    """2-node CI smoke sim: a queued jash block, an optimal block, then
    classic fallback; asserts full convergence.  Returns 0 on success."""
    from repro.core.jash import Jash, JashMeta, collatz_jash

    def small_collatz(max_steps: int) -> Jash:
        base = collatz_jash(max_steps=max_steps)
        return Jash(base.name, base.fn,
                    JashMeta(arg_bits=9, res_bits=32, importance=0.8),
                    example_args=base.example_args)

    net = Network.create(n_nodes, classic_arg_bits=8)
    net.nodes[0].submit(small_collatz(128))
    net.nodes[1 % n_nodes].submit(small_collatz(64))

    schedule: List[Optional[str]] = ["full", "optimal"] + \
        [None] * max(n_blocks - 2, 0)
    for res in net.run(n_blocks, schedule):
        r = res.receipt.record
        if verbose:
            print(f"height {r.height} [{r.workload:8s}] "
                  f"miner=node{res.receipt.payload.origin} "
                  f"root={r.merkle_root[:16]}… "
                  f"accepted_by={res.accepted_by}")
        assert not res.rejected_by, f"peers rejected: {res.rejected_by}"

    assert net.converged(), (net.heights, net.tips)
    assert all(n.audit_chain() for n in net.nodes)
    books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
    assert len(books) == 1, "credit books diverged"
    if verbose:
        s = net.nodes[0].state()
        print(f"converged: {n_nodes} nodes, height {s.height}, "
              f"tip {s.tip_hash[:16]}…, credits {s.total_issued:.1f}")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--blocks", type=int, default=4)
    args = ap.parse_args()
    raise SystemExit(smoke(args.nodes, args.blocks))
