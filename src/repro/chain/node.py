"""The ``Node``: one PNPCoin participant, the Fig. 1 loop behind a facade.

Composes the Runtime Authority (review + publication), the Ledger
(chained commitments), the CreditBook (rewards) and the
DifficultyController (§3.1/§5 args-per-block retargeting) behind four
calls::

    node = Node()
    node.submit(jash)          # researcher -> RA review
    receipt = node.mine_block()  # publish -> mine -> verify -> commit
    node.audit(height)         # re-verify any committed block
    node.state()               # typed snapshot of the whole node

Every committed block is self-verified *before* it is appended — a node
never extends its own chain with a payload a peer would reject.  The
``receive``/``consider_chain`` pair is the peer-side protocol
``chain/network.py`` drives: bit-exact re-verification on receive, and
longest-valid-chain fork choice when tips diverge.

``repro.core.*`` stays the stable kernel layer underneath; nothing here
reaches around the public surfaces of executor/ledger/rewards/verify.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.authority import ReviewReport, RuntimeAuthority
from repro.core.difficulty import DifficultyController
from repro.core.jash import Jash
from repro.core.ledger import Block, Ledger
from repro.core.rewards import CreditBook
from repro.chain.workload import (
    BlockContext, BlockPayload, ChainError, ClassicSha256Workload,
    JashFullWorkload, JashOptimalWorkload, RewardEntries, Workload,
)


@dataclasses.dataclass(frozen=True)
class BlockRecord:
    """Typed view of one committed block (replaces the positional
    ``ledger.append(...)`` kwargs soup at the API boundary)."""
    height: int
    prev_hash: str
    block_hash: str
    workload: str
    jash_id: str
    merkle_root: str
    winner: Optional[int]
    best_res: Optional[str]
    n_results: int
    state_digest: str

    @classmethod
    def from_block(cls, blk: Block) -> "BlockRecord":
        return cls(height=blk.height, prev_hash=blk.prev_hash,
                   block_hash=blk.block_hash, workload=blk.mode,
                   jash_id=blk.jash_id, merkle_root=blk.merkle_root,
                   winner=blk.winner, best_res=blk.best_res,
                   n_results=blk.n_results, state_digest=blk.state_digest)

    def to_block(self) -> Block:
        """The ledger ``Block`` this record describes (what goes on the
        wire; the content hash is timestamp-free so it round-trips)."""
        return Block(height=self.height, prev_hash=self.prev_hash,
                     jash_id=self.jash_id, mode=self.workload,
                     merkle_root=self.merkle_root, winner=self.winner,
                     best_res=self.best_res, n_results=self.n_results,
                     state_digest=self.state_digest)


@dataclasses.dataclass(frozen=True)
class BlockReceipt:
    """What ``mine_block`` hands back: the committed record, the payload
    evidence (what peers re-verify), and the credits it minted.  A
    receipt only exists for a block that passed self-verification —
    ``mine_block`` raises ``ChainError`` otherwise."""
    record: BlockRecord
    payload: BlockPayload
    rewards: RewardEntries
    block_time_s: float


@dataclasses.dataclass(frozen=True)
class NodeState:
    node_id: int
    height: int
    tip_hash: str
    queue_depth: int
    work: Optional[int]
    total_issued: float
    balances: Dict[int, float]
    chain_valid: bool


class Node:
    """One PNPCoin node: RA + ledger + credits + difficulty + workloads."""

    def __init__(self, *, node_id: int = 0,
                 workloads: Optional[Dict[str, Workload]] = None,
                 block_reward: float = 50.0,
                 classic_arg_bits: int = 10,
                 target_block_s: Optional[float] = None,
                 work: Optional[int] = None,
                 mesh: Optional[object] = None,
                 n_lanes: int = 1,
                 ra: Optional[RuntimeAuthority] = None) -> None:
        """``n_lanes`` is multi-lane mining: partition full/optimal
        execution over ``n_lanes`` single-device miner lanes, all run in
        one vmapped dispatch (lane ``l`` earns as global miner
        ``node_id * MINER_LANE + l``).  Mutually exclusive with a
        sharded ``mesh``, whose axes already define the miner fleet.
        Lane partitioning never changes the mined bits, so peers need no
        knowledge of a miner's lane count to verify its blocks."""
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        if n_lanes > 1 and any(
                a in getattr(mesh, "axis_names", ())
                for a in ("pod", "data")):
            # fail at construction, not on the first mine_block deep
            # inside a simulation
            raise ValueError(
                "n_lanes is the single-device miner partition; the mesh "
                "already defines the miner fleet via its axes — use one "
                "or the other")
        self.node_id = node_id
        self.block_reward = block_reward
        self.mesh = mesh
        self.n_lanes = n_lanes
        self.ra = ra if ra is not None else RuntimeAuthority()
        self.ledger = Ledger()
        self.book = CreditBook()
        self.workloads: Dict[str, Workload] = {
            "full": JashFullWorkload(),
            "optimal": JashOptimalWorkload(),
            "classic": ClassicSha256Workload(arg_bits=classic_arg_bits),
        }
        if workloads:
            self.workloads.update(workloads)
        if target_block_s is not None and work is None:
            raise ValueError(
                "target_block_s without an initial work target is a no-op "
                "retargeter — pass work= (e.g. from "
                "repro.core.difficulty.work_for_runtime) as well")
        self.work = work
        self.difficulty = (DifficultyController(target_block_s=target_block_s)
                           if target_block_s is not None else None)
        self._payloads: Dict[int, BlockPayload] = {}

    # -- researcher side ----------------------------------------------
    def submit(self, jash: Jash, veto: bool = False) -> ReviewReport:
        """Researcher submission -> the RA's §3.3 review pipeline."""
        return self.ra.submit(jash, veto=veto)

    # -- mining side --------------------------------------------------
    def mine_block(self, workload: Optional[str] = None) -> BlockReceipt:
        """Publish -> mine -> self-verify -> commit -> reward, one block.

        ``workload=None`` follows the paper's default policy: pop the
        RA queue and run **full** mode, falling back to **classic**
        SHA-256 when the queue is empty (§3.4).  Pass "optimal",
        "training" or "classic" to select the payload explicitly.
        """
        t0 = time.perf_counter()
        if workload in (None, "full", "optimal"):
            jash, source = self.ra.publish_next()
            if source == "queued":
                name = workload or "full"
            elif workload is None:
                name = "classic"            # §3.4 fallback, default policy
            else:
                raise ChainError(
                    f"workload {workload!r} requested explicitly but the "
                    "RA queue is empty — submit a jash first or mine with "
                    "the default policy (workload=None) for the classic "
                    "fallback")
        else:
            if workload not in self.workloads:
                raise ChainError(f"unknown workload {workload!r} "
                                 f"(have {sorted(self.workloads)})")
            jash, source, name = None, workload, workload

        wl = self.workloads[name]
        ctx = BlockContext(height=self.ledger.height,
                           prev_hash=self.ledger.tip_hash,
                           node_id=self.node_id, jash=jash, source=source,
                           work=self.work, block_reward=self.block_reward,
                           mesh=self.mesh, lanes=self.n_lanes)
        try:
            payload = wl.mine(wl.prepare(ctx))
            ok = wl.verify(payload)
        except Exception:
            if source == "queued":
                self.ra.requeue(jash)       # don't lose the submission
            raise
        if not ok:
            if source == "queued":
                self.ra.requeue(jash)
            raise ChainError(
                f"self-mined {name} block at height {ctx.height} failed "
                "verification — refusing to commit")
        record, rewards = self._commit(payload)

        dt = time.perf_counter() - t0
        if self.difficulty is not None:
            self.difficulty.observe(dt)
            if self.work is not None:
                self.work = self.difficulty.propose_work(self.work)
        return BlockReceipt(record=record, payload=payload, rewards=rewards,
                            block_time_s=dt)

    def _commit(self, payload: BlockPayload
                ) -> Tuple[BlockRecord, RewardEntries]:
        blk = self.ledger.append(
            jash_id=payload.jash_id, mode=payload.workload,
            merkle=payload.merkle_root, winner=payload.winner,
            best_res=payload.best_res, n_results=payload.n_results,
            state_digest=payload.state_digest)
        self._payloads[blk.height] = payload
        rewards = self.workloads[payload.workload].reward(self.book, payload)
        return BlockRecord.from_block(blk), rewards

    # -- verifier side ------------------------------------------------
    def audit(self, height: int) -> bool:
        """Re-verify a committed block: header fields must match the
        payload and the payload must re-verify bit-exactly (§3 req. 2)."""
        if not 0 <= height < self.ledger.height:
            raise ChainError(f"no block at height {height}")
        blk = self.ledger.blocks[height]
        payload = self._payloads.get(height)
        if payload is None:
            return False
        return (self._payload_matches(blk, payload)
                and self.workloads[payload.workload].verify(payload))

    def _payload_matches(self, blk: Block, payload: BlockPayload) -> bool:
        return (blk.jash_id == payload.jash_id
                and blk.mode == payload.workload
                and blk.merkle_root == payload.merkle_root
                and blk.winner == payload.winner
                and blk.best_res == payload.best_res
                and blk.n_results == payload.n_results
                and blk.state_digest == payload.state_digest
                and payload.workload in self.workloads)

    # -- peer protocol (driven by chain/network.py) -------------------
    def has_block(self, block_hash: str) -> bool:
        """True iff a block with this content hash is already committed
        — the duplicate check gossip layers run before treating a failed
        ``receive`` as a fork signal (at-least-once delivery must be an
        idempotent no-op, never a chain pull)."""
        return any(b.block_hash == block_hash for b in self.ledger.blocks)

    def receive(self, block: Block, payload: BlockPayload,
                origin: Optional[int] = None) -> bool:
        """Accept a broadcast block iff it extends our tip and the payload
        re-verifies bit-exactly.  Returns False on any mismatch (the
        network layer then falls back to ``consider_chain``).

        Reward-determining payload fields are enforced here, not in the
        workload: ``block_reward`` must equal this node's configured
        reward (a consensus parameter — a payload claiming more mints
        nothing), and when ``origin`` is given (the network layer passes
        the actual sender, the in-process stand-in for a block
        signature) the payload may not claim someone else's lane."""
        if (block.height != self.ledger.height
                or block.prev_hash != self.ledger.tip_hash):
            return False
        if payload.block_reward != self.block_reward:
            return False
        if origin is not None and payload.origin != origin:
            return False
        if not self._payload_matches(block, payload):
            return False
        wl = self.workloads.get(payload.workload)
        if wl is None or not wl.verify(payload):
            return False
        self._commit(payload)
        return True

    def consider_chain(self, blocks: Sequence[Block],
                       payloads: Sequence[BlockPayload]) -> bool:
        """Longest-valid-chain fork choice: adopt a competing chain iff it
        is strictly longer, links from genesis, and every payload
        re-verifies.  The ledger and credit book are rebuilt from the
        adopted payloads (credits follow the chain, not the node)."""
        if len(blocks) <= self.ledger.height or len(blocks) != len(payloads):
            return False
        # the block reward is a consensus parameter; origin attribution
        # inside a relayed chain is a signature problem (out of scope for
        # the in-process network) and is NOT re-checked here
        if any(p.block_reward != self.block_reward for p in payloads):
            return False
        prev = Ledger.GENESIS_HASH
        for i, (blk, payload) in enumerate(zip(blocks, payloads)):
            if (blk.height != i or blk.prev_hash != prev
                    or not self._payload_matches(blk, payload)):
                return False
            prev = blk.block_hash
        # Stateful workloads (training) advance while verifying.  Reset
        # them to genesis first so the candidate chain is replayed from
        # scratch and, on adoption, their state reflects exactly the
        # adopted chain's content (a fork that discards a local training
        # block must rewind the trainer too, or the node's future blocks
        # are unverifiable by peers).  Snapshots roll everything back if
        # a payload fails mid-chain.
        snaps = [(wl, wl.snapshot()) for wl in self.workloads.values()
                 if hasattr(wl, "snapshot")]
        for swl, _ in snaps:
            swl.reset()
        for payload in payloads:
            wl = self.workloads.get(payload.workload)
            if wl is None or not wl.verify(payload):
                for swl, snap in snaps:
                    swl.restore(snap)
                return False
        self.ledger = Ledger()
        self.book = CreditBook()
        self._payloads = {}
        for payload in payloads:
            self._commit(payload)
        return True

    # -- introspection ------------------------------------------------
    def state(self) -> NodeState:
        """Typed snapshot of the whole node.  ``chain_valid`` re-walks
        the hash links from genesis (cheap header check only — use
        ``audit`` for payload re-verification); ``balances`` is a copy,
        so a held snapshot is immune to later fork-choice rebuilds."""
        return NodeState(node_id=self.node_id, height=self.ledger.height,
                         tip_hash=self.ledger.tip_hash,
                         queue_depth=self.ra.queue_depth, work=self.work,
                         total_issued=self.book.total_issued,
                         balances=dict(self.book.balances),
                         chain_valid=self.ledger.verify_chain())

    @property
    def records(self) -> List[BlockRecord]:
        """Typed view of the committed chain, genesis -> tip.  Reflects
        the *current* fork choice — a reorg replaces earlier entries."""
        return [BlockRecord.from_block(b) for b in self.ledger.blocks]

    def chain_payloads(self) -> List[BlockPayload]:
        """Payload evidence for every committed block, chain order (what
        a peer pulls to run fork choice)."""
        return [self._payloads[h] for h in range(self.ledger.height)]
