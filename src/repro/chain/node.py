"""The ``Node``: one PNPCoin participant, the Fig. 1 loop behind a facade.

Composes the Runtime Authority (review + publication), the Ledger
(chained commitments), the CreditBook (rewards) and the
DifficultyController (§3.1/§5 args-per-block retargeting) behind four
calls::

    node = Node()
    node.submit(jash)          # researcher -> RA review
    receipt = node.mine_block()  # publish -> mine -> verify -> commit
    node.audit(height)         # re-verify any committed block
    node.state()               # typed snapshot of the whole node

Every committed block is self-verified *before* it is appended — a node
never extends its own chain with a payload a peer would reject.  The
``receive``/``consider_chain`` pair is the peer-side protocol
``chain/network.py`` drives: bit-exact re-verification on receive, and
longest-valid-chain fork choice when tips diverge.

``repro.core.*`` stays the stable kernel layer underneath; nothing here
reaches around the public surfaces of executor/ledger/rewards/verify.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.authority import ReviewReport, RuntimeAuthority
from repro.core.difficulty import DifficultyController
from repro.core.jash import Jash
from repro.core.ledger import Block, Ledger
from repro.core.rewards import CreditBook
from repro.chain.store import ChainStore, collect_jash_fns
from repro.chain.workload import (
    BlockContext, BlockPayload, ChainError, ClassicSha256Workload,
    JashFullWorkload, JashOptimalWorkload, RewardEntries, Workload,
    is_stateful, verify_chain_batched,
)


class VerifyCache:
    """Content-addressed record of payloads already verified in one
    *trust domain* (a pool of honest nodes sharing verification work —
    ``Network``/``Sim`` create one and hand it to their nodes).

    An entry means "this exact payload object, committed under this
    ``block_hash``, passed workload verification on some node of the
    domain"; peers then skip re-running the §3 req. 2 re-execution and
    re-verify nothing but the cheap header/consensus checks.  Two
    guards keep cache hits consensus-identical to full verification:

    * hits require the **same payload object** (``is``), not just the
      same block hash — a Byzantine sender shipping tampered evidence
      under an honest header misses and gets fully verified;
    * only **stateless** workloads participate: training verification
      doubles as state sync and must replay on every node.

    The domain assumption is that member nodes run an identical
    verification policy (same workload parameters).  Nodes that do not
    — or adversarial-scenario nodes that must re-verify everything
    themselves — opt out with ``Node(use_verify_cache=False)``.

    ``maxsize`` bounds the cache (entries pin whole payloads — full
    evidence arrays included — and a long-running domain would
    otherwise retain every orphaned and reorged-away block forever).
    Eviction is **finality-aware**: once a member node reports a
    finalized height (``note_finalized``), entries at or below it are
    evicted first — a finalized block is never re-verified again (every
    member already holds it, and fork choice substitutes local evidence
    below the fork point), so they are pure dead weight.  With no
    finality information the policy degrades to plain FIFO.  An evicted
    block simply costs its next receiver one ordinary re-verification.
    ``hits``/``misses``/``evictions`` count the domain's traffic.
    """

    def __init__(self, maxsize: int = 4096) -> None:
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._verified: Dict[str, BlockPayload] = {}
        self._heights: Dict[str, int] = {}
        self._finalized = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._verified)

    def check(self, block_hash: str, payload: BlockPayload) -> bool:
        """True iff this exact payload was already verified under this
        block hash somewhere in the trust domain."""
        if self._verified.get(block_hash) is payload:
            self.hits += 1
            return True
        self.misses += 1
        return False

    def note_finalized(self, height: int) -> None:
        """A member node finalized up to ``height`` — entries at or
        below it become preferred eviction victims."""
        if height > self._finalized:
            self._finalized = height

    def add(self, block_hash: str, payload: BlockPayload,
            height: Optional[int] = None) -> None:
        """Record a payload that just passed workload verification
        (``height`` is the block's chain height, fed to the
        finality-aware eviction policy when known)."""
        if block_hash not in self._verified:
            while len(self._verified) >= self.maxsize:
                self._evict_one()
            self._verified[block_hash] = payload
            if height is not None:
                self._heights[block_hash] = height

    def _evict_one(self) -> None:
        victim = None
        if self._finalized:
            for key, h in self._heights.items():
                if h <= self._finalized:           # finalized-behind first
                    victim = key
                    break
        if victim is None:
            victim = next(iter(self._verified))    # then plain FIFO
        self._verified.pop(victim)
        self._heights.pop(victim, None)
        self.evictions += 1


@dataclasses.dataclass(frozen=True)
class _ChainSnapshot:
    """Periodic per-node checkpoint fork choice restarts from: the
    credit book and stateful-workload state as of ``height`` committed
    blocks.  Ledger blocks and payloads are not stored — the common
    prefix up to the fork point is shared with the live chain."""
    height: int
    balances: Dict[int, float]
    total_issued: float
    wl_snaps: Tuple[Tuple[str, object], ...]   # stateful name -> snap


def _stateful_snapshot(wl) -> object:
    """Snapshot a stateful workload without forcing lazy state into
    existence: ``None`` stands for "pristine, restore == reset"."""
    if getattr(wl, "is_pristine", lambda: False)():
        return None
    return wl.snapshot()


def _stateful_restore(wl, snap) -> None:
    if snap is None:
        wl.reset()
    else:
        wl.restore(snap)


@dataclasses.dataclass(frozen=True)
class BlockRecord:
    """Typed view of one committed block (replaces the positional
    ``ledger.append(...)`` kwargs soup at the API boundary)."""
    height: int
    prev_hash: str
    block_hash: str
    workload: str
    jash_id: str
    merkle_root: str
    winner: Optional[int]
    best_res: Optional[str]
    n_results: int
    state_digest: str

    @classmethod
    def from_block(cls, blk: Block) -> "BlockRecord":
        return cls(height=blk.height, prev_hash=blk.prev_hash,
                   block_hash=blk.block_hash, workload=blk.mode,
                   jash_id=blk.jash_id, merkle_root=blk.merkle_root,
                   winner=blk.winner, best_res=blk.best_res,
                   n_results=blk.n_results, state_digest=blk.state_digest)

    def to_block(self) -> Block:
        """The ledger ``Block`` this record describes (what goes on the
        wire; the content hash is timestamp-free so it round-trips)."""
        return Block(height=self.height, prev_hash=self.prev_hash,
                     jash_id=self.jash_id, mode=self.workload,
                     merkle_root=self.merkle_root, winner=self.winner,
                     best_res=self.best_res, n_results=self.n_results,
                     state_digest=self.state_digest)


@dataclasses.dataclass(frozen=True)
class BlockReceipt:
    """What ``mine_block`` hands back: the committed record, the payload
    evidence (what peers re-verify), and the credits it minted.  A
    receipt only exists for a block that passed self-verification —
    ``mine_block`` raises ``ChainError`` otherwise."""
    record: BlockRecord
    payload: BlockPayload
    rewards: RewardEntries
    block_time_s: float


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What ``Node.recover`` did: how many journal blocks it replayed,
    the height it adopted after truncating damage (``truncated_records``
    counts journal records discarded — torn/corrupted tail plus blocks
    that failed re-verification), and the height after resyncing the
    lost tail from peers."""
    replayed: int
    adopted_height: int
    truncated_records: int
    resynced_height: int


@dataclasses.dataclass(frozen=True)
class NodeState:
    node_id: int
    height: int
    tip_hash: str
    queue_depth: int
    work: Optional[int]
    total_issued: float
    balances: Dict[int, float]
    chain_valid: bool


class Node:
    """One PNPCoin node: RA + ledger + credits + difficulty + workloads."""

    def __init__(self, *, node_id: int = 0,
                 workloads: Optional[Dict[str, Workload]] = None,
                 block_reward: float = 50.0,
                 classic_arg_bits: int = 10,
                 target_block_s: Optional[float] = None,
                 work: Optional[int] = None,
                 mesh: Optional[object] = None,
                 n_lanes: int = 1,
                 snapshot_interval: int = 8,
                 snapshot_ring: int = 4,
                 use_verify_cache: bool = True,
                 confirmation_depth: Optional[int] = None,
                 store: Optional[ChainStore] = None,
                 keyring: Optional[object] = None,
                 ra: Optional[RuntimeAuthority] = None) -> None:
        """``n_lanes`` is multi-lane mining: partition full/optimal
        execution over ``n_lanes`` single-device miner lanes, all run in
        one vmapped dispatch (lane ``l`` earns as global miner
        ``node_id * MINER_LANE + l``).  Mutually exclusive with a
        sharded ``mesh``, whose axes already define the miner fleet.
        Lane partitioning never changes the mined bits, so peers need no
        knowledge of a miner's lane count to verify its blocks.

        Every ``snapshot_interval`` committed blocks the node rings a
        fork-choice checkpoint (keeping the last ``snapshot_ring``), so
        ``consider_chain`` rebuilds from the newest checkpoint at or
        below the fork point instead of replaying from genesis.
        ``snapshot_interval=0`` (or a zero ring) disables checkpoints —
        fork choice then always replays from genesis, which is the
        reference behavior the incremental path must match bit-exactly.

        ``use_verify_cache=False`` keeps this node out of any shared
        ``VerifyCache`` a ``Network``/``Sim`` would attach — it then
        re-verifies every payload itself (what adversarial scenarios
        and nodes with non-default verification policy want).

        ``confirmation_depth=k`` turns on **finality**: a block with
        ``k`` committed successors is checkpointed — ``consider_chain``
        rejects any reorg whose fork point crosses it, and finalization
        prunes old checkpoint-ring entries and retained payload
        evidence so long-running memory stays bounded (block *headers*
        are kept forever; they are what hash-links the chain).  With
        checkpoints enabled the ring must cover the non-final tail
        (``confirmation_depth <= (snapshot_ring - 1) *
        snapshot_interval``) or every allowed reorg could outrun its
        own rebuild base — that interaction is validated here, at
        construction.  ``None`` (the default) keeps the pure
        longest-valid-chain behavior.

        ``store`` attaches a durable ``ChainStore`` journal: every
        commit and fork-choice rebuild is appended to it, and after a
        crash ``Node.recover(store, ...)`` rebuilds an equivalent node
        from the journal.  The store must be empty — recovery, not
        construction, is how a journal with history is adopted.

        ``keyring`` (a ``repro.chain.net.KeyRing``) turns on
        cryptographic origin binding: ``receive`` then accepts a block
        only with a ``SignedAnnounce`` whose signature verifies under
        the ring's key for ``payload.origin`` — the same rule for the
        in-process ``Network`` and the wire-level ``PeerNode``.
        ``None`` keeps the transport-level stand-in (the ``origin``
        argument's sender-index equality check)."""
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        if snapshot_interval < 0:
            raise ValueError(
                f"snapshot_interval must be >= 0, got {snapshot_interval}")
        if snapshot_ring < 0:
            raise ValueError(
                f"snapshot_ring must be >= 0, got {snapshot_ring}")
        if confirmation_depth is not None:
            if confirmation_depth < 1:
                raise ValueError(f"confirmation_depth must be >= 1, "
                                 f"got {confirmation_depth}")
            ring_span = (snapshot_ring - 1) * snapshot_interval
            if snapshot_interval > 0 and snapshot_ring > 0 \
                    and confirmation_depth > ring_span:
                raise ValueError(
                    f"confirmation_depth={confirmation_depth} exceeds the "
                    f"checkpoint ring's span of {ring_span} blocks "
                    f"((snapshot_ring - 1) * snapshot_interval = "
                    f"({snapshot_ring} - 1) * {snapshot_interval}) — an "
                    "allowed reorg inside the non-final tail could then "
                    "find no checkpoint at or below its fork point after "
                    "finality pruning; deepen the ring or lower the depth")
        if n_lanes > 1 and any(
                a in getattr(mesh, "axis_names", ())
                for a in ("pod", "data")):
            # fail at construction, not on the first mine_block deep
            # inside a simulation
            raise ValueError(
                "n_lanes is the single-device miner partition; the mesh "
                "already defines the miner fleet via its axes — use one "
                "or the other")
        self.node_id = node_id
        self.block_reward = block_reward
        self.mesh = mesh
        self.n_lanes = n_lanes
        self.ra = ra if ra is not None else RuntimeAuthority()
        self.ledger = Ledger()
        self.book = CreditBook()
        self.workloads: Dict[str, Workload] = {
            "full": JashFullWorkload(),
            "optimal": JashOptimalWorkload(),
            "classic": ClassicSha256Workload(arg_bits=classic_arg_bits),
        }
        if workloads:
            for name, wl in workloads.items():
                self._check_registration(name, wl)
                self.workloads[name] = wl
        if target_block_s is not None and work is None:
            raise ValueError(
                "target_block_s without an initial work target is a no-op "
                "retargeter — pass work= (e.g. from "
                "repro.core.difficulty.work_for_runtime) as well")
        self.work = work
        self.difficulty = (DifficultyController(target_block_s=target_block_s)
                           if target_block_s is not None else None)
        self._payloads: Dict[int, BlockPayload] = {}
        self.snapshot_interval = snapshot_interval
        self._snapshots: collections.deque = collections.deque(
            maxlen=snapshot_ring)
        self.use_verify_cache = use_verify_cache
        self.verify_cache: Optional[VerifyCache] = None
        self._hash_index: set = set()      # block hashes of self.ledger
        self._in_rebuild = False           # fork-choice commit loop
        self.confirmation_depth = confirmation_depth
        self._finalized = 0                # monotone finalized height
        self._evidence_floor = 0           # heights below: payload pruned
        self.finality_rejects = 0          # reorgs rejected at the fence
        if store is not None and not store.is_empty():
            raise ValueError(
                "store already holds journal records — a fresh node may "
                "not silently shadow an existing chain; use "
                "Node.recover(store, ...) to adopt it")
        self.store = store
        self.keyring = keyring
        self._journal_mute = False         # recovery replay: don't re-log
        self.last_recovery: Optional[RecoveryReport] = None

    # -- workload registry --------------------------------------------
    @staticmethod
    def _check_registration(name: str, wl: Workload) -> None:
        """A registered workload's dict key must equal its ``name``
        attribute — payloads circulate under ``wl.name``, so a mismatch
        would make every block this node mines under the key
        unreceivable (``workloads[payload.workload]`` missing on every
        peer, including this node's own self-verify)."""
        wl_name = getattr(wl, "name", None)
        if wl_name != name:
            raise ValueError(
                f"workload registered under key {name!r} reports "
                f"name={wl_name!r} — payloads circulate under the "
                "workload's own .name, so the registry key must match")

    def register_workload(self, wl: Workload) -> None:
        """Register an additional workload family after construction
        (e.g. one of ``repro.chain.workloads``) under its own ``name``.
        Overwriting an existing family is refused — peers re-verify
        committed payloads against the registry, so silently swapping a
        family's semantics mid-chain would strand every block it
        already mined.  Registering a *stateful* family on a node with
        committed blocks is fine: ringed fork-choice checkpoints taken
        before registration simply restore it to pristine, which is
        exactly its state at those heights."""
        name = getattr(wl, "name", None)
        if not isinstance(name, str) or not name:
            raise ValueError(
                "workload has no usable .name attribute — the Workload "
                "protocol requires one (it is the wire name payloads "
                "circulate under)")
        if name in self.workloads:
            raise ValueError(
                f"workload {wl.name!r} already registered — build the "
                "node with workloads={...} to replace a default family")
        self.workloads[wl.name] = wl

    # -- researcher side ----------------------------------------------
    def submit(self, jash: Jash, veto: bool = False) -> ReviewReport:
        """Researcher submission -> the RA's §3.3 review pipeline."""
        return self.ra.submit(jash, veto=veto)

    # -- mining side --------------------------------------------------
    def mine_block(self, workload: Optional[str] = None) -> BlockReceipt:
        """Publish -> mine -> self-verify -> commit -> reward, one block.

        ``workload=None`` follows the paper's default policy: pop the
        RA queue and run **full** mode, falling back to **classic**
        SHA-256 when the queue is empty (§3.4).  Pass "optimal",
        "training" or "classic" to select the payload explicitly.
        """
        t0 = time.perf_counter()
        if workload in (None, "full", "optimal"):
            jash, source = self.ra.publish_next()
            if source == "queued":
                name = workload or "full"
            elif workload is None:
                name = "classic"            # §3.4 fallback, default policy
            else:
                raise ChainError(
                    f"workload {workload!r} requested explicitly but the "
                    "RA queue is empty — submit a jash first or mine with "
                    "the default policy (workload=None) for the classic "
                    "fallback")
        else:
            if workload not in self.workloads:
                raise ChainError(f"unknown workload {workload!r} "
                                 f"(have {sorted(self.workloads)})")
            jash, source, name = None, workload, workload

        wl = self.workloads[name]
        ctx = BlockContext(height=self.ledger.height,
                           prev_hash=self.ledger.tip_hash,
                           node_id=self.node_id, jash=jash, source=source,
                           work=self.work, block_reward=self.block_reward,
                           mesh=self.mesh, lanes=self.n_lanes)
        try:
            payload = wl.mine(wl.prepare(ctx))
            ok = wl.verify(payload)
        except Exception:
            if source == "queued":
                self.ra.requeue(jash)       # don't lose the submission
            raise
        if not ok:
            if source == "queued":
                self.ra.requeue(jash)
            raise ChainError(
                f"self-mined {name} block at height {ctx.height} failed "
                "verification — refusing to commit")
        record, rewards = self._commit(payload)
        if self.verify_cache is not None and not is_stateful(wl):
            # the self-verification above counts for the trust domain
            self.verify_cache.add(record.block_hash, payload,
                                  height=record.height)

        dt = time.perf_counter() - t0
        if self.difficulty is not None:
            self.difficulty.observe(dt)
            if self.work is not None:
                self.work = self.difficulty.propose_work(self.work)
        return BlockReceipt(record=record, payload=payload, rewards=rewards,
                            block_time_s=dt)

    def _commit(self, payload: BlockPayload
                ) -> Tuple[BlockRecord, RewardEntries]:
        blk = self.ledger.append(
            jash_id=payload.jash_id, mode=payload.workload,
            merkle=payload.merkle_root, winner=payload.winner,
            best_res=payload.best_res, n_results=payload.n_results,
            state_digest=payload.state_digest)
        self._hash_index.add(blk.block_hash)
        self._payloads[blk.height] = payload
        if self.store is not None and not self._journal_mute:
            self.store.append_commit(blk, payload)
        rewards = self.workloads[payload.workload].reward(self.book, payload)
        # during a fork-choice rebuild the stateful workloads already
        # sit at the *tail end* state (batched verification replayed
        # them before the commit loop), so a mid-loop checkpoint would
        # pair an intermediate height with end-of-chain trainer state —
        # consider_chain suppresses the ring and pushes one consistent
        # checkpoint at the adopted tip instead
        if (self.snapshot_interval > 0 and not self._in_rebuild
                and self.ledger.height % self.snapshot_interval == 0):
            self._push_snapshot()
        self._advance_finality()
        return BlockRecord.from_block(blk), rewards

    # -- finality ------------------------------------------------------
    @property
    def finalized_height(self) -> int:
        """Heights below this are final: ``consider_chain`` refuses any
        reorg whose fork point crosses it (always 0 with
        ``confirmation_depth=None``)."""
        return self._finalized

    def _advance_finality(self) -> None:
        if self.confirmation_depth is None:
            return
        new_final = self.ledger.height - self.confirmation_depth
        if new_final > self._finalized:
            self._finalized = new_final
            if self.verify_cache is not None:
                self.verify_cache.note_finalized(self._finalized)
            self._prune_finalized()

    def _prune_finalized(self) -> None:
        """Finalization drives pruning: drop checkpoint-ring entries and
        payload evidence below the newest checkpoint at or below the
        finalized height (the *anchor* — the deepest rebuild base any
        still-allowed reorg can need).  Headers stay forever; a chain
        of pruned heights remains hash-verifiable, its evidence is just
        no longer servable to joiners (weak subjectivity — see DESIGN.md
        §12)."""
        anchor = 0
        for snap in self._snapshots:
            if anchor < snap.height <= self._finalized:
                anchor = snap.height
        if anchor == 0:
            return
        if any(s.height < anchor for s in self._snapshots):
            keep = [s for s in self._snapshots if s.height >= anchor]
            self._snapshots = collections.deque(
                keep, maxlen=self._snapshots.maxlen)
        while self._evidence_floor < anchor:
            self._payloads.pop(self._evidence_floor, None)
            self._evidence_floor += 1

    # -- fork-choice checkpoints --------------------------------------
    def _push_snapshot(self) -> None:
        wl_snaps = tuple(
            (name, _stateful_snapshot(wl))
            for name, wl in self.workloads.items() if is_stateful(wl))
        self._snapshots.append(_ChainSnapshot(
            height=self.ledger.height,
            balances=dict(self.book.balances),
            total_issued=self.book.total_issued,
            wl_snaps=wl_snaps))

    def _snapshot_at(self, height: int) -> Optional[_ChainSnapshot]:
        """Newest ringed checkpoint at or below ``height`` (None means
        restart from genesis)."""
        best = None
        for snap in self._snapshots:
            if snap.height <= height and (best is None
                                          or snap.height > best.height):
                best = snap
        return best

    # -- verifier side ------------------------------------------------
    def audit(self, height: int) -> bool:
        """Re-verify a committed block: header fields must match the
        payload and the payload must re-verify bit-exactly (§3 req. 2)."""
        if not 0 <= height < self.ledger.height:
            raise ChainError(f"no block at height {height}")
        blk = self.ledger.blocks[height]
        payload = self._payloads.get(height)
        if payload is None:
            return False
        return (self._payload_matches(blk, payload)
                and self.workloads[payload.workload].verify(payload))

    def audit_chain(self, heights: Optional[Sequence[int]] = None) -> bool:
        """Batched ``audit``: re-verify many committed blocks (default:
        the whole chain) with the stateless workloads grouped into
        single dispatches.  Accept/reject equals ``all(self.audit(h)
        for h in heights)``; like ``audit``, this never consults the
        shared ``VerifyCache`` — an audit is this node proving the
        chain to itself.  The default range starts at the evidence
        floor: payloads below it were pruned at finalization, and a
        finalized block's evidence is by definition no longer held."""
        hs = list(range(self._evidence_floor, self.ledger.height)) \
            if heights is None else list(heights)
        payloads = []
        for h in hs:
            if not 0 <= h < self.ledger.height:
                raise ChainError(f"no block at height {h}")
            payload = self._payloads.get(h)
            if payload is None or not self._payload_matches(
                    self.ledger.blocks[h], payload):
                return False
            payloads.append(payload)
        return verify_chain_batched(self.workloads, payloads)

    def _payload_matches(self, blk: Block, payload: BlockPayload) -> bool:
        return (blk.jash_id == payload.jash_id
                and blk.mode == payload.workload
                and blk.merkle_root == payload.merkle_root
                and blk.winner == payload.winner
                and blk.best_res == payload.best_res
                and blk.n_results == payload.n_results
                and blk.state_digest == payload.state_digest
                and payload.workload in self.workloads)

    # -- peer protocol (driven by chain/network.py) -------------------
    def has_block(self, block_hash: str) -> bool:
        """True iff a block with this content hash is already committed
        — the duplicate check gossip layers run before treating a failed
        ``receive`` as a fork signal (at-least-once delivery must be an
        idempotent no-op, never a chain pull).  O(1) via a hash index
        maintained by commit/fork-choice (gossip runs this once per
        delivery, so a chain-length scan would be quadratic over a
        sim's lifetime)."""
        return block_hash in self._hash_index

    def receive(self, block: Block, payload: BlockPayload,
                origin: Optional[int] = None,
                announce: Optional[object] = None) -> bool:
        """Accept a broadcast block iff it extends our tip and the payload
        re-verifies bit-exactly.  Returns False on any mismatch (the
        network layer then falls back to ``consider_chain``).

        Reward-determining payload fields are enforced here, not in the
        workload: ``block_reward`` must equal this node's configured
        reward (a consensus parameter — a payload claiming more mints
        nothing), and the payload may not claim someone else's lane.
        Origin binding is one rule with two strengths: with a
        ``keyring`` configured, ``announce`` (a
        ``repro.chain.net.SignedAnnounce``) is *required* and must bind
        this exact (block, payload) pair to ``payload.origin`` under
        the ring's key for it; without one, ``origin`` (the transport-
        level sender the in-process network passes) must equal the
        claimed origin — the unsigned stand-in for the same check."""
        if (block.height != self.ledger.height
                or block.prev_hash != self.ledger.tip_hash):
            return False
        if payload.block_reward != self.block_reward:
            return False
        if self.keyring is not None:
            if announce is None or not announce.verify(
                    self.keyring, block, payload):
                return False
        elif origin is not None and payload.origin != origin:
            return False
        if not self._payload_matches(block, payload):
            return False
        wl = self.workloads.get(payload.workload)
        if wl is None:
            return False
        shareable = not is_stateful(wl) and self.verify_cache is not None
        if not (shareable
                and self.verify_cache.check(block.block_hash, payload)):
            if not wl.verify(payload):
                return False
            if shareable:
                self.verify_cache.add(block.block_hash, payload,
                                      height=block.height)
        self._commit(payload)
        return True

    def consider_chain(self, blocks: Sequence[Block],
                       payloads: Sequence[BlockPayload]) -> bool:
        """Longest-valid-chain fork choice: adopt a competing chain iff it
        is strictly longer, links from genesis, and every payload
        re-verifies.  The ledger and credit book are rebuilt from the
        adopted payloads (credits follow the chain, not the node).

        The rebuild is **fork-point incremental**: hash links are still
        checked from genesis (cheap host work), but payload
        re-verification and ledger/book/trainer reconstruction restart
        from the newest ringed checkpoint at or below the fork point —
        everything before it is common prefix this node already
        verified when it committed it.  Stateless payloads of the
        candidate tail verify in one batched dispatch (minus shared
        ``VerifyCache`` hits); stateful ones replay in chain order from
        the checkpoint.  Accept/reject, adopted tips, and rebuilt books
        are bit-identical to a genesis replay (``snapshot_interval=0``
        forces that reference behavior).

        Malformed *calls* — an empty candidate or mismatched
        blocks/payloads lengths — raise ``ChainError`` (they are caller
        bugs, not losing forks); an invalid candidate *chain* returns
        False.  With finality on (``confirmation_depth``), a candidate
        whose fork point lies below our finalized height is refused
        however long it is (counted in ``finality_rejects`` — the fence
        that defeats long-range rewrites).  Below the fork point the
        sender's evidence is ignored in favor of our own retained
        payloads (bit-identical blocks ⇒ the evidence we committed), so
        a peer that pruned finalized evidence may serve ``None`` there;
        at or beyond the fork point every payload must be present and
        cross-check its header."""
        if len(blocks) == 0 or len(blocks) != len(payloads):
            raise ChainError(
                f"consider_chain needs aligned non-empty sequences — got "
                f"{len(blocks)} blocks and {len(payloads)} payloads")
        if len(blocks) <= self.ledger.height:
            return False
        prev = Ledger.GENESIS_HASH
        for i, blk in enumerate(blocks):
            if blk.height != i or blk.prev_hash != prev:
                return False
            prev = blk.block_hash
        # fork point: longest common block-hash prefix with our chain
        common = 0
        for ours, theirs in zip(self.ledger.blocks, blocks):
            if ours.block_hash != theirs.block_hash:
                break
            common += 1
        if self.confirmation_depth is not None and common < self._finalized:
            self.finality_rejects += 1
            return False
        use = list(payloads)
        for i in range(common):
            use[i] = self._payloads.get(i, use[i])
        # the block reward is a consensus parameter; origin attribution
        # inside a relayed chain is a signature problem (out of scope for
        # the in-process network) and is NOT re-checked here
        for i in range(common, len(blocks)):
            p = use[i]
            if (p is None or p.block_reward != self.block_reward
                    or not self._payload_matches(blocks[i], p)):
                return False
        snap = self._snapshot_at(common)
        start = snap.height if snap is not None else 0
        ring_snaps = dict(snap.wl_snaps) if snap is not None else {}
        # Stateful workloads (training) advance while verifying.  Roll
        # them back to the checkpoint so the candidate tail is replayed
        # on exactly the state the common prefix produced (a fork that
        # discards a local training block must rewind the trainer too,
        # or the node's future blocks are unverifiable by peers).  The
        # pre-fork state rolls everything back if the candidate fails.
        stateful = [(name, wl) for name, wl in self.workloads.items()
                    if is_stateful(wl)]
        rollback = [(wl, _stateful_snapshot(wl)) for _, wl in stateful]
        for name, wl in stateful:
            _stateful_restore(wl, ring_snaps.get(name))
        precleared = [False] * (len(use) - start)
        if self.verify_cache is not None:
            for i in range(start, len(use)):
                wl = self.workloads.get(use[i].workload)
                if (wl is not None and not is_stateful(wl)
                        and self.verify_cache.check(blocks[i].block_hash,
                                                    use[i])):
                    precleared[i - start] = True
        if not verify_chain_batched(self.workloads, use[start:],
                                    precleared=precleared):
            for wl, pre_fork in rollback:
                _stateful_restore(wl, pre_fork)
            return False
        # adopt: truncate to the checkpoint and rebuild from there (the
        # kept prefix is bit-identical between the two chains).  The
        # journal stays append-only across reorgs: one TRUNCATE record,
        # then the adopted tail as ordinary commits.
        if self.store is not None and start < self.ledger.height:
            self.store.append_truncate(start)
        del self.ledger.blocks[start:]
        self.book.balances = dict(snap.balances) if snap else {}
        self.book.total_issued = snap.total_issued if snap else 0.0
        self._payloads = {h: self._payloads[h]
                          for h in range(self._evidence_floor, start)}
        self._hash_index = {b.block_hash for b in self.ledger.blocks}
        # checkpoints past the fork point describe the abandoned branch
        keep = [s for s in self._snapshots if s.height <= common]
        self._snapshots = collections.deque(keep,
                                            maxlen=self._snapshots.maxlen)
        self._in_rebuild = True
        try:
            for blk, payload in zip(blocks[start:], use[start:]):
                self._commit(payload)
                if self.verify_cache is not None and not is_stateful(
                        self.workloads[payload.workload]):
                    self.verify_cache.add(blk.block_hash, payload,
                                          height=blk.height)
        finally:
            self._in_rebuild = False
        # one checkpoint at the adopted tip, where ledger, book, and
        # stateful workloads are all consistent again
        if self.snapshot_interval > 0 and self._snapshots.maxlen:
            self._push_snapshot()
        return True

    # -- introspection ------------------------------------------------
    def state(self) -> NodeState:
        """Typed snapshot of the whole node.  ``chain_valid`` re-walks
        the hash links from genesis (cheap header check only — use
        ``audit`` for payload re-verification); ``balances`` is a copy,
        so a held snapshot is immune to later fork-choice rebuilds."""
        return NodeState(node_id=self.node_id, height=self.ledger.height,
                         tip_hash=self.ledger.tip_hash,
                         queue_depth=self.ra.queue_depth, work=self.work,
                         total_issued=self.book.total_issued,
                         balances=dict(self.book.balances),
                         chain_valid=self.ledger.verify_chain())

    @property
    def records(self) -> List[BlockRecord]:
        """Typed view of the committed chain, genesis -> tip.  Reflects
        the *current* fork choice — a reorg replaces earlier entries."""
        return [BlockRecord.from_block(b) for b in self.ledger.blocks]

    def chain_payloads(self) -> List[Optional[BlockPayload]]:
        """Payload evidence for every committed block, chain order (what
        a peer pulls to run fork choice).  Heights whose evidence was
        pruned at finalization yield ``None`` — a puller substitutes its
        own retained evidence below the fork point (``consider_chain``),
        and a fresh joiner must bootstrap from a peer that still holds
        the full evidence (weak subjectivity; DESIGN.md §12)."""
        return [self._payloads.get(h) for h in range(self.ledger.height)]

    # -- crash recovery (the ChainStore journal) ----------------------
    @classmethod
    def recover(cls, store: ChainStore, *,
                peers: Sequence["Node"] = (),
                jash_fns: Optional[Dict[str, object]] = None,
                node: Optional["Node"] = None,
                **node_kwargs) -> "Node":
        """Rebuild a node from its durable journal after a crash.

        Reads the journal (damaged tails already truncated by
        ``ChainStore.read_chain``), replays the surviving chain through
        the **ordinary verify path** — a block that fails re-verification
        truncates the replay there instead of crashing — commits the
        adopted prefix, compacts the journal to it, and finally pulls
        each node in ``peers`` through ``consider_chain`` to resync the
        lost tail.  What happened is recorded in ``last_recovery``.

        The recovered node is built from ``node_kwargs`` (same
        constructor arguments as the crashed node — workload parameters
        are consensus policy, they are not journaled), or pass a
        pre-built fresh ``node=`` shell.  ``jash_fns`` maps jash names
        to their functions for payload families whose evidence must be
        re-*executed* (full/optimal researcher jashes); the classic
        fallback and the application workloads resolve themselves."""
        if node is None:
            node = cls(**node_kwargs)
        if node.ledger.height != 0 or node.store is not None:
            raise ChainError(
                "Node.recover needs a fresh node shell (no committed "
                "blocks, no attached store)")
        fns = collect_jash_fns(node.workloads, jash_fns)
        read = store.read_chain(jash_fns=fns)
        adopted = node._replay_journal(read.blocks, read.payloads)
        truncated = read.truncated_records + (len(read.blocks) - adopted)
        if not read.clean or adopted < len(read.blocks):
            store.rewrite(read.blocks[:adopted], read.payloads[:adopted])
        node.store = store
        for peer in peers:
            if peer.ledger.height > node.ledger.height:
                node.consider_chain(list(peer.ledger.blocks),
                                    peer.chain_payloads())
        node.last_recovery = RecoveryReport(
            replayed=len(read.blocks), adopted_height=adopted,
            truncated_records=truncated,
            resynced_height=node.ledger.height)
        return node

    def _replay_journal(self, blocks: Sequence[Block],
                        payloads: Sequence[Optional[BlockPayload]]) -> int:
        """Commit the longest valid prefix of a journal chain; returns
        how many blocks were adopted.  Validity is exactly what
        ``consider_chain`` demands: genesis-rooted hash links,
        header/payload cross-checks, consensus reward, and bit-exact
        workload re-verification."""
        n = 0
        prev = Ledger.GENESIS_HASH
        for blk, payload in zip(blocks, payloads):
            if (blk.height != n or blk.prev_hash != prev
                    or payload is None
                    or payload.block_reward != self.block_reward
                    or not self._payload_matches(blk, payload)):
                break
            prev = blk.block_hash
            n += 1
        ok = 0
        try:
            if n and verify_chain_batched(self.workloads, payloads[:n]):
                ok = n
        except Exception:
            ok = 0
        if ok == 0 and n:
            # the batched pass failed somewhere — scan block by block
            # for the longest verifying prefix (stateful workloads
            # advance exactly as far as verification succeeds, which is
            # the adopted tail state); reset them first, the failed
            # batch may have advanced them partway
            for _, wl in [(m, w) for m, w in self.workloads.items()
                          if is_stateful(w)]:
                wl.reset()
            for i in range(n):
                try:
                    if not verify_chain_batched(self.workloads,
                                                payloads[i:i + 1]):
                        break
                except Exception:
                    break
                ok = i + 1
        self._in_rebuild = True
        self._journal_mute = True      # replay must not re-journal
        try:
            for payload in payloads[:ok]:
                self._commit(payload)
        finally:
            self._in_rebuild = False
            self._journal_mute = False
        if ok and self.snapshot_interval > 0 and self._snapshots.maxlen:
            self._push_snapshot()
        return ok
