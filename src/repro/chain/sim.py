"""``repro.chain.sim`` — deterministic event-driven async gossip simulator.

``chain/network.py`` models instantaneous, honest, lock-step broadcast;
the paper's claim is that the publication→mining→verification loop
survives a *real* network.  This module layers a seeded discrete-event
scheduler over the existing ``Node``/``Workload`` API so the scenarios
where PoUW schemes actually break — fork depth and verification lag
under asynchrony — can be measured:

* **latency & loss** — every link delivery draws from a configurable
  latency distribution (``LinkModel``) and may be dropped;
* **partitions** — ``partition_at``/``heal_at`` split the network into
  isolated groups and rejoin them (healing triggers tip announcements,
  so the groups converge by longest-valid-chain fork choice);
* **churn** — ``join_at`` adds a node mid-chain; it syncs by pulling a
  peer's chain through ``Node.consider_chain`` exactly like any forked
  peer;
* **adversaries** — ``WithholdingMiner`` (selfish mining: private chain
  released later), ``StaleSpammer`` (rebroadcasts old blocks),
  ``PayloadCorrupter`` (tampers every outgoing block/payload pair),
  ``LongRangeRewriter`` (re-mines history from behind the finality
  horizon) — all exercising the receive-side re-verification,
  fork-choice rollback, and finality-fence paths;
* **crash faults** — ``crash_at`` discards a node's entire in-memory
  state (its durable ``ChainStore`` journal survives as the "disk"),
  ``restart_at`` rebuilds it mid-simulation via ``Node.recover``, and
  ``corrupt_store_at`` bit-flips or tears the journal tail first —
  recovery must truncate gracefully and resync from peers, never raise;
* **retry-with-backoff** — a randomly dropped delivery is retransmitted
  up to ``LinkModel.max_retries`` times with exponential backoff before
  it counts as lost (``drops_final``), so gossip is no longer silently
  lossy between periodic announces.

**Determinism invariant**: given the same nodes, scenario, and
``SimConfig.seed``, a run is *bit-reproducible* — the event order, every
latency/drop draw, the final chains, the credit books, and the
``SimReport`` (its ``to_json()`` included) are identical across runs.
Everything random goes through one seeded ``random.Random``; simulated
time never reads the wallclock.  Nodes with wallclock difficulty
retargeting (``target_block_s``) are rejected at construction because
their chain content would depend on host timing (override with
``SimConfig(allow_wallclock_difficulty=True)`` if you explicitly want a
non-reproducible run).

Run the canonical scenarios from the CLI::

    PYTHONPATH=src python -m repro.chain.sim --scenario partition
    PYTHONPATH=src python -m repro.chain.sim --scenario adversarial
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import json
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.chain.network import Network
from repro.chain.node import Node, VerifyCache
from repro.chain.store import ChainStore
from repro.chain.workload import BlockPayload, ChainError
from repro.core.ledger import Block

__all__ = [
    "Adversary",
    "LinkModel",
    "LongRangeRewriter",
    "PayloadCorrupter",
    "Sim",
    "SimConfig",
    "SimReport",
    "StaleSpammer",
    "WithholdingMiner",
    "adversarial_scenario",
    "chaos_scenario",
    "heterogeneous_scenario",
    "partitioned_scenario",
    "throughput_scenario",
]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-link delivery model: uniform latency in ``[min_latency,
    max_latency]`` seconds of *simulated* time, i.i.d. drop probability,
    and the extra round-trip a failed direct delivery pays before the
    receiver pulls the sender's whole chain (``sync_latency``).

    A randomly dropped send is retransmitted up to ``max_retries``
    times, waiting ``retry_backoff * 2**attempt`` before each retry;
    only a message whose every attempt dropped counts as lost
    (``SimReport.drops_final``).  Partition drops are not retried — the
    heal announces tips instead.  ``max_retries=0`` restores the old
    fire-and-forget gossip."""
    min_latency: float = 0.01
    max_latency: float = 0.05
    drop_prob: float = 0.0
    sync_latency: float = 0.1
    max_retries: int = 2
    retry_backoff: float = 0.05


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Simulator knobs.  ``seed`` drives every random draw (latency,
    drops, jitter, churn peer choice); ``max_events`` is the runaway
    backstop for event loops.

    ``shared_verify_cache`` puts the *honest* nodes in one trust domain
    (a ``VerifyCache``): each unique broadcast payload is §3 req. 2
    re-verified once across the pool instead of once per node — the
    lever that lets 64-node scenarios run in reasonable wall-clock.
    Adversary-controlled nodes are never enrolled, and honest nodes can
    opt out individually with ``Node(use_verify_cache=False)`` (an
    adversarial analysis in which every node must check everything
    itself).  Accept/reject decisions — and hence the ``SimReport`` —
    are identical either way; only who runs the verification changes."""
    seed: int = 0
    link: LinkModel = LinkModel()
    max_events: int = 100_000
    allow_wallclock_difficulty: bool = False
    shared_verify_cache: bool = True


class Adversary:
    """Base adversary: honest behavior, with the two hooks dishonest
    nodes override.  ``transform`` is applied to *everything* the node
    sends (block broadcasts, tip announcements, and full-chain syncs),
    so a corrupting node cannot accidentally leak its honest local state;
    ``withholds()`` keeps mined blocks private until released."""

    def install(self, sim: "Sim", node_id: int) -> None:
        """Called once when the simulation starts; schedule any timed
        behavior (releases, spam) here."""

    def withholds(self) -> bool:
        return False

    def transform(self, block: Block, payload: BlockPayload
                  ) -> Tuple[Block, BlockPayload]:
        return block, payload


class WithholdingMiner(Adversary):
    """Selfish miner: keeps every block it mines private, then at
    ``release_at`` announces its tip — if the private chain is strictly
    longer, honest peers reorg onto it (their own blocks are orphaned
    and their credit books rebuilt from the adopted payloads)."""

    def __init__(self, release_at: float) -> None:
        self.release_at = release_at
        self.withholding = True

    def install(self, sim: "Sim", node_id: int) -> None:
        sim.at(self.release_at, sim._release, node_id)

    def withholds(self) -> bool:
        return self.withholding


class StaleSpammer(Adversary):
    """Rebroadcasts an old block of its own chain every ``every``
    seconds until ``until`` — peers count the duplicates and discard
    them without state changes (a receive-side idempotence check)."""

    def __init__(self, every: float, until: float, height: int = 0) -> None:
        self.every, self.until, self.height = every, until, height

    def install(self, sim: "Sim", node_id: int) -> None:
        t = self.every
        while t <= self.until:
            sim.at(t, sim._spam, node_id, self.height)
            t += self.every


class PayloadCorrupter(Adversary):
    """Byzantine sender: every outgoing (block, payload) pair gets a
    consistent bogus Merkle root, so the header/payload cross-check
    passes and rejection happens where it must — in the workload's
    deterministic re-verification (§3 req. 2).  Corrupted *chains*
    additionally break their hash links, so ``consider_chain`` rejects
    them at the linkage check."""

    BAD_ROOT = "f" * 64

    def transform(self, block: Block, payload: BlockPayload
                  ) -> Tuple[Block, BlockPayload]:
        return (dataclasses.replace(block, merkle_root=self.BAD_ROOT),
                dataclasses.replace(payload, merkle_root=self.BAD_ROOT))


class LongRangeRewriter(Adversary):
    """Long-range attack: at ``rewrite_at`` the adversary throws away
    its own chain back to ``fork_height`` — a point it expects to lie
    *behind* the honest finality horizon — privately re-mines ``length``
    alternate blocks on top of the kept prefix (one every ``every``
    simulated seconds), then announces the result.  The rewritten chain
    is strictly longer than the honest one, so a pure
    longest-valid-chain node would adopt it and rewrite settled
    history; nodes with ``confirmation_depth`` set refuse it at the
    finality fence instead (counted in ``SimReport.finality_rejects``,
    which the chaos scenario pins to every honest node)."""

    def __init__(self, rewrite_at: float, fork_height: int,
                 length: int, *, every: float = 0.02) -> None:
        self.rewrite_at = rewrite_at
        self.fork_height = fork_height
        self.length = length
        self.every = every
        self.withholding = False

    def install(self, sim: "Sim", node_id: int) -> None:
        sim.at(self.rewrite_at, sim._long_range_rewrite, node_id, self)

    def withholds(self) -> bool:
        return self.withholding


@dataclasses.dataclass(frozen=True)
class _MinedBlock:
    block_hash: str
    height: int
    origin: int
    t_mined: float
    workload: str


@dataclasses.dataclass
class SimReport:
    """Deterministic summary of one simulation run (same seed ⇒
    bit-identical report; see the module docstring).

    Health metrics: ``fork_depth_hist`` maps reorg depth (number of
    blocks a node discarded when adopting a competing chain; depth 0 =
    pure catch-up sync) to occurrence count; ``orphan_rate`` is the
    fraction of mined blocks that did not end up in the canonical chain;
    ``ttf_mean``/``ttf_max`` are time-to-finality — mine time to the
    moment the *last* honest node accepted the block — over canonical
    blocks every honest node holds; ``credit_divergence`` is the maximum
    pairwise L1 distance between honest nodes' credit books (zero iff
    the books are bit-consistent)."""
    seed: int
    n_nodes: int
    n_events: int
    t_end: float
    # mining
    blocks_mined: int
    blocks_withheld: int
    mine_failures: int
    # gossip
    deliveries_sent: int
    accepts: int
    duplicates: int
    rejects: int
    drops_random: int
    drops_partition: int
    spam_sent: int
    retries: int
    drops_final: int
    drops_crash: int
    # fork choice
    syncs: int
    reorgs: int
    sync_rejects: int
    joins: int
    fork_depth_hist: Dict[int, int]
    # crash faults & recovery
    crashes: int
    recoveries: int
    truncated_records: int
    corruptions: int
    # finality (confirmation_depth nodes; divergence must be 0 for
    # honest nodes once converged)
    finality_rejects: int
    finalized_heights: List[int]
    finalized_divergence: int
    # chain health
    canonical_height: int
    orphans: int
    orphan_rate: float
    finalized: int
    unfinalized: int
    ttf_mean: float
    ttf_max: float
    final_heights: List[int]
    converged: bool
    credit_divergence: float

    def to_json(self) -> str:
        """Canonical JSON (sorted keys) — the bit-reproducibility
        artifact: two runs with the same seed must produce identical
        strings."""
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


class Sim:
    """Seeded discrete-event asynchronous network simulator over
    ``Node`` instances.

    Wire protocol per event (all on *simulated* time):

    1. a ``mine_at``/``auto_mine`` event makes one node mine one block
       (``Node.mine_block`` — self-verified before commit, exactly as on
       the synchronous ``Network``);
    2. the block is gossiped to every connected peer, each delivery
       drawing its own latency (and possibly being dropped);
    3. on delivery the peer runs the bit-exact receive-side
       re-verification (``Node.receive``); a tip mismatch schedules a
       chain pull one ``sync_latency`` later, which applies
       longest-valid-chain fork choice (``Node.consider_chain`` —
       ledger *and* credit book rebuilt, stateful workloads rolled
       back/replayed);
    4. partitions drop cross-group traffic (including in-flight messages
       at delivery time); healing makes every node announce its tip so
       divergent groups reconverge through step 3.

    Construction rejects duplicate node ids, workload instances shared
    across nodes (sharing voids independent re-verification — same rule
    as ``Network.create``), and wallclock difficulty retargeting (breaks
    bit-reproducibility; see the module docstring).
    """

    def __init__(self, nodes: Sequence[Node],
                 config: SimConfig = SimConfig(),
                 adversaries: Optional[Dict[int, Adversary]] = None) -> None:
        if not nodes:
            raise ValueError("a simulation needs at least one node")
        self.config = config
        self._nodes: Dict[int, Node] = {}
        seen_wl: Dict[int, int] = {}
        for node in nodes:
            self._check_node(node, seen_wl)
            self._nodes[node.node_id] = node
        self._adversaries = dict(adversaries or {})
        for nid in self._adversaries:
            if nid not in self._nodes:
                raise ValueError(f"adversary for unknown node {nid}")
        self.verify_cache = (VerifyCache()
                             if config.shared_verify_cache else None)
        for node in self._nodes.values():
            self._enroll(node)

        self._rng = random.Random(config.seed)
        self._events: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = 0
        self.now = 0.0
        self._group: Dict[int, int] = {nid: 0 for nid in self._nodes}

        # bookkeeping for the report
        self._mined: Dict[str, _MinedBlock] = {}
        self._accepts: Dict[str, Dict[int, float]] = {}
        self._fork_depths: Dict[int, int] = {}
        self._counters = {k: 0 for k in (
            "blocks_mined", "blocks_withheld", "mine_failures",
            "deliveries_sent", "accepts", "duplicates", "rejects",
            "drops_random", "drops_partition", "spam_sent",
            "retries", "drops_final", "drops_crash",
            "syncs", "reorgs", "sync_rejects", "joins",
            "crashes", "recoveries", "truncated_records", "corruptions")}
        self._n_events = 0
        # crashed node id -> its surviving ChainStore (None if diskless)
        self._crashed: Dict[int, Optional[ChainStore]] = {}

        for nid, adv in sorted(self._adversaries.items()):
            adv.install(self, nid)

    def _enroll(self, node: Node) -> None:
        """Enroll an honest node in the shared verify-cache trust
        domain.  Adversary-controlled nodes are excluded (they should
        not be able to pre-clear payloads for honest peers, nor lean on
        honest verification work), as are nodes that opted out or
        already belong to a domain."""
        if (self.verify_cache is not None
                and node.node_id not in self._adversaries
                and node.use_verify_cache and node.verify_cache is None):
            node.verify_cache = self.verify_cache

    def _check_node(self, node: Node, seen_wl: Dict[int, int]) -> None:
        if node.node_id in self._nodes:
            raise ValueError(f"duplicate node_id {node.node_id}")
        if node.difficulty is not None \
                and not self.config.allow_wallclock_difficulty:
            raise ValueError(
                "node retargets difficulty on wallclock block times — that "
                "makes chain content depend on host timing and breaks the "
                "simulator's bit-reproducibility guarantee; construct sim "
                "nodes without target_block_s (or set "
                "SimConfig(allow_wallclock_difficulty=True) for an "
                "explicitly non-reproducible run)")
        for wl in node.workloads.values():
            owner = seen_wl.setdefault(id(wl), node.node_id)
            if owner != node.node_id:
                raise ValueError(
                    f"workload instance shared between nodes {owner} and "
                    f"{node.node_id} — every node needs its own Workload "
                    "objects or 're-verification' compares a stateful "
                    "workload's history against itself")

    # -- scheduling API -----------------------------------------------
    def at(self, t: float, fn: Callable, *args) -> None:
        """Schedule ``fn(*args)`` at simulated time ``t`` (events at the
        same time fire in scheduling order — the tiebreaker that keeps
        runs deterministic)."""
        self._schedule(t, fn, *args)

    def _schedule(self, t: float, fn: Callable, *args) -> None:
        # simulated time is monotonic: nothing may fire before `now`
        # (past-dated events would invert mine/accept timestamps and
        # corrupt the finality metrics)
        heapq.heappush(self._events, (max(t, self.now), self._seq, fn,
                                      args))
        self._seq += 1

    def mine_at(self, t: float, node_id: int,
                workload: Optional[str] = None) -> None:
        """One node mines one block at ``t`` and gossips it to every
        connected peer (unless its adversary withholds)."""
        self._schedule(t, self._mine, node_id, workload)

    def auto_mine(self, node_id: int, every: float, until: float, *,
                  start: Optional[float] = None, jitter: float = 0.0,
                  workload: Optional[str] = None) -> None:
        """Recurring mining: first block at ``start`` (default
        ``every``), then every ``every`` ± uniform ``jitter`` seconds
        while the next tick is <= ``until``."""
        self._schedule(start if start is not None else every,
                       self._auto_tick, node_id, every, until, jitter,
                       workload)

    def partition_at(self, t: float,
                     groups: Sequence[Sequence[int]]) -> None:
        """Split the network at ``t``: only nodes in the same group can
        exchange messages afterwards (nodes absent from every group are
        isolated).  Messages in flight across a new boundary are dropped
        at delivery time."""
        self._schedule(t, self._partition,
                       tuple(tuple(g) for g in groups))

    def heal_at(self, t: float) -> None:
        """Rejoin all groups at ``t``.  Every node then announces its
        tip, so partitioned chains reconverge by longest-valid-chain
        fork choice (equal-length competing tips stay split until the
        next mined block breaks the tie, as on any real chain)."""
        self._schedule(t, self._heal)

    def join_at(self, t: float, node: Node,
                sync_from: Optional[int] = None) -> None:
        """Node churn: ``node`` joins mid-chain at ``t`` and immediately
        pulls a connected peer's chain (``sync_from``, or a seeded-random
        choice) through ``consider_chain`` — the same fork-choice path a
        diverged peer uses, so a joiner's ledger/credit book is rebuilt
        from verified payloads, never trusted."""
        self._schedule(t, self._join, node, sync_from)

    def announce_at(self, t: float, node_id: int) -> None:
        """The node gossips its current tip (block + payload) at ``t``;
        peers behind it will reject the direct append and pull the full
        chain."""
        self._schedule(t, self._announce, node_id)

    def crash_at(self, t: float, node_id: int) -> None:
        """Crash fault: at ``t`` the node loses its entire in-memory
        state (ledger, credit book, caches, workload state).  Its
        durable ``ChainStore`` journal — if it has one — survives as
        the "disk" a later ``restart_at`` recovers from.  Messages
        delivered to a crashed node are dropped (``drops_crash``)."""
        self._schedule(t, self._crash, node_id)

    def restart_at(self, t: float, node_id: int,
                   factory: Callable[[], Node]) -> None:
        """Restart a crashed node at ``t``: ``factory()`` builds a fresh
        shell (same constructor parameters as the crashed node, **no**
        store attached), ``Node.recover`` replays the surviving journal
        into it — truncating any damage instead of raising — and the
        node then pulls a connected peer to resync the lost tail,
        exactly like a joiner."""
        self._schedule(t, self._restart, node_id, factory)

    def corrupt_store_at(self, t: float, node_id: int,
                         mode: str = "bitflip") -> None:
        """Disk fault: damage the node's journal tail at ``t`` —
        ``"bitflip"`` flips one seeded-random bit in the last record,
        ``"torn"`` truncates the journal mid-record (an interrupted
        write).  Works on live and crashed nodes alike; the damage
        surfaces at the next recovery as a graceful truncation."""
        self._schedule(t, self._corrupt_store, node_id, mode)

    # -- event handlers -----------------------------------------------
    def _connected(self, a: int, b: int) -> bool:
        return self._group.get(a) == self._group.get(b)

    def _auto_tick(self, nid: int, every: float, until: float,
                   jitter: float, workload: Optional[str]) -> None:
        self._mine(nid, workload)
        nxt = self.now + every
        if jitter > 0.0:
            nxt += self._rng.uniform(-jitter, jitter)
        # simulated time is monotonic: a jitter draw larger than the
        # period must never schedule into the past (that would invert
        # mine/accept timestamps and corrupt the finality metrics)
        nxt = max(nxt, self.now)
        if nxt <= until:
            self._schedule(nxt, self._auto_tick, nid, every, until, jitter,
                           workload)

    def _mine(self, nid: int, workload: Optional[str]) -> None:
        node = self._nodes.get(nid)
        if node is None:
            return
        try:
            receipt = node.mine_block(workload)
        except ChainError:
            self._counters["mine_failures"] += 1
            return
        rec = receipt.record
        self._counters["blocks_mined"] += 1
        self._mined[rec.block_hash] = _MinedBlock(
            rec.block_hash, rec.height, nid, self.now, rec.workload)
        self._accepts.setdefault(rec.block_hash, {})[nid] = self.now
        adv = self._adversaries.get(nid)
        if adv is not None and adv.withholds():
            self._counters["blocks_withheld"] += 1
            return
        self._gossip(nid, rec.to_block(), receipt.payload)

    def _gossip(self, origin: int, block: Block,
                payload: BlockPayload) -> None:
        adv = self._adversaries.get(origin)
        if adv is not None:
            block, payload = adv.transform(block, payload)
        for dest in sorted(self._nodes):
            if dest == origin:
                continue
            self._send(origin, dest, block, payload, 0)

    def _send(self, origin: int, dest: int, block: Block,
              payload: BlockPayload, attempt: int) -> None:
        """One transmission attempt.  A random drop schedules a
        retransmission with exponential backoff (up to
        ``LinkModel.max_retries``) before the message counts as lost;
        partition drops are never retried (healing re-announces)."""
        link = self.config.link
        if not self._connected(origin, dest):
            self._counters["drops_partition"] += 1
            return
        if self._rng.random() < link.drop_prob:
            self._counters["drops_random"] += 1
            if attempt < link.max_retries:
                self._counters["retries"] += 1
                backoff = link.retry_backoff * (2 ** attempt)
                self._schedule(self.now + backoff, self._send, origin,
                               dest, block, payload, attempt + 1)
            else:
                self._counters["drops_final"] += 1
            return
        lat = self._rng.uniform(link.min_latency, link.max_latency)
        self._counters["deliveries_sent"] += 1
        self._schedule(self.now + lat, self._deliver, origin, dest,
                       block, payload)

    def _deliver(self, origin: int, dest: int, block: Block,
                 payload: BlockPayload) -> None:
        node = self._nodes.get(dest)
        if node is None:
            if dest in self._crashed:
                self._counters["drops_crash"] += 1
            return
        if not self._connected(origin, dest):
            # the link went down while the message was in flight
            self._counters["drops_partition"] += 1
            return
        if node.has_block(block.block_hash):
            self._counters["duplicates"] += 1
            return
        if node.receive(block, payload, origin=origin):
            self._counters["accepts"] += 1
            self._accepts.setdefault(block.block_hash, {}) \
                .setdefault(dest, self.now)
            return
        # invalid payload OR tip mismatch: pull the sender's whole chain
        # after a sync round-trip and run fork choice on it
        self._counters["rejects"] += 1
        self._schedule(self.now + self.config.link.sync_latency,
                       self._sync, origin, dest)

    def _sync(self, origin: int, dest: int) -> None:
        src, node = self._nodes.get(origin), self._nodes.get(dest)
        if src is None or node is None:
            return
        if not self._connected(origin, dest):
            self._counters["drops_partition"] += 1
            return
        self._counters["syncs"] += 1
        blocks: List[Block] = list(src.ledger.blocks)
        if not blocks:
            # nothing to pull (an empty candidate is a caller bug to
            # consider_chain, not a losing fork)
            self._counters["sync_rejects"] += 1
            return
        payloads = src.chain_payloads()
        adv = self._adversaries.get(origin)
        if adv is not None:
            pairs = [adv.transform(b, p) for b, p in zip(blocks, payloads)]
            blocks = [b for b, _ in pairs]
            payloads = [p for _, p in pairs]
        pre = [b.block_hash for b in node.ledger.blocks]
        if not node.consider_chain(blocks, payloads):
            self._counters["sync_rejects"] += 1
            return
        self._counters["reorgs"] += 1
        new = [b.block_hash for b in node.ledger.blocks]
        common = 0
        for a, b in zip(pre, new):
            if a != b:
                break
            common += 1
        depth = len(pre) - common       # blocks the node discarded
        self._fork_depths[depth] = self._fork_depths.get(depth, 0) + 1
        for h in new[common:]:
            self._accepts.setdefault(h, {}).setdefault(dest, self.now)

    def _partition(self, groups: Tuple[Tuple[int, ...], ...]) -> None:
        listed = set()
        for g, members in enumerate(groups, start=1):
            for nid in members:
                self._group[nid] = g
                listed.add(nid)
        for nid in self._group:
            if nid not in listed:
                self._group[nid] = -(nid + 1)     # isolated singleton

    def _heal(self) -> None:
        for nid in self._group:
            self._group[nid] = 0
        for nid in sorted(self._nodes):
            self._announce(nid)

    def _announce(self, nid: int) -> None:
        node = self._nodes.get(nid)
        if node is None or node.ledger.height == 0:
            return
        self._gossip(nid, node.ledger.blocks[-1],
                     node.chain_payloads()[-1])

    def _join(self, node: Node, sync_from: Optional[int]) -> None:
        seen_wl: Dict[int, int] = {}
        for other in self._nodes.values():
            for wl in other.workloads.values():
                seen_wl[id(wl)] = other.node_id
        self._check_node(node, seen_wl)
        nid = node.node_id
        self._nodes[nid] = node
        self._enroll(node)
        self._group[nid] = 0
        self._counters["joins"] += 1
        if sync_from is not None:
            if sync_from not in self._nodes:
                raise ValueError(
                    f"join_at sync_from={sync_from} is not a known node")
            # always schedule the explicitly requested sync; if the link
            # is partitioned, _sync counts it as drops_partition instead
            # of silently skipping the bootstrap
            src = sync_from
        else:
            peers = [p for p in sorted(self._nodes)
                     if p != nid and self._connected(nid, p)]
            if not peers:
                return
            src = self._rng.choice(peers)
        self._schedule(self.now + self.config.link.sync_latency,
                       self._sync, src, nid)

    def _spam(self, nid: int, height: int) -> None:
        node = self._nodes.get(nid)
        if node is None or height >= node.ledger.height:
            return
        self._counters["spam_sent"] += 1
        self._gossip(nid, node.ledger.blocks[height],
                     node.chain_payloads()[height])

    def _release(self, nid: int) -> None:
        adv = self._adversaries.get(nid)
        if adv is not None and hasattr(adv, "withholding"):
            adv.withholding = False
        self._announce(nid)

    # -- crash-fault handlers -----------------------------------------
    def _crash(self, nid: int) -> None:
        node = self._nodes.pop(nid, None)
        if node is None:
            return
        self._counters["crashes"] += 1
        # the in-memory node object is gone; only the journal survives
        self._crashed[nid] = node.store

    def _restart(self, nid: int, factory: Callable[[], Node]) -> None:
        if nid not in self._crashed:
            return
        store = self._crashed.pop(nid)
        shell = factory()
        if shell.node_id != nid:
            raise ValueError(
                f"restart factory built node_id={shell.node_id}, "
                f"expected {nid}")
        seen_wl: Dict[int, int] = {}
        for other in self._nodes.values():
            for wl in other.workloads.values():
                seen_wl[id(wl)] = other.node_id
        self._check_node(shell, seen_wl)
        if store is None:
            node = shell           # diskless node: restarts empty
        else:
            node = Node.recover(store, node=shell)
        self._nodes[nid] = node
        self._enroll(node)
        self._group.setdefault(nid, 0)
        self._counters["recoveries"] += 1
        rec = node.last_recovery
        if rec is not None:
            self._counters["truncated_records"] += rec.truncated_records
        # pull a connected peer to resync the tail lost while down (the
        # same bootstrap path a joiner uses)
        peers = [p for p in sorted(self._nodes)
                 if p != nid and self._connected(nid, p)]
        if peers:
            src = self._rng.choice(peers)
            self._schedule(self.now + self.config.link.sync_latency,
                           self._sync, src, nid)

    def _corrupt_store(self, nid: int, mode: str) -> None:
        store = self._crashed.get(nid)
        if store is None:
            node = self._nodes.get(nid)
            store = node.store if node is not None else None
        if store is None:
            return
        if store.corrupt_tail(self._rng, mode=mode):
            self._counters["corruptions"] += 1

    def _long_range_rewrite(self, nid: int, adv: LongRangeRewriter) -> None:
        """Rewrite the adversary's own chain from ``fork_height`` and
        schedule the private re-mining run.  This is surgery on the
        adversary's internals, not fork choice — it is *making* an
        alternate history, and its credit book is garbage afterwards
        (nothing honest ever reads an adversary's book)."""
        node = self._nodes.get(nid)
        if node is None:
            return
        fork = min(adv.fork_height, node.ledger.height)
        del node.ledger.blocks[fork:]
        node._payloads = {h: p for h, p in node._payloads.items()
                          if h < fork}
        node._hash_index = {b.block_hash for b in node.ledger.blocks}
        keep = [s for s in node._snapshots if s.height <= fork]
        node._snapshots = collections.deque(
            keep, maxlen=node._snapshots.maxlen)
        adv.withholding = True
        t = self.now
        for _ in range(adv.length):
            t += adv.every
            self._schedule(t, self._mine, nid, "classic")
        self._schedule(t + adv.every, self._release, nid)

    # -- run + report -------------------------------------------------
    def run(self, until: Optional[float] = None) -> SimReport:
        """Drain the event queue (optionally only up to ``until``) and
        return the ``SimReport``.  Processing is single-threaded and
        deterministic; ``config.max_events`` bounds runaway feedback
        loops (exceeding it raises rather than silently truncating)."""
        while self._events:
            if self._n_events >= self.config.max_events:
                raise RuntimeError(
                    f"simulation exceeded max_events="
                    f"{self.config.max_events} — runaway event loop?")
            t = self._events[0][0]
            if until is not None and t > until:
                break
            t, _, fn, args = heapq.heappop(self._events)
            self.now = t
            self._n_events += 1
            fn(*args)
        return self.report()

    @property
    def honest_nodes(self) -> List[Node]:
        """Nodes with no adversary attached, ascending id — the
        population convergence and divergence metrics quantify over."""
        return [self._nodes[nid] for nid in sorted(self._nodes)
                if nid not in self._adversaries]

    def converged(self) -> bool:
        """True iff every honest node holds the same verified chain —
        equal tips, valid hash links, bit-identical Merkle roots at
        every height (delegates to ``Network.converged``)."""
        honest = self.honest_nodes
        if not honest:
            return True
        # a read-only check: never graft a fresh Network trust domain
        # onto nodes that live in this Sim's domain
        return Network(honest, shared_verify_cache=False).converged()

    def report(self) -> SimReport:
        """Build the deterministic ``SimReport`` from the current
        simulation state (``run`` calls this at the end; calling it
        mid-run is fine and snapshots the metrics so far)."""
        honest = self.honest_nodes
        canonical = max(honest, key=lambda n: (n.ledger.height,
                                               -n.node_id),
                        default=None)
        canon_hashes = ([b.block_hash for b in canonical.ledger.blocks]
                        if canonical is not None else [])
        canon_set = set(canon_hashes)
        orphans = sum(1 for h in self._mined if h not in canon_set)

        honest_ids = [n.node_id for n in honest]
        ttfs: List[float] = []
        finalized = unfinalized = 0
        for h in canon_hashes:
            info = self._mined.get(h)
            if info is None:
                continue                       # block predates the sim
            times = self._accepts.get(h, {})
            if all(nid in times for nid in honest_ids):
                ttfs.append(max(times[nid] for nid in honest_ids)
                            - info.t_mined)
                finalized += 1
            else:
                unfinalized += 1

        divergence = 0.0
        books = [n.book.balances for n in honest]
        for i in range(len(books)):
            for j in range(i + 1, len(books)):
                keys = set(books[i]) | set(books[j])
                d = sum(abs(books[i].get(k, 0.0) - books[j].get(k, 0.0))
                        for k in keys)
                divergence = max(divergence, d)

        fin_heights = [n.finalized_height for n in honest]
        fin_div = (max(fin_heights) - min(fin_heights)
                   if len(fin_heights) > 1 else 0)
        c = self._counters
        return SimReport(
            seed=self.config.seed,
            n_nodes=len(self._nodes),
            n_events=self._n_events,
            t_end=self.now,
            blocks_mined=c["blocks_mined"],
            blocks_withheld=c["blocks_withheld"],
            mine_failures=c["mine_failures"],
            deliveries_sent=c["deliveries_sent"],
            accepts=c["accepts"],
            duplicates=c["duplicates"],
            rejects=c["rejects"],
            drops_random=c["drops_random"],
            drops_partition=c["drops_partition"],
            spam_sent=c["spam_sent"],
            retries=c["retries"],
            drops_final=c["drops_final"],
            drops_crash=c["drops_crash"],
            syncs=c["syncs"],
            reorgs=c["reorgs"],
            sync_rejects=c["sync_rejects"],
            joins=c["joins"],
            fork_depth_hist=dict(sorted(self._fork_depths.items())),
            crashes=c["crashes"],
            recoveries=c["recoveries"],
            truncated_records=c["truncated_records"],
            corruptions=c["corruptions"],
            finality_rejects=sum(n.finality_rejects for n in honest),
            finalized_heights=fin_heights,
            finalized_divergence=fin_div,
            canonical_height=len(canon_hashes),
            orphans=orphans,
            orphan_rate=orphans / max(len(self._mined), 1),
            finalized=finalized,
            unfinalized=unfinalized,
            ttf_mean=(sum(ttfs) / len(ttfs)) if ttfs else 0.0,
            ttf_max=max(ttfs) if ttfs else 0.0,
            final_heights=[self._nodes[nid].ledger.height
                           for nid in sorted(self._nodes)],
            converged=self.converged(),
            credit_divergence=divergence,
        )


# ---------------------------------------------------------------------------
# canonical scenarios (used by tests, benchmarks and the CLI)
# ---------------------------------------------------------------------------


def partitioned_scenario(n_nodes: int = 4, seed: int = 0, *,
                         blocks_a: int = 2, blocks_b: int = 3,
                         classic_arg_bits: int = 6,
                         n_lanes: int = 1,
                         drop_prob: float = 0.0) -> Sim:
    """The acceptance scenario: the network splits into two halves, each
    half mines its own chain (``blocks_a`` vs ``blocks_b`` classic
    blocks), then the partition heals — the shorter half must reorg onto
    the longer chain and every honest credit book must be rebuilt to
    bit-consistency (``credit_divergence == 0``)."""
    nodes = [Node(node_id=i, classic_arg_bits=classic_arg_bits,
                  n_lanes=n_lanes) for i in range(n_nodes)]
    cfg = SimConfig(seed=seed,
                    link=LinkModel(drop_prob=drop_prob))
    sim = Sim(nodes, cfg)
    half = max(n_nodes // 2, 1)
    sim.partition_at(0.0, [list(range(half)), list(range(half, n_nodes))])
    t = 1.0
    for b in range(blocks_a):
        sim.mine_at(t, b % half)
        t += 1.0
    t = 1.5
    for b in range(blocks_b):
        sim.mine_at(t, half + b % max(n_nodes - half, 1))
        t += 1.0
    sim.heal_at(2.0 + max(blocks_a, blocks_b))
    return sim


def throughput_scenario(n_nodes: int = 16, n_blocks: int = 128, *,
                        seed: int = 0, classic_arg_bits: int = 6,
                        spacing: float = 0.2,
                        shared_verify_cache: bool = True) -> Sim:
    """The scale scenario: ``n_nodes`` honest peers round-robin mine
    ``n_blocks`` classic blocks, each gossiped to every peer — the
    workload whose cost is dominated by §3.3's N-1 re-verifications
    per block.  ``spacing`` (simulated seconds between mine events)
    above the link's max latency keeps the chain extending serially,
    so the wall-clock of ``run()`` measures the verification pipeline,
    not fork churn.  ``shared_verify_cache=False`` is the
    every-node-verifies-everything baseline the batched pipeline is
    benchmarked against."""
    nodes = [Node(node_id=i, classic_arg_bits=classic_arg_bits)
             for i in range(n_nodes)]
    events = 4 * n_blocks * max(n_nodes, 2)    # mines + per-link traffic
    sim = Sim(nodes, SimConfig(seed=seed,
                               max_events=max(100_000, events),
                               shared_verify_cache=shared_verify_cache))
    t = 1.0
    for b in range(n_blocks):
        sim.mine_at(t, b % n_nodes)
        t += spacing
    return sim


def adversarial_scenario(n_honest: int = 3, seed: int = 0, *,
                         classic_arg_bits: int = 6) -> Sim:
    """Withholding + corruption in one run: node ``n_honest`` selfish-
    mines a 3-block private chain and releases it at t=6 (outrunning the
    2 honest blocks — a depth-2 reorg with orphans); node
    ``n_honest + 1`` corrupts everything it sends, so its block is
    rejected by every peer and orphaned.  A final honest block at t=8
    converges everyone onto one chain."""
    wid, cid = n_honest, n_honest + 1
    nodes = [Node(node_id=i, classic_arg_bits=classic_arg_bits)
             for i in range(n_honest + 2)]
    sim = Sim(nodes, SimConfig(seed=seed),
              adversaries={wid: WithholdingMiner(release_at=6.0),
                           cid: PayloadCorrupter()})
    for t in (0.5, 1.0, 1.5):                   # private chain, 3 blocks
        sim.mine_at(t, wid)
    sim.mine_at(2.0, 0)                          # honest chain, 2 blocks
    sim.mine_at(4.0, 1 % n_honest)
    sim.mine_at(3.0, cid)                        # corrupted broadcast
    sim.mine_at(8.0, 0)                          # post-release tiebreak
    return sim


def heterogeneous_scenario(n_honest: int = 3, seed: int = 0, *,
                           suite_seed: int = 7,
                           classic_arg_bits: int = 6) -> Sim:
    """The workload-catalogue scenario: every node carries the full
    application suite (``repro.chain.workloads.default_suite`` — SAT,
    GAN inversion, docking, real-model training — fresh instances per
    node, same ``suite_seed`` so all nodes agree on the formula family,
    inverse problem, data bundle, and init weights), and the mining
    schedule interleaves all families plus the classic fallback across
    nodes.  A ``PayloadCorrupter`` node mines too — its blocks are
    rejected by workload re-verification and orphaned, and its own
    chain falls behind until fork choice reorgs it onto the honest one,
    rolling its *stateful* GAN grid and model-train state back through
    the same snapshot machinery training blocks use.  Converges with
    ``credit_divergence == 0``."""
    from repro.chain.workloads import default_suite
    from repro.chain.workloads.model_train import MICRO_KWARGS

    small = dict(sat={"n_vars": 10, "n_clauses": 40},
                 gan={"grid_bits": 8},
                 docking={"n_r": 16, "n_p": 16},
                 model_train=dict(MICRO_KWARGS))
    cid = n_honest
    nodes = [Node(node_id=i, classic_arg_bits=classic_arg_bits,
                  workloads=default_suite(seed=suite_seed, **small))
             for i in range(n_honest + 1)]
    sim = Sim(nodes, SimConfig(seed=seed),
              adversaries={cid: PayloadCorrupter()})
    schedule = ("sat", "gan", "model_train", "docking", "classic", "sat",
                "gan", "model_train", "docking", "sat")
    t = 0.5
    for b, family in enumerate(schedule):
        sim.mine_at(t, b % n_honest, family)
        t += 1.0                     # spacing > max latency: serial chain
    sim.mine_at(2.25, cid, "sat")    # corrupted broadcast — orphaned
    sim.mine_at(5.25, cid, "gan")    # stateful corrupted block — ditto
    return sim


def chaos_scenario(n_nodes: int = 16, seed: int = 0, *,
                   n_blocks: int = 24,
                   classic_arg_bits: int = 6,
                   confirmation_depth: int = 6,
                   snapshot_interval: int = 4,
                   snapshot_ring: int = 4) -> Sim:
    """The crash-fault acceptance scenario: ``n_nodes`` honest nodes,
    each with a durable journal (``ChainStore``) and finality
    (``confirmation_depth``), round-robin mine ``n_blocks`` classic
    blocks while the sim injects every fault class at once:

    * node 3 crashes mid-run and restarts — ``Node.recover`` replays
      its journal and a peer sync supplies the lost tail;
    * node 5 crashes, its journal tail is **bit-flipped**, and it
      restarts — recovery truncates the damage gracefully (counted in
      ``truncated_records``) and resyncs, never raising;
    * a ``LongRangeRewriter`` (node ``n_nodes``) re-mines a longer
      alternate history from behind the finality horizon and announces
      it — every honest node refuses it at the finality fence.

    Honest nodes must converge with ``finalized_divergence == 0`` and a
    bit-identical ``SimReport`` across repeated seeded runs."""
    def shell(i: int) -> Node:
        # a restart factory must NOT attach a store — Node.recover
        # adopts the crashed node's surviving journal into the shell
        return Node(node_id=i, classic_arg_bits=classic_arg_bits,
                    confirmation_depth=confirmation_depth,
                    snapshot_interval=snapshot_interval,
                    snapshot_ring=snapshot_ring)

    def fresh(i: int) -> Node:
        return Node(node_id=i, classic_arg_bits=classic_arg_bits,
                    confirmation_depth=confirmation_depth,
                    snapshot_interval=snapshot_interval,
                    snapshot_ring=snapshot_ring, store=ChainStore())

    rid = n_nodes
    rewriter = Node(node_id=rid, classic_arg_bits=classic_arg_bits)
    nodes = [fresh(i) for i in range(n_nodes)] + [rewriter]
    t_last = 0.5 + 0.4 * (n_blocks - 1)
    adv = LongRangeRewriter(rewrite_at=t_last + 1.0, fork_height=1,
                            length=n_blocks + 4)
    sim = Sim(nodes, SimConfig(seed=seed, max_events=400_000),
              adversaries={rid: adv})
    t = 0.5
    for b in range(n_blocks):
        sim.mine_at(t, b % n_nodes)
        t += 0.4
    sim.crash_at(2.05, 3 % n_nodes)
    sim.restart_at(4.05, 3 % n_nodes, lambda: shell(3 % n_nodes))
    sim.crash_at(5.05, 5 % n_nodes)
    sim.corrupt_store_at(5.15, 5 % n_nodes, mode="bitflip")
    sim.restart_at(6.55, 5 % n_nodes, lambda: shell(5 % n_nodes))
    # final announce wave: any straggler pulls the canonical chain
    for i in range(n_nodes):
        sim.announce_at(t_last + 4.0, i)
    return sim


def _main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario",
                    choices=("partition", "adversarial", "throughput",
                             "heterogeneous", "chaos", "wire", "mesh",
                             "mesh_chaos"),
                    default="partition")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--nodes", type=int, default=4,
                    help="node count (partition/throughput) / honest "
                         "count (adversarial)")
    ap.add_argument("--blocks", type=int, default=32,
                    help="chain length (throughput scenario)")
    args = ap.parse_args()
    if args.scenario == "wire":
        # N peers over the repro.chain.net loopback wire (signed compact
        # relay), checked bit-for-bit against the in-process Network
        from repro.chain.net import loopback_scenario
        report = loopback_scenario(n_peers=max(args.nodes, 2),
                                   seed=args.seed)
        print(json.dumps(report, indent=2, sort_keys=True))
        assert report["converged"], "wire peers failed to converge"
        assert report["oracle_match"], \
            "wire-relayed chain diverged from the in-process oracle"
        return 0
    if args.scenario == "mesh":
        # N >= 5 peers bootstrapped from a single seed address: HELLO/
        # ADDR discovery fills the mesh, then mining must still match
        # the in-process oracle bit-for-bit (DESIGN.md §14)
        from repro.chain.net import mesh_scenario
        report = mesh_scenario(n_peers=max(args.nodes, 5),
                               seed=args.seed)
        print(json.dumps(report, indent=2, sort_keys=True))
        assert report["full_mesh"], "discovery failed to fill the mesh"
        assert report["converged"], "mesh peers failed to converge"
        assert report["oracle_match"], \
            "mesh-relayed chain diverged from the in-process oracle"
        return 0
    if args.scenario == "mesh_chaos":
        # everything at once over the wire: crashes + journal
        # corruption + restarts through Node.recover + an eclipse
        # attacker + corrupted frames — and still byte-identical to
        # the in-process oracle (DESIGN.md §15)
        from repro.chain.net import mesh_chaos_scenario
        report = mesh_chaos_scenario(n_peers=max(args.nodes, 5),
                                     seed=args.seed)
        print(json.dumps(report, indent=2, sort_keys=True))
        assert report["converged"], "chaos mesh failed to reconverge"
        assert report["oracle_match"], \
            "chaos mesh diverged from the in-process oracle"
        assert report["recoveries"], "no crash was recovered"
        assert report["victim"]["honest_anchors"] >= 1, \
            "eclipse attacker evicted every honest anchor"
        return 0
    if args.scenario == "partition":
        sim = partitioned_scenario(n_nodes=args.nodes, seed=args.seed)
    elif args.scenario == "throughput":
        sim = throughput_scenario(n_nodes=args.nodes,
                                  n_blocks=args.blocks, seed=args.seed)
    elif args.scenario == "heterogeneous":
        sim = heterogeneous_scenario(n_honest=max(args.nodes - 1, 2),
                                     seed=args.seed)
    elif args.scenario == "chaos":
        sim = chaos_scenario(n_nodes=max(args.nodes, 8), seed=args.seed)
    else:
        sim = adversarial_scenario(n_honest=max(args.nodes - 2, 1),
                                   seed=args.seed)
    report = sim.run()
    print(json.dumps(dataclasses.asdict(report), indent=2, sort_keys=True))
    assert report.converged, "honest nodes failed to converge"
    assert report.credit_divergence == 0.0, "credit books diverged"
    assert report.finalized_divergence == 0, "finalized heights diverged"
    if args.scenario == "chaos":
        assert report.recoveries >= 2, "expected two crash recoveries"
        assert report.finality_rejects > 0, \
            "long-range rewrite was not rejected at the finality fence"
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
