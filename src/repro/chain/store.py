"""``repro.chain.store`` — the durable chain journal (crash-fault layer).

A ``ChainStore`` is an append-only journal of everything a ``Node``
commits: one ``COMMIT`` record per block (header + full payload
evidence, canonically encoded) and one ``TRUNCATE`` record per
fork-choice rebuild (the journal itself is never rewritten in place —
a reorg appends ``TRUNCATE(fork_point)`` and then re-appends the
adopted tail, so a crash at any byte leaves a readable prefix).

Layout::

    magic "PNPJRNL1"
    record*            u8 rectype | u32 body_len (LE) | body | sha256(body)[:16]

``rectype`` 1 is ``COMMIT`` (encoded ``Block`` + ``BlockPayload``),
``rectype`` 2 is ``TRUNCATE`` (u64 height).  Every record carries its
own checksum, so a torn tail or a flipped bit is detected at read time
and the journal is **truncated at the first damaged record instead of
crashing** — ``Node.recover`` then replays the surviving prefix through
the ordinary verify path and resyncs the lost tail from peers.

The canonical byte encoding (little-endian scalars, length-prefixed
strings/bytes, dtype-tagged C-order arrays) covers every payload
family the chain mines — ``certificate`` bytes and ``FullResult``
evidence arrays included — and is bit-exact under round trip:
``encode_payload(decode_payload(b)) == b``.  It is the stepping stone
to the ROADMAP's cross-process wire format.

One thing cannot be serialized: a jash's ``fn`` (a live JAX callable).
Decoding rebuilds the ``Jash`` from its name + meta (enough for
``source_id`` and for every workload that re-derives its instance
locally — SAT, GAN inversion, docking, classic via the registry) and
attaches the function from a ``jash_fns`` registry keyed by jash name;
unresolved functions become a sentinel that raises ``ChainError`` if
actually called, which makes the affected block fail re-verification
and be truncated rather than crash the reader.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import FullResult
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import Block
from repro.chain.workload import BlockPayload, ChainError

__all__ = [
    "ChainStore",
    "JournalReadResult",
    "collect_jash_fns",
    "decode_block",
    "decode_payload",
    "encode_block",
    "encode_payload",
    "payload_checksum",
]

MAGIC = b"PNPJRNL1"
REC_COMMIT = 1
REC_TRUNCATE = 2
_CHECKSUM_LEN = 16
_HEAD = struct.Struct("<BI")            # rectype, body_len

_U8 = struct.Struct("<B")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_I64 = struct.Struct("<q")
_F64 = struct.Struct("<d")


class _Corrupt(ChainError):
    """Internal: the journal (or one record body) failed to parse."""


class _UnresolvedFn:
    """Placeholder for a jash function the decoder could not resolve.
    ``source_id`` never calls the function, so decoded payloads still
    cross-check their committed ``jash_id``; any workload that actually
    needs to *execute* the jash fails verification cleanly instead."""

    __slots__ = ("name",)

    def __init__(self, name: str) -> None:
        self.name = name

    def __call__(self, *args, **kwargs):
        raise ChainError(
            f"jash function {self.name!r} is not available in this "
            "process — pass jash_fns={...} to Node.recover / "
            "ChainStore.read_chain to re-verify its blocks")

    def __repr__(self) -> str:
        return f"<unresolved jash fn {self.name!r}>"


# ---------------------------------------------------------------------------
# canonical encoding primitives
# ---------------------------------------------------------------------------


class _W:
    """Append-only canonical writer."""

    def __init__(self) -> None:
        self.buf = bytearray()

    def u8(self, v: int) -> None:
        self.buf += _U8.pack(v)

    def u32(self, v: int) -> None:
        self.buf += _U32.pack(v)

    def u64(self, v: int) -> None:
        self.buf += _U64.pack(v)

    def i64(self, v: int) -> None:
        self.buf += _I64.pack(v)

    def f64(self, v: float) -> None:
        self.buf += _F64.pack(v)

    def bstr(self, b: bytes) -> None:
        self.u32(len(b))
        self.buf += b

    def s(self, v: str) -> None:
        self.bstr(v.encode("utf-8"))

    def opt(self, v, enc: Callable) -> None:
        if v is None:
            self.u8(0)
        else:
            self.u8(1)
            enc(v)

    def arr(self, a: np.ndarray) -> None:
        a = np.ascontiguousarray(a)
        self.s(a.dtype.str)
        self.u8(a.ndim)
        for d in a.shape:
            self.u64(d)
        self.bstr(a.tobytes(order="C"))


class _R:
    """Bounds-checked canonical reader (raises ``_Corrupt`` on overrun
    or malformed content — the caller truncates, never crashes)."""

    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 0

    def _take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise _Corrupt("journal record body overruns its frame")
        out = self.data[self.pos:self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return _U8.unpack(self._take(1))[0]

    def u32(self) -> int:
        return _U32.unpack(self._take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self._take(8))[0]

    def i64(self) -> int:
        return _I64.unpack(self._take(8))[0]

    def f64(self) -> float:
        return _F64.unpack(self._take(8))[0]

    def bstr(self) -> bytes:
        return self._take(self.u32())

    def s(self) -> str:
        try:
            return self.bstr().decode("utf-8")
        except UnicodeDecodeError as e:
            raise _Corrupt(f"invalid utf-8 in journal record: {e}")

    def opt(self, dec: Callable):
        flag = self.u8()
        if flag == 0:
            return None
        if flag != 1:
            raise _Corrupt(f"invalid presence flag {flag}")
        return dec()

    def arr(self) -> np.ndarray:
        dtype = np.dtype(self.s())
        ndim = self.u8()
        shape = tuple(self.u64() for _ in range(ndim))
        raw = self.bstr()
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if n * dtype.itemsize != len(raw):
            raise _Corrupt("array byte length does not match its shape")
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()

    def done(self) -> None:
        if self.pos != len(self.data):
            raise _Corrupt(
                f"{len(self.data) - self.pos} trailing bytes in record")


def _enc_block(w: _W, blk: Block) -> None:
    # every header field except the timestamp — block_hash is
    # timestamp-free by design, so the decoded block re-hashes
    # identically (timestamp decodes as 0.0)
    w.u64(blk.height)
    w.s(blk.prev_hash)
    w.s(blk.jash_id)
    w.s(blk.mode)
    w.s(blk.merkle_root)
    w.opt(blk.winner, w.i64)
    w.opt(blk.best_res, w.s)
    w.u64(blk.n_results)
    w.s(blk.state_digest)


def _dec_block(r: _R) -> Block:
    return Block(height=r.u64(), prev_hash=r.s(), jash_id=r.s(),
                 mode=r.s(), merkle_root=r.s(),
                 winner=r.opt(r.i64), best_res=r.opt(r.s),
                 n_results=r.u64(), state_digest=r.s(), timestamp=0.0)


def _enc_jash(w: _W, jash: Jash) -> None:
    m = jash.meta
    w.s(jash.name)
    w.u32(m.arg_bits)
    w.u32(m.res_bits)
    w.opt(m.max_arg, w.u64)
    w.s(m.data_checksum)
    w.s(m.data_acquisition)
    w.f64(m.importance)
    w.s(m.description)


def _dec_jash(r: _R, jash_fns: Dict[str, Callable]) -> Jash:
    name = r.s()
    meta = JashMeta(arg_bits=r.u32(), res_bits=r.u32(),
                    max_arg=r.opt(r.u64), data_checksum=r.s(),
                    data_acquisition=r.s(), importance=r.f64(),
                    description=r.s())
    fn = jash_fns.get(name) or _UnresolvedFn(name)
    return Jash(name, fn, meta)


def _enc_full(w: _W, full: FullResult) -> None:
    w.arr(full.args)
    w.arr(full.results)
    w.arr(full.hashes)
    w.arr(full.miner_of)
    w.arr(full.leaf_digests)


def _dec_full(r: _R) -> FullResult:
    return FullResult(args=r.arr(), results=r.arr(), hashes=r.arr(),
                      miner_of=r.arr(), leaf_digests=r.arr())


def _enc_payload(w: _W, p: BlockPayload) -> None:
    w.s(p.workload)
    w.s(p.jash_id)
    w.s(p.merkle_root)
    w.u64(p.n_results)
    w.opt(p.winner, w.i64)
    w.opt(p.best_res, w.s)
    w.s(p.state_digest)
    w.i64(p.origin)
    w.f64(p.block_reward)
    w.opt(p.jash, lambda j: _enc_jash(w, j))
    w.opt(p.full, lambda f: _enc_full(w, f))
    w.opt(p.best_arg, w.i64)
    w.opt(p.loss, w.f64)
    w.opt(p.train_height, w.i64)
    w.u64(p.n_miners)
    w.opt(p.certificate, w.bstr)
    w.opt(p.micro_proof, w.arr)


def _dec_payload(r: _R, jash_fns: Dict[str, Callable]) -> BlockPayload:
    return BlockPayload(
        workload=r.s(), jash_id=r.s(), merkle_root=r.s(),
        n_results=r.u64(), winner=r.opt(r.i64), best_res=r.opt(r.s),
        state_digest=r.s(), origin=r.i64(), block_reward=r.f64(),
        jash=r.opt(lambda: _dec_jash(r, jash_fns)),
        full=r.opt(lambda: _dec_full(r)),
        best_arg=r.opt(r.i64), loss=r.opt(r.f64),
        train_height=r.opt(r.i64), n_miners=r.u64(),
        certificate=r.opt(r.bstr), micro_proof=r.opt(r.arr))


def encode_block(blk: Block) -> bytes:
    """Canonical bytes of a ledger ``Block`` header (timestamp-free, so
    the decoded block's content hash is bit-identical)."""
    w = _W()
    _enc_block(w, blk)
    return bytes(w.buf)


def decode_block(data: bytes) -> Block:
    r = _R(data)
    blk = _dec_block(r)
    r.done()
    return blk


def encode_payload(payload: BlockPayload) -> bytes:
    """Canonical bytes of a ``BlockPayload`` — committed fields plus the
    full evidence (``jash`` name/meta, ``FullResult`` arrays,
    ``certificate`` bytes).  Bit-exact under round trip for every
    payload family."""
    w = _W()
    _enc_payload(w, payload)
    return bytes(w.buf)


def decode_payload(data: bytes,
                   jash_fns: Optional[Dict[str, Callable]] = None
                   ) -> BlockPayload:
    r = _R(data)
    p = _dec_payload(r, jash_fns or {})
    r.done()
    return p


def payload_checksum(payload: BlockPayload) -> bytes:
    """The 16-byte content address of a payload: truncated SHA-256 of
    its canonical encoding.  This is the id compact block relay
    announces and fetches bodies by (``repro.chain.net``), the same
    truncation the journal uses per record — two payloads share a
    checksum iff their canonical bytes are identical."""
    return hashlib.sha256(encode_payload(payload)).digest()[:_CHECKSUM_LEN]


def collect_jash_fns(workloads: Dict[str, object],
                     extra: Optional[Dict[str, Callable]] = None
                     ) -> Dict[str, Callable]:
    """The jash-function registry a decoder needs: every registered
    workload's ``journal_jash_fns`` hook (the classic fallback
    publishes its base jash here), overlaid with caller-supplied
    ``extra`` entries (full/optimal researcher jashes).  Shared by
    ``Node.recover`` (journal replay) and ``repro.chain.net.PeerNode``
    (wire decode) — one resolution rule for disk and wire."""
    fns: Dict[str, Callable] = {}
    for wl in workloads.values():
        hook = getattr(wl, "journal_jash_fns", None)
        if hook is not None:
            fns.update(hook())
    if extra:
        fns.update(extra)
    return fns


# ---------------------------------------------------------------------------
# the journal
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JournalReadResult:
    """What ``ChainStore.read_chain`` recovered.  ``blocks``/``payloads``
    are the journal's surviving chain (``COMMIT``/``TRUNCATE`` records
    folded in order); ``truncated_records`` counts damaged-tail events
    (0 or 1 per read — everything at and after the first torn or
    checksum-failing record is discarded); ``clean`` is True iff the
    journal parsed end-to-end undamaged."""
    blocks: List[Block]
    payloads: List[BlockPayload]
    records_read: int
    truncated_records: int
    clean: bool


class ChainStore:
    """Append-only, per-record-checksummed journal of one node's chain.

    ``path=None`` keeps the journal in memory (what the simulator's
    crash/restart faults use as the surviving "disk"); a real path
    appends to that file.  The write API is exactly what ``Node`` emits:
    ``append_commit`` on every committed block, ``append_truncate`` at
    each fork-choice rebuild.  ``read_chain`` never raises on damaged
    input — it returns the longest undamaged prefix and flags the
    truncation."""

    def __init__(self, path: Optional[object] = None) -> None:
        self.path = Path(path) if path is not None else None
        self._buf: Optional[bytearray] = None
        if self.path is None:
            self._buf = bytearray(MAGIC)
        elif not self.path.exists() or self.path.stat().st_size == 0:
            self.path.write_bytes(MAGIC)

    # -- raw byte access ----------------------------------------------
    @property
    def size(self) -> int:
        return (len(self._buf) if self._buf is not None
                else self.path.stat().st_size)

    def is_empty(self) -> bool:
        """True iff the journal holds no records (header only, or a
        header too damaged to hold any)."""
        return self.size <= len(MAGIC)

    def to_bytes(self) -> bytes:
        """The journal's raw bytes (what a disk image of it would hold
        — the torn-write tests snapshot this and damage copies)."""
        return self._read_all()

    @classmethod
    def from_bytes(cls, data: bytes) -> "ChainStore":
        """An in-memory journal initialized from raw bytes (damaged
        input is fine — ``read_chain`` truncates, never raises)."""
        store = cls()
        store._buf[:] = data
        return store

    def _read_all(self) -> bytes:
        return (bytes(self._buf) if self._buf is not None
                else self.path.read_bytes())

    def _write_all(self, data: bytes) -> None:
        if self._buf is not None:
            self._buf[:] = data
        else:
            self.path.write_bytes(data)

    def _append(self, data: bytes) -> None:
        if self._buf is not None:
            self._buf += data
        else:
            with open(self.path, "ab") as f:
                f.write(data)

    # -- write side (what Node emits) ---------------------------------
    @staticmethod
    def _frame(rectype: int, body: bytes) -> bytes:
        return (_HEAD.pack(rectype, len(body)) + body
                + hashlib.sha256(body).digest()[:_CHECKSUM_LEN])

    def append_commit(self, block: Block, payload: BlockPayload) -> None:
        """Journal one committed block (header + payload evidence)."""
        w = _W()
        _enc_block(w, block)
        _enc_payload(w, payload)
        self._append(self._frame(REC_COMMIT, bytes(w.buf)))

    def append_truncate(self, height: int) -> None:
        """Journal a fork-choice truncation: the chain now ends at
        ``height`` and the adopted tail follows as ordinary commits."""
        self._append(self._frame(REC_TRUNCATE, _U64.pack(height)))

    def rewrite(self, blocks: Sequence[Block],
                payloads: Sequence[BlockPayload]) -> None:
        """Compact the journal to exactly this chain (one ``COMMIT`` per
        block, damaged tail and historical ``TRUNCATE`` records dropped)
        — what ``Node.recover`` does after adopting a truncated prefix."""
        out = bytearray(MAGIC)
        for blk, payload in zip(blocks, payloads):
            w = _W()
            _enc_block(w, blk)
            _enc_payload(w, payload)
            out += self._frame(REC_COMMIT, bytes(w.buf))
        self._write_all(bytes(out))

    # -- read side (what Node.recover replays) ------------------------
    def _record_spans(self) -> List[Tuple[int, int]]:
        """Byte spans ``[start, end)`` of every well-framed record (used
        by the fault injectors to aim corruption at the tail)."""
        data = self._read_all()
        spans: List[Tuple[int, int]] = []
        pos = len(MAGIC)
        while pos + _HEAD.size <= len(data):
            _, body_len = _HEAD.unpack_from(data, pos)
            end = pos + _HEAD.size + body_len + _CHECKSUM_LEN
            if end > len(data):
                break
            spans.append((pos, end))
            pos = end
        return spans

    def read_chain(self, jash_fns: Optional[Dict[str, Callable]] = None
                   ) -> JournalReadResult:
        """Fold the journal into its final chain.  Damage — bad magic, a
        torn tail, a checksum mismatch, an undecodable body, or a record
        that contradicts the chain built so far — truncates the read at
        that point (``clean=False``); everything before it survives."""
        fns = jash_fns or {}
        data = self._read_all()
        blocks: List[Block] = []
        payloads: List[BlockPayload] = []
        records = 0
        if data[:len(MAGIC)] != MAGIC:
            return JournalReadResult([], [], 0, 1, clean=False)
        pos = len(MAGIC)
        clean = True
        while pos < len(data):
            if pos + _HEAD.size > len(data):
                clean = False
                break
            rectype, body_len = _HEAD.unpack_from(data, pos)
            body_start = pos + _HEAD.size
            body_end = body_start + body_len
            if body_end + _CHECKSUM_LEN > len(data):
                clean = False                      # torn tail
                break
            body = data[body_start:body_end]
            check = data[body_end:body_end + _CHECKSUM_LEN]
            if hashlib.sha256(body).digest()[:_CHECKSUM_LEN] != check:
                clean = False                      # flipped bits
                break
            try:
                if rectype == REC_COMMIT:
                    r = _R(body)
                    blk = _dec_block(r)
                    payload = _dec_payload(r, fns)
                    r.done()
                    if blk.height != len(blocks):
                        raise _Corrupt(
                            f"commit at height {blk.height} does not "
                            f"extend the journal chain ({len(blocks)})")
                    blocks.append(blk)
                    payloads.append(payload)
                elif rectype == REC_TRUNCATE:
                    (height,) = _U64.unpack(body)
                    if height > len(blocks):
                        raise _Corrupt(
                            f"truncate to {height} beyond journal "
                            f"chain ({len(blocks)})")
                    del blocks[height:]
                    del payloads[height:]
                else:
                    raise _Corrupt(f"unknown record type {rectype}")
            except (_Corrupt, ChainError, ValueError, TypeError,
                    struct.error):
                clean = False
                break
            records += 1
            pos = body_end + _CHECKSUM_LEN
        return JournalReadResult(blocks, payloads, records,
                                 0 if clean else 1, clean=clean)

    # -- fault injection (chaos scenarios + torn-write tests) ---------
    def corrupt_tail(self, rng, mode: str = "bitflip") -> str:
        """Deterministically damage the journal's last record (the
        simulator's ``corrupt_store_at`` fault).  ``mode="bitflip"``
        flips one random bit inside the record; ``"torn"`` truncates the
        journal mid-record, as an interrupted write would.  Returns a
        short description of what was damaged (empty if the journal has
        no records to damage)."""
        spans = self._record_spans()
        data = bytearray(self._read_all())
        if not spans:
            # no well-framed record — tear whatever trailing bytes exist
            if len(data) > len(MAGIC):
                self._write_all(bytes(data[:len(MAGIC)]))
                return "tore unframed tail"
            return ""
        start, end = spans[-1]
        if mode == "torn":
            cut = rng.randrange(start + 1, end)
            self._write_all(bytes(data[:cut]))
            return f"tore last record at byte {cut - start}/{end - start}"
        if mode != "bitflip":
            raise ValueError(f"unknown corruption mode {mode!r}")
        off = rng.randrange(start, end)
        bit = rng.randrange(8)
        data[off] ^= 1 << bit
        self._write_all(bytes(data))
        return f"flipped bit {bit} of byte {off - start}/{end - start}"

    def flip_bit(self, offset: int, bit: int = 0) -> None:
        """Low-level fault helper: flip one bit at an absolute byte
        offset (the torn-write property tests sweep every offset)."""
        data = bytearray(self._read_all())
        data[offset] ^= 1 << bit
        self._write_all(bytes(data))

    def truncate_bytes(self, n: int) -> None:
        """Low-level fault helper: keep only the first ``n`` bytes, as a
        crash mid-write would."""
        self._write_all(self._read_all()[:n])
