"""The ``Workload`` protocol: what a block's useful work *is*.

PNPCoin frames mining as "execute the published jash over its argument
space" (§3.3), but the paper admits four distinct block payloads:

  * **full**     — every arg evaluated, Merkle-committed, reward split
                   across first submissions (+§4 leading-zeros bonus);
  * **optimal**  — distributed argmin, winner takes the block;
  * **training** — the flagship §1 payload: one PoUW train step per
                   block, state digest chained into the ledger;
  * **classic**  — §3.4 back-compatibility: double-SHA-256 blocks when
                   the researcher queue is empty.

Each is a ``Workload``: ``prepare(ctx) -> PreparedWork`` (resolve the
published jash against the block's work target), ``mine(work) ->
BlockPayload`` (produce the commitment + evidence), ``verify(payload)
-> bool`` (bit-exact re-execution — the §3 req. 2 determinism audit any
peer runs on receive), and ``reward(book, payload)`` (credit miners,
deterministically derivable from the payload so every node's book
agrees).  ``chain/node.py`` drives the four against one ledger;
``chain/network.py`` replays them across peers.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.authority import classic_jash
from repro.core.executor import FullResult, run_full, run_optimal
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import merkle_root
from repro.core.rewards import CreditBook, reward_full, reward_optimal
from repro.core.verify import (quorum_verify, quorum_verify_batched,
                               recompute_roots_batched)

# Global miner-id lane: chain-level miner id = node_id * MINER_LANE +
# local device index, so per-node credit books agree on who earned what
# without coordinating id allocation.
MINER_LANE = 1 << 16


def global_miner(node_id: int, local: int) -> int:
    return int(node_id) * MINER_LANE + int(local)


class ChainError(RuntimeError):
    """A block failed verification or could not be committed."""


@dataclasses.dataclass(frozen=True)
class BlockContext:
    """Everything a workload needs to know about the block being mined.

    ``lanes`` is the single-device miner partition (``Node(n_lanes=k)``):
    full/optimal mining vmaps over ``k`` lane-partitioned miner ids in
    one device dispatch, and lane ``l`` of node ``i`` is credited as
    global miner ``global_miner(i, l)``.  Lane partitioning never
    changes the mined bits, so peers verify with ``lanes=1``."""
    height: int
    prev_hash: str
    node_id: int = 0
    jash: Optional[Jash] = None        # RA publication ("queued"/"classic")
    source: str = "queued"
    work: Optional[int] = None         # args-per-block target (§3.1/§5)
    block_reward: float = 50.0
    mesh: Optional[object] = None      # jax Mesh for the miner fleet
    lanes: int = 1                     # single-device miner lanes


@dataclasses.dataclass(frozen=True)
class PreparedWork:
    """A resolved block assignment: the exact jash the miners will run."""
    ctx: BlockContext
    jash: Optional[Jash]


@dataclasses.dataclass
class BlockPayload:
    """Block commitment + in-process evidence.

    The committed fields (``jash_id`` .. ``state_digest``) are what the
    ledger header signs; the evidence fields carry enough for a peer to
    re-verify bit-exactly (in-process today, serialized on the wire
    later).

    ``certificate`` is the verify-cheap evidence channel: a workload
    whose block carries a succinct proof (a SAT witness, an inclusion
    path, …) puts the raw certificate bytes here and commits
    ``certificate_digest(certificate)`` as the block's
    ``state_digest`` — the header then signs the certificate, so a
    tampered certificate under an honest header fails the digest
    cross-check before the workload even looks at it.  Stateful
    workloads instead use ``state_digest`` for their chained state
    commitment; the two uses are exclusive by construction (a workload
    is one or the other).  ``train_height`` doubles as the generic
    *stateful sequence index* — the position of this block in the
    workload's own state chain (train step for training, refinement
    round for GAN inversion).  ``micro_proof`` is the model-training
    evidence channel: a ``(block_microsteps, 64) uint8`` array of
    per-microstep ``(batch_digest, metrics_digest)`` sha256 pairs whose
    leaves re-derive ``merkle_root`` — a verifier checks the binding
    cheaply, then replays the microsteps and must reproduce every row
    bit-exactly (so a divergence is attributed to its exact
    microstep)."""
    workload: str                      # "full"|"optimal"|"training"|...
    jash_id: str
    merkle_root: str
    n_results: int
    winner: Optional[int] = None       # global miner id
    best_res: Optional[str] = None
    state_digest: str = ""
    origin: int = 0                    # node id that mined the block
    block_reward: float = 50.0
    # evidence ----------------------------------------------------------
    jash: Optional[Jash] = None
    full: Optional[FullResult] = None
    best_arg: Optional[int] = None
    loss: Optional[float] = None
    train_height: Optional[int] = None
    n_miners: int = 1
    certificate: Optional[bytes] = None
    micro_proof: Optional[np.ndarray] = None


def certificate_digest(cert: Optional[bytes]) -> str:
    """Consensus binding for verify-cheap certificates: the hex digest a
    certificate-carrying workload commits as the block's
    ``state_digest``.  ``None`` (no certificate) maps to the empty
    string — the same value certificate-free blocks commit — so "this
    block claims no certificate" is itself header-signed: a relay
    cannot strip a certificate without breaking the digest
    cross-check, and cannot graft one on either."""
    if cert is None:
        return ""
    return hashlib.sha256(b"certificate:" + cert).hexdigest()


RewardEntries = Tuple[Tuple[int, float], ...]


def _apply_rewards(book: CreditBook, staged: CreditBook) -> RewardEntries:
    """Merge a staged book into ``book`` and return the applied entries."""
    entries = tuple(sorted(staged.balances.items()))
    for miner, amount in entries:
        book.credit(miner, amount)
    return entries


@runtime_checkable
class Workload(Protocol):
    """The block-payload contract every mining mode implements.

    Stateful workloads (whose ``verify`` advances local state, like
    training) should additionally expose ``snapshot()``/``restore(snap)``
    so fork choice can roll them back when a candidate chain fails
    mid-verification.  Stateless workloads may expose
    ``verify_batch(payloads) -> List[bool]``, a segment-at-a-time
    verifier that must accept/reject bit-identically to per-payload
    ``verify`` calls — ``verify_chain_batched`` uses it to amortize
    device dispatches across a whole chain."""
    name: str

    def prepare(self, ctx: BlockContext) -> PreparedWork: ...

    def mine(self, work: PreparedWork) -> BlockPayload: ...

    def verify(self, payload: BlockPayload) -> bool: ...

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries: ...


def is_stateful(wl: object) -> bool:
    """True for workloads whose ``verify`` advances local state (they
    expose the ``snapshot``/``restore`` rollback pair).  Stateful
    verification can be neither reordered, skipped, nor shared across
    nodes — it doubles as state sync."""
    return hasattr(wl, "snapshot")


def verify_chain_batched(workloads: Dict[str, "Workload"],
                         payloads: Sequence[BlockPayload],
                         precleared: Optional[Sequence[bool]] = None
                         ) -> bool:
    """Re-verify a chain segment, batching stateless workloads.

    Accept/reject is identical to the per-block loop ``for p in
    payloads: wl.verify(p)``: stateless payloads are grouped per
    workload and handed to ``verify_batch`` (one cached jitted
    dispatch per group instead of one per block), then stateful
    payloads replay **in chain order** — their verification advances
    local state, so order is part of the protocol.  A stateless
    failure is detected before any stateful replay runs; the caller
    owns snapshot/rollback of stateful workloads exactly as with the
    per-block loop.

    ``precleared[i]`` marks payload ``i`` as already verified in this
    trust domain (a ``VerifyCache`` hit) — only honored for stateless
    workloads, since stateful verification doubles as state sync.
    Returns True iff every payload verifies (or is legitimately
    precleared)."""
    if precleared is not None and len(precleared) != len(payloads):
        raise ValueError("precleared must align with payloads")
    stateless: Dict[str, List[int]] = {}
    stateful_idx: List[int] = []
    for i, payload in enumerate(payloads):
        wl = workloads.get(payload.workload)
        if wl is None:
            return False
        if is_stateful(wl):
            stateful_idx.append(i)
        elif not (precleared is not None and precleared[i]):
            stateless.setdefault(payload.workload, []).append(i)
    for name, idxs in stateless.items():
        wl = workloads[name]
        group = [payloads[i] for i in idxs]
        if hasattr(wl, "verify_batch"):
            oks = wl.verify_batch(group)
        else:
            oks = [wl.verify(p) for p in group]
        if not all(oks):
            return False
    for i in stateful_idx:                  # chain order == replay order
        if not workloads[payloads[i].workload].verify(payloads[i]):
            return False
    return True


def _batched_stateless_verify(payloads: Sequence[BlockPayload],
                              classify, *, fraction: float
                              ) -> List[bool]:
    """The shared engine behind every stateless ``verify_batch``:
    classify each payload, dedup byte-identical evidence, then batch
    the two O(N)-per-block costs — one independent root recomputation
    (``recompute_roots_batched``, hashlib spot-check + full fallback
    inside) and one stacked quorum dispatch per distinct jash fn
    (``quorum_verify_batched``).  Keeping the dup-propagation order,
    live-list filtering, and root/quorum sequencing in ONE place is
    the point: the PR-4 hardening semantics must not drift apart
    across workload families.

    ``classify(payload)`` returns one of:

    * ``False``/``None`` — rejected by prechecks;
    * ``True`` — accepted without batching (e.g. an O(clauses)
      certificate check already ran);
    * ``(jash, dedup_key)`` — re-verify via batched roots + quorum,
      replaying with ``jash`` (the *locally trusted* jash: either the
      evidence jash after a ``source_id`` cross-check, or one the
      workload rebuilt itself).  ``dedup_key`` collapses byte-identical
      payloads to one representative; it must cover the evidence bytes
      and pin the jash function — by containing the fn object, or
      because ``classify`` already bound the payload to a single local
      fn.  ``None`` disables dedup for this payload.

    Verdicts are bit-identical to the scalar ``verify`` each caller
    defines (the parity suites pin this per family)."""
    oks: List[Optional[bool]] = [None] * len(payloads)
    jashes: Dict[int, Jash] = {}
    rep_of: Dict[object, int] = {}     # dedup key -> first index
    dup_of: Dict[int, int] = {}        # duplicate index -> rep index
    live: List[int] = []
    for i, payload in enumerate(payloads):
        verdict = classify(payload)
        if verdict is None or isinstance(verdict, bool):
            oks[i] = bool(verdict)
            continue
        jash, key = verdict
        if key is not None:
            rep = rep_of.setdefault(key, i)
            if rep != i:
                dup_of[i] = rep
                continue
        jashes[i] = jash
        oks[i] = True
        live.append(i)
    roots = recompute_roots_batched([payloads[i].full for i in live])
    for i, root in zip(live, roots):
        if root != payloads[i].merkle_root:
            oks[i] = False
    live = [i for i in live if oks[i]]
    reports = quorum_verify_batched(
        [(jashes[i], payloads[i].full) for i in live], fraction=fraction)
    for i, report in zip(live, reports):
        if not report.ok:
            oks[i] = False
    for i, rep in dup_of.items():
        oks[i] = oks[rep]
    return oks


# ---------------------------------------------------------------------------
# full mode
# ---------------------------------------------------------------------------


def _sized(jash: Jash, work: Optional[int]) -> Jash:
    """Re-publish ``jash`` with the controller's args-per-block target
    (§3.1 granularity: ``max_arg`` trims below the power-of-two bound)."""
    if work is None or work >= jash.meta.n_args:
        return jash
    meta = dataclasses.replace(jash.meta, max_arg=max(int(work), 1))
    return Jash(jash.name, jash.fn, meta, example_args=jash.example_args)


class JashFullWorkload:
    """§3.3 full execution: every valid arg, Merkle-committed, reward
    split over first submissions with the §4 leading-zeros bonus."""

    name = "full"

    def __init__(self, *, verify_fraction: float = 0.25,
                 bonus_fraction: float = 0.1) -> None:
        self.verify_fraction = verify_fraction
        self.bonus_fraction = bonus_fraction

    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Resolve the published jash against the block's args-per-block
        target (§3.1 granularity via ``meta.max_arg``).  Raises
        ``ChainError`` without a publication — full mode never invents
        its own work."""
        if ctx.jash is None:
            raise ChainError("full workload needs a published jash")
        return PreparedWork(ctx, _sized(ctx.jash, ctx.work))

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Evaluate every valid arg on the fused executor (one vmapped
        dispatch per chunk across ``ctx.lanes`` miner lanes) and commit
        the device Merkle root.  The payload carries the full evidence
        (`args`/`results`/`hashes`/`miner_of`) a peer re-verifies."""
        ctx, jash = work.ctx, work.jash
        full = run_full(jash, mesh=ctx.mesh, lanes=ctx.lanes)
        return BlockPayload(
            workload=self.name, jash_id=jash.source_id(),
            merkle_root=full.commit_root(), n_results=len(full.args),
            origin=ctx.node_id, block_reward=ctx.block_reward,
            jash=jash, full=full)

    def verify(self, payload: BlockPayload) -> bool:
        """The §3 req. 2 determinism audit every peer runs on receive:
        (a) the committed ``jash_id`` must equal the evidence jash's
        ``source_id()``; (b) the committed root is recomputed
        *independently* (hashlib, not the device kernel that produced
        it) from the raw ``(arg, res)`` arrays; (c) a random
        ``verify_fraction`` of the arg space is re-executed bit-exactly.
        Lane partitioning does not change results, so a ``lanes=1``
        verifier audits a multi-lane miner unchanged.  Stateless: safe
        to call any number of times, nothing to roll back."""
        full = payload.full
        if full is None or payload.jash is None:
            return False
        if payload.jash.source_id() != payload.jash_id:
            return False            # committed id must match the evidence
        # independent root recomputation (hashlib, NOT the device kernel
        # that produced the commitment) from the raw (arg, res) arrays —
        # catches tampered roots, tampered leaf digests, and device-kernel
        # bugs alike …
        if merkle_root(list(full.merkle_leaves),
                       backend="hashlib") != payload.merkle_root:
            return False
        # … and deterministic re-execution catches tampered results.
        return quorum_verify(payload.jash, full,
                             fraction=self.verify_fraction).ok

    def verify_batch(self, payloads: Sequence[BlockPayload]) -> List[bool]:
        """``verify`` over a whole segment, bit-identical per payload.

        Identical payloads first collapse to one representative:
        ``verify`` is a pure function of (committed fields, evidence
        bytes), so byte-identical payloads get byte-identical verdicts
        — and deterministic mining *produces* byte-identical payloads
        whenever the same publication is mined repeatedly (the
        full-mode analogue of the classic/optimal replay memo).  Each
        distinct payload then pays the two O(N) costs batched by the
        shared ``_batched_stateless_verify`` engine."""

        def classify(p: BlockPayload):
            if (p.full is None or p.jash is None
                    or p.jash.source_id() != p.jash_id):
                return False
            # the fn object is part of the key: source_id() hashes only
            # name+meta, so a payload pairing honest evidence with a
            # different function must run its own quorum re-execution,
            # never ride the honest payload's verdict
            key = (p.jash.fn, p.jash_id, p.merkle_root,
                   hashlib.sha256(p.full.packed_words().tobytes())
                   .digest())
            return p.jash, key

        return _batched_stateless_verify(payloads, classify,
                                         fraction=self.verify_fraction)

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """Split the block reward evenly over first submissions
        (``full.miner_of`` mapped into the origin node's miner lanes)
        plus the §4 leading-zeros bonus.  Derived only from the payload,
        so every node's book stays bit-identical — the invariant fork
        choice relies on when it rebuilds books from adopted payloads."""
        full = payload.full
        staged = CreditBook()
        submitters = [global_miner(payload.origin, m)
                      for m in full.miner_of]
        # §4: the miner whose submission hash has the most leading zeros
        # takes a bonus slice — lexicographic min over sha256(arg || res),
        # single pass per word with early exit (no O(n log n) sort).
        bonus = None
        if self.bonus_fraction > 0.0 and len(full.hashes):
            idx = np.arange(len(full.hashes))
            for col in range(full.hashes.shape[1]):
                word = full.hashes[idx, col]
                idx = idx[word == word.min()]
                if len(idx) == 1:
                    break
            bonus = global_miner(payload.origin,
                                 int(full.miner_of[idx[0]]))
        reward_full(staged, submitters, payload.block_reward,
                    bonus_winner=bonus, bonus_fraction=self.bonus_fraction)
        return _apply_rewards(book, staged)


# ---------------------------------------------------------------------------
# optimal mode
# ---------------------------------------------------------------------------


class JashOptimalWorkload:
    """§3.3 optimal execution: lowest res wins the whole block reward."""

    name = "optimal"

    # The §3 req. 2 replay is a pure function of (jash.fn, n_args), so a
    # node re-verifying many blocks over the same arg space — every
    # classic block of a chain, every optimal block of one publication —
    # may reuse its *own* earlier replay: the cross-call analogue of
    # verify_batch's in-segment dedup, and per-instance, so it never
    # shares results across nodes (trust stays node-local).
    _REPLAY_MEMO_MAX = 8

    def __init__(self) -> None:
        self._replay_memo: Dict[tuple, object] = {}

    def _replay(self, jash: Jash):
        key = (jash.fn, jash.meta.n_args)
        opt = self._replay_memo.get(key)
        if opt is None:
            opt = run_optimal(jash)
            if len(self._replay_memo) >= self._REPLAY_MEMO_MAX:
                self._replay_memo.pop(next(iter(self._replay_memo)))
            self._replay_memo[key] = opt
        return opt

    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Resolve the published jash against the args-per-block target;
        raises ``ChainError`` without a publication."""
        if ctx.jash is None:
            raise ChainError("optimal workload needs a published jash")
        return PreparedWork(ctx, _sized(ctx.jash, ctx.work))

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Distributed argmin over the arg space — ``ctx.lanes`` miner
        lanes reduced in one vmapped dispatch; the winning lane's global
        miner id takes the block.  ``(best_arg, best_res)`` is
        independent of the lane count (contiguous lanes preserve the
        first-occurrence tie-break), which is what peers re-derive."""
        ctx, jash = work.ctx, work.jash
        # mining always executes for real — a memoized mine would feed
        # near-zero block times into the DifficultyController and leave
        # BlockReceipt.block_time_s meaningless.  The verify-side memo
        # still spares the miner's self-verify the second dispatch.
        opt = run_optimal(jash, mesh=ctx.mesh, lanes=ctx.lanes)
        leaf = (np.uint32(opt.best_arg).tobytes()
                + opt.best_res.astype("<u4").tobytes())
        return BlockPayload(
            workload=self.name, jash_id=jash.source_id(),
            merkle_root=merkle_root([leaf]), n_results=opt.n_evaluated,
            winner=global_miner(ctx.node_id, opt.winner),
            best_res=opt.best_res.tobytes().hex(),
            origin=ctx.node_id, block_reward=ctx.block_reward,
            jash=jash, best_arg=opt.best_arg)

    def verify(self, payload: BlockPayload) -> bool:
        """Deterministic argmin replay (§3 req. 2), run on receive: the
        committed ``jash_id`` must match the evidence, the claimed
        winner's lane must belong to the claimed origin (a payload
        crediting someone else's lane mints nothing), and a single-lane
        re-execution must reproduce ``(best_arg, best_res)`` and the
        one-leaf Merkle root bit-exactly.  Stateless — nothing to roll
        back on failure."""
        if payload.jash is None:
            return False
        if payload.jash.source_id() != payload.jash_id:
            return False            # committed id must match the evidence
        # the winner's device index needs the miner's mesh to re-derive,
        # but its *lane* must belong to the claimed origin — a payload
        # crediting someone else's lane is rejected outright
        if (payload.winner is None
                or payload.winner // MINER_LANE != payload.origin):
            return False
        return self._replay_matches(payload, self._replay(payload.jash))

    @staticmethod
    def _replay_matches(payload: BlockPayload, opt) -> bool:
        """Does a (deterministic) argmin replay reproduce the payload's
        committed ``(best_arg, best_res, merkle_root)`` bit-exactly?"""
        leaf = (np.uint32(opt.best_arg).tobytes()
                + opt.best_res.astype("<u4").tobytes())
        return (opt.best_arg == payload.best_arg
                and opt.best_res.tobytes().hex() == payload.best_res
                and merkle_root([leaf]) == payload.merkle_root)

    def verify_batch(self, payloads: Sequence[BlockPayload]) -> List[bool]:
        """``verify`` over a whole segment, bit-identical per payload.

        The §3 req. 2 replay is a pure function of ``(jash.fn,
        n_args)``, so a segment re-executes each *distinct* arg space
        once and compares every payload against the shared replay — a
        chain of classic blocks over one nonce space costs one device
        dispatch instead of one per block."""
        oks = []
        for p in payloads:
            if (p.jash is None or p.jash.source_id() != p.jash_id
                    or p.winner is None
                    or p.winner // MINER_LANE != p.origin):
                oks.append(False)
                continue
            oks.append(self._replay_matches(p, self._replay(p.jash)))
        return oks

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """Winner takes the whole block reward — derived only from the
        payload (already lane-checked by ``verify``), so rebuilt books
        agree bit-exactly across nodes after fork adoption."""
        staged = CreditBook()
        reward_optimal(staged, payload.winner, payload.block_reward)
        return _apply_rewards(book, staged)


# ---------------------------------------------------------------------------
# classic fallback (§3.4)
# ---------------------------------------------------------------------------


class ClassicSha256Workload(JashOptimalWorkload):
    """§3.4 back-compatibility: when the researcher queue is empty the
    chain mines plain double-SHA-256 blocks — an optimal-mode search over
    a bounded nonce space (``arg_bits`` nonces; lowest double-SHA-256
    wins, i.e. "most leading zeros" exactly as in Bitcoin).

    This is the **default-policy fallback**: ``Node.mine_block(None)``
    selects it whenever the RA queue is empty, so an idle chain keeps
    extending (and keeps its difficulty/work signal alive) instead of
    stalling.  Verification and rewards are inherited unchanged from
    ``JashOptimalWorkload`` — a classic block is re-verified on receive
    by the same deterministic argmin replay, and participates in
    longest-valid-chain fork choice exactly like any jash block (mixed
    classic/full/optimal chains replay workload-by-workload)."""

    name = "classic"

    def __init__(self, *, arg_bits: int = 10) -> None:
        super().__init__()
        self.arg_bits = arg_bits
        self._base: Optional[Jash] = None

    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Publish the (cached) classic double-SHA-256 jash over this
        workload's nonce space.  The base jash is built once per
        workload so its function identity is stable and every classic
        block reuses the executors' compiled caches."""
        if ctx.jash is not None:
            base = ctx.jash
        else:
            if self._base is None:
                self._base = classic_jash()
            base = self._base
        jash = Jash(base.name, base.fn,
                    JashMeta(arg_bits=self.arg_bits, res_bits=256,
                             description=base.meta.description),
                    example_args=base.example_args)
        return PreparedWork(ctx, _sized(jash, ctx.work))

    def journal_jash_fns(self) -> Dict[str, Callable]:
        """Journal-decode support (``Node.recover``): a jash function
        cannot be serialized, so decoding resolves it by name — the
        classic base jash is rebuilt locally and its (stable-identity)
        function registered under its wire name.  Workloads whose
        verification never executes ``payload.jash.fn`` (SAT, GAN
        inversion, docking, training) need no such hook."""
        if self._base is None:
            self._base = classic_jash()
        return {self._base.name: self._base.fn}


# ---------------------------------------------------------------------------
# training (PoUW) mode
# ---------------------------------------------------------------------------


class TrainingWorkload:
    """The §1 flagship payload: each block is one (or ``block_microsteps``)
    deterministic train step(s); the post-step state digest is the
    chained commitment.

    Verification *is* re-execution: a peer receiving a training block
    advances its own (identically seeded) trainer one block and compares
    digests bit-exactly (§3 req. 2) — the audit doubles as state sync, so
    every node holds the model the chain says it should.  A failed
    verify rolls the local trainer back, leaving state untouched.
    """

    name = "training"

    def __init__(self, trainer_factory) -> None:
        self._factory = trainer_factory
        self._trainer = None
        self._self_check = None

    @property
    def trainer(self):
        if self._trainer is None:
            self._trainer = self._factory()
        return self._trainer

    def reset(self) -> None:
        """Back to genesis: the next access rebuilds the trainer from the
        factory (deterministic by seed).  Fork choice calls this so an
        adopted chain is replayed from scratch and discarded local
        training blocks are truly unwound."""
        self._trainer = None
        self._self_check = None

    def is_pristine(self) -> bool:
        """True while the trainer has never been instantiated — a
        snapshot of this state is just "reset me", which lets fork
        choice checkpoint a node that has this workload configured but
        has never mined or verified a training block, without paying a
        model build."""
        return self._trainer is None

    # -- trainer state is functional (immutable pytrees), so a snapshot
    #    is just the current references; the internal credit book is
    #    included so a rolled-back verify mints nothing ----------------
    def snapshot(self):
        t = self.trainer
        return (t.state, t.key, list(t.ledger.blocks), list(t.history),
                dict(t.book.balances), t.book.total_issued)

    def restore(self, snap) -> None:
        t = self.trainer
        t.state, t.key = snap[0], snap[1]
        # copies, not the snapshot's own containers: ringed fork-choice
        # checkpoints outlive a restore, and the live trainer mutates
        # ledger/history/book in place — aliasing would corrupt the
        # checkpoint the moment training resumes after a restore
        t.ledger.blocks = list(snap[2])
        t.history = list(snap[3])
        t.book.balances = dict(snap[4])
        t.book.total_issued = snap[5]

    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """The published jash *is* the validated train step (the trainer
        re-derives the block's batch from (seed, height), so there is no
        per-block work sizing)."""
        return PreparedWork(ctx, self.trainer.step_jash)

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Advance the local trainer one block (``block_microsteps``
        scan-fused train steps) and commit the post-step state digest.
        Mining mutates trainer state — if this block later loses fork
        choice, ``consider_chain`` unwinds it via ``reset()`` + replay
        of the adopted chain."""
        ctx = work.ctx
        t = self.trainer
        rec = t.run_block()
        blk = t.ledger.blocks[rec.height]
        self._self_check = payload = BlockPayload(
            workload=self.name, jash_id=blk.jash_id,
            merkle_root=blk.merkle_root, n_results=blk.n_results,
            winner=(None if blk.winner is None
                    else global_miner(ctx.node_id, blk.winner)),
            best_res=blk.best_res, state_digest=rec.state_digest,
            origin=ctx.node_id, block_reward=ctx.block_reward,
            loss=rec.loss, train_height=rec.height, n_miners=t.n_miners)
        return payload

    def verify(self, payload: BlockPayload) -> bool:
        """Verification *is* re-execution, and it is **stateful**: a
        payload at the trainer's own height advances the local trainer
        one block and compares state digests bit-exactly (§3 req. 2), so
        on receive the audit doubles as state sync.  A mismatch restores
        the pre-verify snapshot — trainer state, history, *and* its
        internal credit book — leaving the node exactly where it was.
        Payloads below the local height re-verify against history plus a
        genuine incremental replay (``audit_block``); the only exception
        is the one-shot fast path for the payload this very process just
        mined (documented inline below)."""
        t = self.trainer
        h = payload.train_height
        if h is None or h > t.ledger.height:
            return False                      # out-of-order: can't replay
        if payload.jash_id != t.step_jash.source_id():
            return False                      # forged jash id
        if (payload.winner is not None
                and payload.winner // MINER_LANE != payload.origin):
            return False                      # ES winner outside origin lane
        if h < t.ledger.height:
            # Already applied locally.  The Node's immediate self-check of
            # a just-mined payload is a one-shot fast path (this process
            # computed the digest microseconds ago; a replay adds no
            # assurance and would double the training hot loop).  Every
            # other call — audit(), peer receive, fork choice — checks
            # against history AND genuinely re-executes on the cached
            # incremental replay trainer (§3 req. 2 demands replay).
            fresh = payload is self._self_check
            self._self_check = None
            return (t.history[h].state_digest == payload.state_digest
                    and t.ledger.blocks[h].merkle_root
                    == payload.merkle_root
                    and (fresh or t.audit_block(h)))
        snap = self.snapshot()
        rec = t.run_block()                   # bit-exact re-execution
        ok = (rec.state_digest == payload.state_digest
              and t.ledger.blocks[h].merkle_root == payload.merkle_root)
        if not ok:
            self.restore(snap)
        return ok

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """Full-mode training splits the reward across the origin's
        ``n_miners`` lanes; ES/optimal training pays the winning lane.
        Derived only from the payload so rebuilt books agree after fork
        adoption."""
        staged = CreditBook()
        if payload.winner is not None:        # optimal/ES trainer mode
            reward_optimal(staged, payload.winner, payload.block_reward)
        else:                                 # full: split across miners
            submitters = [global_miner(payload.origin, m)
                          for m in range(payload.n_miners)]
            reward_full(staged, submitters, payload.block_reward)
        return _apply_rewards(book, staged)
