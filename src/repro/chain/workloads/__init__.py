"""``repro.chain.workloads`` — the application workload suite.

The paper's §1/§4 application list, turned into first-class chain
payloads riding the full Node/Network/Sim stack (gossip, bit-exact
re-verification on receive, batched segment verification, fork-choice
rollback, rewards):

* ``SatWorkload`` — §1 "brute-force theorem proving": exhaustive 3-CNF
  decision, with a committed satisfiability certificate that verifiers
  re-check in O(clauses) instead of re-mining (the first asymmetric
  mine-hard/verify-cheap workload; exhaustive refutations stay
  quorum-sampled).
* ``GanInversionWorkload`` — §1 "finding the appropriate input to a
  Generator": stateful optimal-mode latent search; each accepted block
  zooms the grid around the previous winner, exercising the same
  snapshot/rollback machinery as the training workload.
* ``DockingWorkload`` — the §4 walkthrough, with the data-bundle
  checksum bound into consensus: a peer holding tampered tables
  rejects honest blocks and vice versa.
* ``ModelTrainingWorkload`` — §1 "Deep Net training" at real model
  scale: each block runs sharded ``train/steps.py`` microsteps of a
  ``repro.configs`` transformer on a deterministic
  ``(seed, height, micro)``-keyed token stream, committing the
  canonical params digest; verification replays the microbatches on
  the verifier's own state/mesh (state sync, like training/GAN).

``default_suite`` builds one fresh instance of each family (every node
needs its own objects — sharing an instance across nodes voids
independent re-verification, same rule as ``Network.create``);
``WORKLOAD_FAMILIES`` maps family names to classes for registry-style
construction.  See ``docs/workloads.md`` for the authoring guide and
DESIGN.md §11 for the architecture + trust argument.
"""
from typing import Dict

from repro.chain.workload import Workload
from repro.chain.workloads.docking import DockingBundle, DockingWorkload
from repro.chain.workloads.gan import GanInversionWorkload
from repro.chain.workloads.model_train import ModelTrainingWorkload
from repro.chain.workloads.sat import Cnf3, SatWorkload, random_cnf3

__all__ = [
    "Cnf3",
    "DockingBundle",
    "DockingWorkload",
    "GanInversionWorkload",
    "ModelTrainingWorkload",
    "SatWorkload",
    "WORKLOAD_FAMILIES",
    "default_suite",
    "random_cnf3",
]

# family name -> class; the registry sim scenarios and examples build
# node workload dicts from.  Keys equal each class's ``name`` attribute
# (``Node`` validates that invariant for every registered workload).
WORKLOAD_FAMILIES = {
    SatWorkload.name: SatWorkload,
    GanInversionWorkload.name: GanInversionWorkload,
    DockingWorkload.name: DockingWorkload,
    ModelTrainingWorkload.name: ModelTrainingWorkload,
}


def default_suite(seed: int = 0, **overrides) -> Dict[str, Workload]:
    """Fresh instances of every family, keyed by family name — pass the
    result as ``Node(workloads=...)``.  Call once **per node**: each
    node must own its instances.  ``overrides`` maps a family name to a
    kwargs dict for that family's constructor, e.g.
    ``default_suite(sat={"n_vars": 10})``."""
    suite: Dict[str, Workload] = {}
    for name, cls in WORKLOAD_FAMILIES.items():
        kwargs = dict(overrides.get(name, ()))
        kwargs.setdefault("seed", seed)
        suite[name] = cls(**kwargs)
    return suite
