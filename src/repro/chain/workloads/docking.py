"""``DockingWorkload`` — the paper's §4 cellular-docking walkthrough as
a chain payload, with the data bundle bound into consensus.

§4's use case: screen every (receptor, peptide) pair with a bounded
matcher — pair space ``b = (n_r mod N_r + n_p * N_r)₂`` (eq. 1), 2-bit
output (01 binds / 00 no-bind / 10 did-not-terminate), the relaxation
loop converted to bounded complexity via ``bounded_while`` (§3.2).

What makes it more than the old standalone script is the **data-bundle
checksum in the consensus path**: the per-receptor/peptide feature
tables are a ``DockingBundle`` whose sha256 goes into the jash meta
(``data_checksum``), and the meta is hashed into the committed
``jash_id``.  Every verifier rebuilds the jash from its *own local
bundle* and requires ``source_id()`` to match the committed id before
re-executing — so a peer whose bundle was tampered in p2p transit
rejects honest blocks (it cannot re-derive their id), and a miner who
screened tampered data cannot get its blocks past honest peers (wrong
id, or — if it forges the honest checksum — quorum re-execution
against the honest tables mismatches).  Data integrity is not a side
channel; it is part of block validity.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.chain.workload import (BlockContext, BlockPayload, PreparedWork,
                                  RewardEntries, _apply_rewards,
                                  _batched_stateless_verify, global_miner)
from repro.core.executor import run_full
from repro.core.jash import Jash, JashMeta, bounded_while
from repro.core.ledger import merkle_root
from repro.core.rewards import CreditBook, reward_full
from repro.core.verify import quorum_verify


@dataclasses.dataclass(frozen=True)
class DockingBundle:
    """The §4 data bundle: per-receptor and per-peptide feature words,
    acquired out-of-band (the paper says p2p fileshare) and checksummed
    into the jash meta so consensus binds the exact bytes."""
    receptors: np.ndarray      # (n_r,) uint32
    peptides: np.ndarray       # (n_p,) uint32

    def checksum(self) -> str:
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(self.receptors, np.uint32).tobytes())
        h.update(np.ascontiguousarray(self.peptides, np.uint32).tobytes())
        return h.hexdigest()

    @classmethod
    def generate(cls, n_r: int = 32, n_p: int = 32,
                 seed: int = 0) -> "DockingBundle":
        """Deterministic stand-in for the fileshare download — every
        node generating with the same ``(n_r, n_p, seed)`` holds
        bit-identical tables."""
        rng = np.random.RandomState(seed & 0x7FFFFFFF)
        return cls(
            receptors=rng.randint(0, 1 << 16, (n_r,), dtype=np.uint32),
            peptides=rng.randint(0, 1 << 16, (n_p,), dtype=np.uint32))


class DockingWorkload:
    """§4 docking brute force: one full screening campaign per block.

    Stateless; implements ``verify_batch`` (content-dedup + batched
    roots + batched quorum, like full mode — repeated screenings of one
    bundle produce byte-identical evidence, so a chain of docking
    blocks re-verifies at the cost of one).  Reward: even split over
    first submissions (§3.3 full-mode rule).
    """

    name = "docking"

    def __init__(self, bundle: Optional[DockingBundle] = None, *,
                 n_r: int = 32, n_p: int = 32, seed: int = 0,
                 max_steps: int = 64, bind_threshold: int = 24,
                 verify_fraction: float = 0.25) -> None:
        self.bundle = bundle if bundle is not None \
            else DockingBundle.generate(n_r, n_p, seed)
        self.n_r = len(self.bundle.receptors)
        self.n_p = len(self.bundle.peptides)
        self.max_steps = max_steps
        self.bind_threshold = bind_threshold
        self.verify_fraction = verify_fraction
        self._jash = self._build_jash()

    def _build_jash(self) -> Jash:
        receptors = jnp.asarray(self.bundle.receptors)
        peptides = jnp.asarray(self.bundle.peptides)
        n_r = jnp.uint32(self.n_r)
        max_steps, thresh = self.max_steps, self.bind_threshold

        def matcher(b):
            """Bounded relaxation loop (paper §4 / Fig. 2-3 transform):
            binds if the energy drops under threshold fast enough."""
            r = receptors[b % n_r]
            p = peptides[b // n_r]
            e0 = ((r ^ p) * jnp.uint32(2654435761)) >> jnp.uint32(16)

            def cond(s):
                return s[0] > jnp.uint32(100)

            def body(s):
                e, t = s
                return (e - (e >> jnp.uint32(3)) - jnp.uint32(1), t + 1)

            (e, steps), terminated = bounded_while(
                cond, body, (e0, jnp.uint32(0)), max_steps=max_steps)
            # 01 binds / 00 no-bind / 10 did not terminate (§4)
            return jnp.where(
                ~terminated, jnp.uint32(0b10),
                jnp.where(steps < jnp.uint32(thresh), jnp.uint32(0b01),
                          jnp.uint32(0b00)))

        n_pairs = self.n_r * self.n_p
        arg_bits = max(int(np.ceil(np.log2(max(n_pairs, 2)))), 1)
        return Jash("docking-matcher", matcher,
                    JashMeta(arg_bits=arg_bits, res_bits=2,
                             max_arg=n_pairs,
                             data_checksum=self.bundle.checksum(),
                             data_acquisition="p2p",
                             importance=0.9,
                             description="peptide-receptor docking "
                                         "(paper §4)"),
                    example_args=(jnp.uint32(0),))

    # -- Workload protocol --------------------------------------------
    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Self-publishing: the campaign jash is fixed by the local
        bundle.  ``ctx.work`` sizing is ignored — a partial screening
        is not the §4 claim (and would change ``jash_id``, which the
        bundle checksum pins)."""
        return PreparedWork(ctx, self._jash)

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Screen every pair on the fused executor and Merkle-commit
        the result table."""
        ctx = work.ctx
        full = run_full(self._jash, mesh=ctx.mesh, lanes=ctx.lanes)
        return BlockPayload(
            workload=self.name, jash_id=self._jash.source_id(),
            merkle_root=full.commit_root(), n_results=len(full.args),
            origin=ctx.node_id, block_reward=ctx.block_reward,
            jash=self._jash, full=full)

    def _prechecks(self, payload: BlockPayload) -> bool:
        """Everything before the root + quorum work.  The first check is
        the consensus data binding: the committed id must equal the id
        this node derives from its **own** bundle — a tampered local
        bundle (or a block mined against one) fails here."""
        if payload.jash_id != self._jash.source_id():
            return False
        full = payload.full
        return (full is not None
                and len(full.args) == self._jash.meta.n_args
                and payload.winner is None
                and payload.state_digest == "")

    def verify(self, payload: BlockPayload) -> bool:
        """Full-mode audit against the local bundle: independent hashlib
        root recomputation plus quorum re-execution **with the locally
        built jash** — evidence closures are never executed, so forged
        checksums meet the honest tables and mismatch.  Stateless."""
        if not self._prechecks(payload):
            return False
        if merkle_root(list(payload.full.merkle_leaves),
                       backend="hashlib") != payload.merkle_root:
            return False
        return quorum_verify(self._jash, payload.full,
                             fraction=self.verify_fraction).ok

    def verify_batch(self, payloads: Sequence[BlockPayload]) -> List[bool]:
        """``verify`` over a segment, bit-identical per payload.
        Byte-identical payloads (what deterministic re-screening of one
        bundle produces) collapse to one representative; distinct ones
        share one batched root recomputation and one stacked quorum
        dispatch (all docking blocks replay the *local* jash fn, which
        ``_prechecks`` already pinned via the committed id — the fn
        object still rides in the dedup key to keep the key's contract
        self-contained)."""

        def classify(p: BlockPayload):
            if not self._prechecks(p):
                return False
            key = (self._jash.fn, p.merkle_root,
                   hashlib.sha256(p.full.packed_words().tobytes())
                   .digest())
            return self._jash, key

        return _batched_stateless_verify(payloads, classify,
                                         fraction=self.verify_fraction)

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """§3.3 full-mode rule: even split over first submissions
        (``full.miner_of`` mapped into the origin node's lanes) —
        derived only from the payload, so rebuilt books agree."""
        staged = CreditBook()
        submitters = [global_miner(payload.origin, m)
                      for m in payload.full.miner_of]
        reward_full(staged, submitters, payload.block_reward)
        return _apply_rewards(book, staged)
