"""``GanInversionWorkload`` — §1 "finding the appropriate input to a
Generator": stateful optimal-mode latent search as a chain payload.

The inverse problem: given a fixed generator ``G`` and a target ``x*``,
find ``z`` minimizing ``||G(z) - x*||²``.  Each block is one refinement
round — an optimal-mode argmin over a pseudo-random latent grid
centered on the previous winner — and accepting a block **zooms** the
grid (center moves to the winning latent, scale halves), so the search
state is chained exactly like the training workload's model state:

* the post-zoom ``(round, center, scale)`` digest is the committed
  ``state_digest``; a peer re-verifies by replaying the round on its
  *own* state and comparing digests bit-exactly (§3 req. 2) — the
  audit doubles as state sync;
* verification is therefore **stateful**: it advances local state on
  success, restores the pre-verify snapshot on mismatch, and exposes
  the ``snapshot``/``restore``/``reset`` rollback trio so fork choice
  can unwind discarded rounds (a reorg that drops round *r* rewinds
  the grid to round *r*'s starting state, or the node's future blocks
  would be unverifiable by peers);
* ``BlockPayload.train_height`` carries the round index — the generic
  stateful sequence position, as for training blocks.

The generator weights and target are derived deterministically from
``seed``, so every node constructing ``GanInversionWorkload(seed=s)``
holds the same inverse problem without exchanging data.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.workload import (BlockContext, BlockPayload, MINER_LANE,
                                  PreparedWork, RewardEntries,
                                  _apply_rewards, global_miner)
from repro.core.executor import run_optimal
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import merkle_root
from repro.core.rewards import CreditBook, reward_optimal


class GanInversionWorkload:
    """§1 GAN inversion: one grid-refinement round per block.

    Stateful (``snapshot``/``restore``/``reset``); winner-takes-block
    rewards like optimal mode.  ``verify_batch`` exists for protocol
    completeness but is a chain-order loop — stateful verification can
    be neither reordered nor deduplicated, and ``verify_chain_batched``
    replays stateful workloads per block by design.
    """

    name = "gan"

    def __init__(self, *, seed: int = 0, d_z: int = 8, d_x: int = 32,
                 grid_bits: int = 10, zoom: float = 0.5,
                 init_scale: float = 3.0) -> None:
        if not 0.0 < zoom < 1.0:
            raise ValueError(f"zoom must be in (0, 1), got {zoom}")
        self.seed = seed
        self.d_z, self.d_x = d_z, d_x
        self.grid_bits = grid_bits
        self.zoom = zoom
        self.init_scale = init_scale
        k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
        self._w1 = jax.random.normal(k1, (d_z, 64)) / np.sqrt(d_z)
        self._w2 = jax.random.normal(k2, (64, d_x)) / 8.0
        self._z_true = jax.random.normal(k3, (d_z,))
        self._x_target = self._generate(self._z_true)
        # -- chained search state -------------------------------------
        self._round = 0
        self._center = np.zeros(d_z, np.float32)
        self._scale = float(init_scale)
        # committed fields of every round this instance applied, round
        # order: (jash_id, best_arg, best_res, merkle_root, state_digest)
        self._history: List[Tuple[str, int, str, str, str]] = []
        self._jash_cache: Optional[Tuple[int, Jash]] = None

    # -- the fixed inverse problem ------------------------------------
    def _generate(self, z: jax.Array) -> jax.Array:
        return jnp.tanh(z @ self._w1) @ self._w2

    def _latent(self, arg) -> jax.Array:
        """The grid is pseudo-random, not lattice: arg -> a deterministic
        Gaussian perturbation of the current center (the §1 'input to a
        Generator' candidates)."""
        zs = jax.random.normal(
            jax.random.fold_in(jax.random.key(self.seed), arg), (self.d_z,))
        return jnp.asarray(self._center) + self._scale * zs / 3.0

    def inversion_error(self) -> float:
        """``||G(center) - x*||²`` of the current search state — the
        quantity the chain is collectively minimizing (monotone
        non-increasing is *not* guaranteed per round, but the zoom
        schedule contracts the grid around ever-better winners)."""
        c = jnp.asarray(self._center)
        return float(jnp.sum(jnp.square(self._generate(c) - self._x_target)))

    # -- chained state --------------------------------------------------
    @property
    def round(self) -> int:
        return self._round

    def state_digest(self) -> str:
        """Bit-exact commitment of ``(round, center, scale)`` — what the
        block header signs and peers compare after replaying a round."""
        h = hashlib.sha256()
        h.update(np.int64(self._round).tobytes())
        h.update(np.ascontiguousarray(self._center, np.float32).tobytes())
        h.update(np.float64(self._scale).tobytes())
        return h.hexdigest()

    def snapshot(self):
        return (self._round, self._center.copy(), self._scale,
                list(self._history))

    def restore(self, snap) -> None:
        # copies, not the snapshot's own containers — ringed fork-choice
        # checkpoints outlive a restore (same aliasing rule as the
        # training workload)
        self._round = snap[0]
        self._center = snap[1].copy()
        self._scale = snap[2]
        self._history = list(snap[3])
        self._jash_cache = None

    def reset(self) -> None:
        """Back to round 0 — fork choice calls this when an adopted
        chain must be replayed from genesis."""
        self._round = 0
        self._center = np.zeros(self.d_z, np.float32)
        self._scale = float(self.init_scale)
        self._history = []
        self._jash_cache = None

    def is_pristine(self) -> bool:
        return self._round == 0 and not self._history

    def _round_jash(self) -> Jash:
        """The current round's jash: argmin of the inversion error over
        the latent grid defined by ``(center, scale)``.  The state
        digest is checksummed into the meta, so ``jash_id`` commits the
        exact grid this round searched.  Cached per round — stable fn
        identity keeps the optimal executor's compile cache warm across
        a round's mine + N verifies."""
        if self._jash_cache is not None and \
                self._jash_cache[0] == self._round:
            return self._jash_cache[1]
        center = jnp.asarray(self._center)
        scale = self._scale
        seed, d_z = self.seed, self.d_z
        w1, w2, x_target = self._w1, self._w2, self._x_target

        def fn(arg):
            zs = jax.random.normal(
                jax.random.fold_in(jax.random.key(seed), arg), (d_z,))
            z = center + scale * zs / 3.0
            err = jnp.sum(jnp.square(jnp.tanh(z @ w1) @ w2 - x_target))
            return (err * 1e4).astype(jnp.uint32)   # lower res wins (§3.3)

        jash = Jash(f"gan-inv-{self.seed}-r{self._round}", fn,
                    JashMeta(arg_bits=self.grid_bits, res_bits=32,
                             data_checksum=self.state_digest(),
                             description="GAN-inversion latent grid "
                                         "refinement (paper §1)"),
                    example_args=(jnp.uint32(0),))
        self._jash_cache = (self._round, jash)
        return jash

    def _zoom(self, best_arg: int) -> None:
        """Advance the search state: re-center on the winning latent and
        contract the grid.  Pure function of (state, best_arg), so every
        node replaying the same round lands on a bit-identical state."""
        z = self._latent(jnp.uint32(best_arg))
        self._center = np.asarray(z, np.float32)
        self._scale *= self.zoom
        self._round += 1

    # -- Workload protocol --------------------------------------------
    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Self-publishing: the round's jash is derived from local
        state (``ctx.work`` sizing is ignored — the grid *is* the
        arg space)."""
        return PreparedWork(ctx, self._round_jash())

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Argmin over the grid, then zoom.  Mining mutates search
        state, exactly like a training block advances the trainer — if
        the block later loses fork choice, ``consider_chain`` unwinds
        it via snapshot/``reset`` + replay."""
        ctx = work.ctx
        r = self._round
        jash = work.jash
        opt = run_optimal(jash, mesh=ctx.mesh, lanes=ctx.lanes)
        leaf = (np.uint32(opt.best_arg).tobytes()
                + opt.best_res.astype("<u4").tobytes())
        root = merkle_root([leaf])
        self._zoom(opt.best_arg)
        digest = self.state_digest()
        best_res = opt.best_res.tobytes().hex()
        self._history.append((jash.source_id(), opt.best_arg, best_res,
                              root, digest))
        return BlockPayload(
            workload=self.name, jash_id=jash.source_id(),
            merkle_root=root, n_results=opt.n_evaluated,
            winner=global_miner(ctx.node_id, opt.winner),
            best_res=best_res, state_digest=digest,
            origin=ctx.node_id, block_reward=ctx.block_reward,
            jash=jash, best_arg=opt.best_arg, train_height=r)

    def verify(self, payload: BlockPayload) -> bool:
        """Stateful re-execution audit (§3 req. 2): a payload at the
        local round replays the argmin on this node's own grid state —
        never the evidence closure — compares ``(best_arg, best_res,
        root)`` bit-exactly, then zooms and compares the post-zoom
        state digest.  Success advances local state (state sync);
        any mismatch leaves state untouched.  Rounds already applied
        re-verify against the committed history; future rounds are
        unverifiable (``False``) until the gap is filled."""
        r = payload.train_height
        if r is None or r > self._round:
            return False
        if (payload.winner is None
                or payload.winner // MINER_LANE != payload.origin):
            return False
        if r < self._round:
            hist = self._history[r]
            return (hist[0] == payload.jash_id
                    and hist[1] == payload.best_arg
                    and hist[2] == payload.best_res
                    and hist[3] == payload.merkle_root
                    and hist[4] == payload.state_digest)
        jash = self._round_jash()
        if jash.source_id() != payload.jash_id:
            return False
        opt = run_optimal(jash)        # replay on OUR state, lanes=1
        leaf = (np.uint32(opt.best_arg).tobytes()
                + opt.best_res.astype("<u4").tobytes())
        best_res = opt.best_res.tobytes().hex()
        if (opt.best_arg != payload.best_arg
                or best_res != payload.best_res
                or merkle_root([leaf]) != payload.merkle_root):
            return False
        snap = self.snapshot()
        self._zoom(opt.best_arg)
        if self.state_digest() != payload.state_digest:
            self.restore(snap)
            return False
        self._history.append((payload.jash_id, opt.best_arg, best_res,
                              payload.merkle_root, payload.state_digest))
        return True

    def verify_batch(self, payloads: Sequence[BlockPayload]) -> List[bool]:
        """Chain-order loop: stateful verification cannot be reordered,
        deduplicated, or shared — each round's replay *is* the state
        advance the next round builds on.  Provided so direct callers
        get the same contract surface as the stateless families;
        ``verify_chain_batched`` already replays stateful workloads
        per block in chain order."""
        return [self.verify(p) for p in payloads]

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """Winner takes the block — the lane that found the round's best
        latent (already lane-checked against ``origin`` by
        ``verify``)."""
        staged = CreditBook()
        reward_optimal(staged, payload.winner, payload.block_reward)
        return _apply_rewards(book, staged)
