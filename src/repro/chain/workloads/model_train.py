"""``ModelTrainingWorkload`` — real-model PoUW: the chain trains the
seed's transformer zoo, not the toy trainer.

Each block runs ``block_microsteps`` microbatches of a real sharded
``train/steps.py`` train step — ``make_train_state``/``make_train_step``
under ``sharding/partition.py`` param/batch specs when a device mesh is
attached — and commits:

* ``state_digest`` — sha256 of the canonical post-block params bytes
  (``train.steps.params_digest``: gathered to host, little-endian,
  dtype+shape framed, so a 1-device CPU node and an 8-way FSDP node
  commit identical digests for identical weights);
* ``merkle_root`` — over per-microstep leaves
  ``height | micro | batch_digest | metrics_digest``, with the raw
  digest pairs shipped as ``BlockPayload.micro_proof`` evidence;
* ``train_height`` — the generic stateful sequence index, exactly as
  for ``TrainingWorkload``/``GanInversionWorkload``.

The data stream is ``(chain_seed, height, micro)``-keyed
(``SyntheticTokenPipeline.microbatch``): a pure function of the chain
position, so a verifier re-derives the miner's batches from the meta
alone.  Verification is stateful replay-on-own-state — the §3 req. 2
audit doubling as state sync: re-derive the batches, re-execute the
microsteps on the verifier's *own* state (its own mesh, its own
sharding), and compare root, per-microstep proof rows, loss, and the
post-block params digest bit-exactly.  Before replaying, the verifier
re-derives one seeded-randomly-sampled microbatch from a *fresh*
pipeline instance and cross-checks it against the stream — the
soundness precondition (batches really are replayable) is asserted on
every verify, not just in tests.  Success advances local state; any
mismatch leaves it untouched.  ``snapshot``/``restore``/``reset`` give
fork choice reorg rollback, and payload round-trip through the journal
(``chain/store.py``) is bit-exact, so ``Node.recover`` replays
model-train blocks like any other family.

Compiled train steps are shared process-wide per ``(cfg, hp,
block_microsteps, mesh)`` — every node in an in-process Network or Sim
reuses one XLA executable, which is what keeps a real transformer
affordable in the multi-node suites (re-execution itself is per-node
and independent; only the compilation is shared).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.chain.workload import (BlockContext, BlockPayload, PreparedWork,
                                  RewardEntries, _apply_rewards, global_miner)
from repro.configs import get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import merkle_root
from repro.core.rewards import CreditBook, reward_full
from repro.data.pipeline import SyntheticTokenPipeline
from repro.sharding.partition import batch_specs, param_specs, use_rules
from repro.train.steps import (TrainHparams, TrainState, make_train_state,
                               make_train_step, params_digest, tree_digest)

# digest pair per microstep: sha256(batch) ++ sha256(metrics)
_PROOF_ROW = 64

# The CI micro instance of the family: a real (1-layer) transformer small
# enough for sim scenarios and unit suites.  One canonical kwargs dict —
# sim, tests, and benchmarks all construct THE SAME (cfg, hp, microsteps)
# key, so the whole process pays a single XLA compile for all of them.
MICRO_CONFIG = ModelConfig(
    name="pnpcoin-micro", family="dense", n_layers=1, d_model=32,
    n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=128,
    tie_embeddings=True, remat=False, dtype="float32",
    citation="this work (CI micro model for the model_train suites)")

MICRO_KWARGS = dict(cfg=MICRO_CONFIG, seq_len=16, batch=2,
                    block_microsteps=2, n_miners=2)

# one compiled block step per (cfg, hp, n_micro, mesh) — shared across
# every workload instance in the process (see module docstring)
_STEP_CACHE: Dict[Tuple, Callable] = {}


def _block_step(cfg: ModelConfig, hp: TrainHparams, n_micro: int,
                mesh) -> Callable:
    key = (cfg, hp, n_micro, mesh)
    fn = _STEP_CACHE.get(key)
    if fn is None:
        train_step = make_train_step(cfg, hp)

        def block(state, batches):
            def body(st, b):
                st, metrics = train_step(st, b)
                return st, metrics

            return jax.lax.scan(body, state, batches)

        fn = jax.jit(block)
        _STEP_CACHE[key] = fn
    return fn


class ModelTrainingWorkload:
    """Chain-train a real ``repro.models`` transformer (ROADMAP
    "Real-model PoUW"; Coin.AI / Proof-of-Deep-Learning per PAPERS.md).

    Stateful (``snapshot``/``restore``/``reset``); rewards split across
    the origin's ``n_miners`` lanes like full-mode data-parallel SGD.
    Every consensus parameter — config body, input shape, seed,
    hparams, microsteps per block — is checksummed into the jash meta,
    so ``jash_id`` pins the exact training program."""

    name = "model_train"

    def __init__(self, *, cfg: Any = "pnpcoin-demo", seq_len: int = 32,
                 batch: int = 4, seed: int = 0, block_microsteps: int = 2,
                 hp: TrainHparams = TrainHparams(warmup_steps=4,
                                                 total_steps=512),
                 n_miners: int = 4, mesh=None) -> None:
        if block_microsteps < 1:
            raise ValueError(
                f"block_microsteps must be >= 1, got {block_microsteps} "
                "(a block with no microsteps commits no work)")
        if n_miners < 1:
            raise ValueError(f"n_miners must be >= 1, got {n_miners}")
        self.cfg: ModelConfig = get_config(cfg) if isinstance(cfg, str) \
            else cfg
        self.seq_len, self.batch = seq_len, batch
        self.seed = seed
        self.block_microsteps = block_microsteps
        self.hp = hp
        self.n_miners = n_miners
        self.mesh = mesh
        self.shape = InputShape(f"chain{seq_len}x{batch}", seq_len, batch,
                                "train")
        self.pipeline = SyntheticTokenPipeline(self.cfg, self.shape,
                                               seed=seed)
        # -- chained training state (built lazily on first block) ------
        self._state: Optional[TrainState] = None
        self._round = 0
        # committed fields of every block this instance applied, chain
        # order: (jash_id, merkle_root, state_digest, loss, proof bytes)
        self._history: List[Tuple[str, str, str, float, bytes]] = []
        self._jash: Optional[Jash] = None

    # -- consensus identity -------------------------------------------
    def _consensus_checksum(self) -> str:
        """Checksum over *everything* two nodes must agree on to train
        the same program: data meta, the full config body (not just its
        name), hparams, and the per-block microstep count."""
        h = hashlib.sha256()
        h.update(self.pipeline.checksum().encode())
        h.update(repr(dataclasses.asdict(self.cfg)).encode())
        h.update(repr(self.hp).encode())
        h.update(np.int64(self.block_microsteps).tobytes())
        return h.hexdigest()

    def _step_jash(self) -> Jash:
        """The published train-step jash.  One per workload — unlike the
        GAN grid the step function never changes across blocks; the
        chain position lives in ``train_height``."""
        if self._jash is None:
            self._jash = Jash(
                name=f"model-train-{self.cfg.name}-{self.shape.name}"
                     f"-s{self.seed}",
                fn=make_train_step(self.cfg, self.hp),
                meta=JashMeta(
                    arg_bits=32, res_bits=256,
                    data_checksum=self._consensus_checksum(),
                    data_acquisition="p2p", importance=1.0,
                    description=f"{self.block_microsteps} sharded "
                                f"{self.cfg.name} train microstep(s) "
                                "per block (real-model PoUW)"))
        return self._jash

    # -- chained state -------------------------------------------------
    @property
    def round(self) -> int:
        return self._round

    def _ensure_state(self) -> TrainState:
        if self._state is None:
            state = make_train_state(self.cfg, jax.random.key(self.seed))
            if self.mesh is not None:
                shardings = jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s),
                    param_specs(state, self.mesh, fsdp=self.cfg.fsdp))
                state = jax.device_put(state, shardings)
            self._state = state
        return self._state

    def state_digest(self) -> str:
        """Canonical params digest of the current state — what the next
        mined block chains from, and what converged peers compare."""
        return params_digest(self._ensure_state())

    def snapshot(self):
        # TrainState leaves are immutable jax arrays — aliasing is safe
        # (every update is functional); only the containers are copied
        return (self._round, self._state, list(self._history))

    def restore(self, snap) -> None:
        self._round = snap[0]
        self._state = snap[1]
        self._history = list(snap[2])

    def reset(self) -> None:
        """Back to round 0 — fork choice calls this when an adopted
        chain must be replayed from genesis."""
        self._state = None
        self._round = 0
        self._history = []

    def is_pristine(self) -> bool:
        return self._round == 0 and not self._history

    # -- the block computation ----------------------------------------
    def _stack_batches(self, batches: Sequence[Dict]) -> Any:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        if self.mesh is not None:
            specs = batch_specs(batches[0], self.mesh,
                                self.shape.global_batch)
            stacked = jax.device_put(
                stacked,
                jax.tree.map(
                    lambda s: NamedSharding(
                        self.mesh, P(*((None,) + tuple(s)))), specs))
        return stacked

    @staticmethod
    def _leaf(height: int, micro: int, batch_dig: bytes,
              metrics_dig: bytes) -> bytes:
        return (np.int64(height).tobytes() + np.int64(micro).tobytes()
                + batch_dig + metrics_dig)

    def _run_block(self, height: int):
        """Execute block ``height``'s microsteps on the current state.
        Functional: returns ``(new_state, proof, root, loss)`` without
        mutating the workload, so a failed verify needs no rollback."""
        state = self._ensure_state()
        batches = [self.pipeline.microbatch(height, m)
                   for m in range(self.block_microsteps)]
        step = _block_step(self.cfg, self.hp, self.block_microsteps,
                           self.mesh)
        with use_rules(self.mesh):
            new_state, stacked_metrics = step(state,
                                              self._stack_batches(batches))
        metrics = jax.device_get(stacked_metrics)
        rows = []
        for m in range(self.block_microsteps):
            mh = hashlib.sha256()
            for k in sorted(metrics):
                mh.update(k.encode())
                mh.update(np.asarray(metrics[k][m], np.float64).tobytes())
            bd = bytes.fromhex(tree_digest(batches[m]))
            rows.append(np.frombuffer(bd + mh.digest(), np.uint8))
        proof = np.stack(rows)
        root = merkle_root([
            self._leaf(height, m, proof[m, :32].tobytes(),
                       proof[m, 32:].tobytes())
            for m in range(self.block_microsteps)])
        loss = float(np.asarray(metrics["loss"][-1], np.float64))
        return new_state, batches, proof, root, loss

    def _sampled_micro(self, payload: BlockPayload) -> int:
        """Seeded-random microstep index for the fresh-pipeline spot
        check — derived from the committed block fields, so miner and
        every verifier sample the same index and no miner can steer it."""
        h = hashlib.sha256(
            f"{payload.jash_id}|{payload.train_height}|"
            f"{payload.state_digest}".encode())
        return int.from_bytes(h.digest()[:8], "big") % self.block_microsteps

    # -- Workload protocol --------------------------------------------
    def prepare(self, ctx: BlockContext) -> PreparedWork:
        """Self-publishing, like the GAN family: the block's jash is the
        (fixed) train step; ``ctx.work`` sizing is ignored — the data
        stream is the arg space."""
        return PreparedWork(ctx, self._step_jash())

    def mine(self, work: PreparedWork) -> BlockPayload:
        """Run the block's microsteps and advance local state.  If the
        block later loses fork choice, ``consider_chain`` unwinds the
        trainer via snapshot/``reset`` + replay."""
        ctx = work.ctx
        r = self._round
        jash_id = self._step_jash().source_id()
        new_state, _, proof, root, loss = self._run_block(r)
        self._state = new_state
        self._round = r + 1
        digest = params_digest(new_state)
        self._history.append((jash_id, root, digest, loss, proof.tobytes()))
        return BlockPayload(
            workload=self.name, jash_id=jash_id, merkle_root=root,
            n_results=self.block_microsteps, state_digest=digest,
            origin=ctx.node_id, block_reward=ctx.block_reward,
            loss=loss, train_height=r, n_miners=self.n_miners,
            micro_proof=proof)

    def verify(self, payload: BlockPayload) -> bool:
        """Stateful re-execution audit (§3 req. 2), doubling as state
        sync: replay the block's microsteps on this node's own state
        and mesh, compare root / proof rows / loss / post-block params
        digest bit-exactly.  Success advances local state; any mismatch
        leaves it untouched.  Blocks already applied re-verify against
        the committed history; future heights are unverifiable
        (``False``) until the gap is filled."""
        r = payload.train_height
        if r is None or r > self._round:
            return False
        if payload.jash_id != self._step_jash().source_id():
            return False
        if (payload.n_results != self.block_microsteps
                or payload.n_miners != self.n_miners
                or payload.winner is not None):
            return False
        proof = payload.micro_proof
        if proof is None or tuple(np.shape(proof)) != \
                (self.block_microsteps, _PROOF_ROW):
            return False
        proof = np.ascontiguousarray(np.asarray(proof, np.uint8))
        # evidence must re-derive the committed root before any replay —
        # a relay cannot swap proof rows under an honest header
        if merkle_root([
                self._leaf(r, m, proof[m, :32].tobytes(),
                           proof[m, 32:].tobytes())
                for m in range(self.block_microsteps)]) \
                != payload.merkle_root:
            return False
        if r < self._round:
            hist = self._history[r]
            return (hist[0] == payload.jash_id
                    and hist[1] == payload.merkle_root
                    and hist[2] == payload.state_digest
                    and hist[3] == payload.loss
                    and hist[4] == proof.tobytes())
        # -- r == self._round: replay on OUR state ---------------------
        new_state, batches, ours, root, loss = self._run_block(r)
        # soundness precondition, asserted on every verify: a *fresh*
        # pipeline instance re-derives the seeded-randomly-sampled
        # microbatch bit-identically from the chain position alone
        idx = self._sampled_micro(payload)
        fresh = SyntheticTokenPipeline(self.cfg, self.shape, seed=self.seed)
        if tree_digest(fresh.microbatch(r, idx)) != \
                tree_digest(batches[idx]):
            return False
        if (root != payload.merkle_root
                or ours.tobytes() != proof.tobytes()
                or loss != payload.loss
                or params_digest(new_state) != payload.state_digest):
            return False
        self._state = new_state
        self._round = r + 1
        self._history.append((payload.jash_id, payload.merkle_root,
                              payload.state_digest, payload.loss,
                              proof.tobytes()))
        return True

    def verify_batch(self, payloads: Sequence[BlockPayload]) -> List[bool]:
        """Chain-order loop: stateful verification cannot be reordered,
        deduplicated, or shared — each block's replay *is* the state
        advance the next block builds on (same contract as the GAN
        family; ``verify_chain_batched`` already replays stateful
        workloads per block in chain order)."""
        return [self.verify(p) for p in payloads]

    def reward(self, book: CreditBook, payload: BlockPayload
               ) -> RewardEntries:
        """Full-mode split: the origin's ``n_miners`` lanes share the
        block equally — data-parallel SGD has no single winner
        (``verify`` pins ``n_miners`` to the consensus value)."""
        staged = CreditBook()
        reward_full(staged,
                    [global_miner(payload.origin, m)
                     for m in range(payload.n_miners)],
                    payload.block_reward)
        return _apply_rewards(book, staged)
