from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    list_configs,
    reduced,
    register,
)

__all__ = [
    "INPUT_SHAPES", "InputShape", "ModelConfig",
    "get_config", "list_configs", "reduced", "register",
]
