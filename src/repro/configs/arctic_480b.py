"""arctic-480b [moe] — 128 experts top-2 with a parallel dense residual FFN.

[hf:Snowflake/snowflake-arctic-base]  35 layers, d_model=7168, 56 heads
(GQA kv=8), d_ff=4864 (dense residual and per-expert), vocab=32000.
Dense-MoE hybrid: every layer computes dense_ffn(x) + moe(x).
56 heads are not divisible by the 16-way model axis -> the partitioner
falls back to replicated-head attention with d_model/d_ff sharding.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    citation="hf:Snowflake/snowflake-arctic-base",
))
