"""Config system: model architecture configs + input-shape configs.

Every assigned architecture is one ``configs/<id>.py`` exporting ``CONFIG``.
``get_config(name)`` resolves from the registry; ``reduced(cfg)`` produces
the CPU-smoke-test variant (2 layers, d_model<=512, <=4 experts) required
by the brief.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25      # expert capacity = T·k/E · cf

    # --- hybrid (recurrentgemma) ---
    pattern: Tuple[str, ...] = ()    # repeating layer pattern, e.g. ("rec","rec","attn")
    lru_width: int = 0               # RG-LRU recurrent width (0 -> d_model)
    window: int = 0                  # local/sliding attention window (0 -> full)

    # --- ssm (rwkv6) ---
    wkv_head_dim: int = 64

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_enc_tokens: int = 1500         # post-conv audio frames (frontend stubbed)

    # --- vlm (llama-3.2-vision) ---
    cross_attn_every: int = 0        # a cross-attn layer every N layers
    n_img_tokens: int = 0
    d_vision: int = 0                # stubbed vision-encoder embedding width

    # --- sharding policy (hillclimb levers; see EXPERIMENTS.md §Perf) ---
    fsdp: bool = True                # shard params over the data axis
    fsdp_pod: bool = False           # ... over (pod, data) on multi-pod
    constrain_kv: bool = False       # force kv activations head-sharded/
                                     # replicated (stops GSPMD splitting
                                     # head_dim -> score all-reduce)
    expert_axis: str = "model"       # expert-parallel mesh axis

    # --- numerics / training ---
    dtype: str = "bfloat16"
    opt_dtype: str = "float32"       # AdamW m/v storage (bf16 = memory lever)
    remat: bool = True
    scan_layers: bool = True   # False: unroll (dry-run roofline fidelity —
                               # XLA cost_analysis counts scan bodies once)
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False

    # --- bookkeeping ---
    citation: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so it shards on any mesh
        axis we use (16/32).  Logits beyond ``vocab_size`` are masked in
        the loss (whisper's 51865 is the one odd case)."""
        return 128 * math.ceil(self.vocab_size / 128)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def n_wkv_heads(self) -> int:
        return self.d_model // self.wkv_head_dim

    # --- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_layer = 0
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,w projections + output) + channel-mix
            per_layer += 6 * d * d                  # r,k,v,g,w,out
            per_layer += 2 * d * f                  # channel mix (k: d->f, v: f->d)
        else:
            attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            ffn_dense = 3 * d * f                   # gated (w1, w3, w2)
            n_attn_layers = self.n_layers
            n_ffn_layers = self.n_layers
            if self.family == "hybrid" and self.pattern:
                n_attn = sum(1 for i in range(self.n_layers)
                             if self.pattern[i % len(self.pattern)] == "attn")
                n_rec = self.n_layers - n_attn
                lru = self.lru_width or d
                rec_block = 2 * d * lru + lru * d + 2 * lru * lru // 1  # in/out proj + gates
                per_layer = 0
                total = n_attn * attn + self.n_layers * ffn_dense + n_rec * rec_block
                total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
                total += self.n_layers * 2 * d
                return total
            if self.family == "moe":
                expert_f = 3 * d * f
                moe = self.n_experts * expert_f + d * self.n_experts
                active_moe = self.top_k * expert_f + d * self.n_experts
                dense_extra = ffn_dense if self.moe_dense_residual else 0
                use = active_moe if active_only else moe
                per_layer = attn + use + dense_extra
            else:
                per_layer = attn + ffn_dense
            total = n_attn_layers * 0 + self.n_layers * per_layer
        if self.family == "ssm":
            total = self.n_layers * per_layer
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total += self.n_layers * 2 * d               # norms
        if self.family == "encdec":
            enc_per = (d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d) + 3 * d * f
            cross = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            total += self.n_enc_layers * enc_per + self.n_layers * cross
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            cross = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            total += n_cross * cross + (self.d_vision or d) * d
        return int(total)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import importlib
    for mod in (
        "whisper_medium", "arctic_480b", "stablelm_1_6b", "qwen3_0_6b",
        "qwen3_8b", "olmoe_1b_7b", "stablelm_3b", "llama_3_2_vision_11b",
        "recurrentgemma_2b", "rwkv6_7b", "pnpcoin_demo",
    ):
        importlib.import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants — 2 layers, d_model<=512, <=4 experts
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    d = min(cfg.d_model, 256)
    hd = 32
    n_heads = max(2, min(cfg.n_heads, d // hd))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if n_heads % n_kv:
        n_kv = 1
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=2 if not cfg.pattern else len(cfg.pattern),
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        remat=False,
        dtype="float32",
    )
    if cfg.n_experts:
        changes["n_experts"] = min(cfg.n_experts, 4)
        changes["top_k"] = min(cfg.top_k, 2)
        changes["capacity_factor"] = float(changes["n_experts"])  # drop-free
    if cfg.lru_width:
        changes["lru_width"] = d
    if cfg.window:
        changes["window"] = 64
    if cfg.n_enc_layers:
        changes["n_enc_layers"] = 2
        changes["n_enc_tokens"] = 16
    if cfg.cross_attn_every:
        changes["n_layers"] = 2 * cfg.cross_attn_every  # keep the pattern valid
        changes["n_img_tokens"] = 8
        changes["d_vision"] = 64
    if cfg.family == "ssm":
        changes["wkv_head_dim"] = 32
    return dataclasses.replace(cfg, **changes)
