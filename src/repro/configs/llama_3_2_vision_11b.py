"""llama-3.2-vision-11b [vlm] — cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision]  40 layers, d_model=4096, 32 heads
(GQA kv=8), d_ff=14336, vocab=128256.  A cross-attention layer to the
image tokens every 5th layer (8 total), scanned as 8 groups of
(4 self + 1 cross).  The ViT vision encoder + projector input is stubbed:
``input_specs`` supplies patch embeddings (B, 1601, d_vision=1280); the
model owns only the linear projector into d_model.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    n_img_tokens=1601,
    d_vision=1280,
    rope_theta=500_000.0,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
))
