"""olmoe-1b-7b [moe] — 64 experts top-8.  [arXiv:2409.02060]

16 layers, d_model=2048, 16 heads (GQA kv=16), per-expert d_ff=1024,
vocab=50304.  1B active / 7B total parameters.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    qk_norm=True,
    citation="arXiv:2409.02060",
))
