"""pnpcoin-demo — the paper's own end-to-end payload: a ~2M-parameter
dense LM trained as proof-of-useful-work (one block per ``train_height``
step), per PNPCoin §1 ("finding the next optimum in hyperdimensional
stochastic gradient descent").  Deliberately CI-sized: a CPU runner
mines, verifies, reorgs, and journal-recovers real
``ModelTrainingWorkload`` blocks on it in seconds (the
``examples/chain_train_model.py`` acceptance loop), while keeping every
architectural feature of the bigger configs — GQA attention, qk-norm,
tied embeddings — so the chain exercises the real model stack, not a
stub.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pnpcoin-demo",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=2048,
    qk_norm=True,
    tie_embeddings=True,
    remat=False,
    dtype="float32",
    citation="this work (PNPCoin reproduction demo payload)",
))
