"""pnpcoin-demo — the paper's own end-to-end payload: a ~100M dense LM
trained for a few hundred steps as proof-of-useful-work (one block per
step), per PNPCoin §1 ("finding the next optimum in hyperdimensional
stochastic gradient descent").  Runs on CPU in the examples.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pnpcoin-demo",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=8192,
    qk_norm=True,
    tie_embeddings=True,
    remat=False,
    dtype="float32",
    citation="this work (PNPCoin reproduction demo payload)",
))
