"""qwen3-0.6b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family]

28 layers, d_model=1024, 16 heads (GQA kv=8), head_dim=128 (explicit,
larger than d_model/n_heads per the Qwen3 card), d_ff=3072, vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    citation="hf:Qwen/Qwen3-8B",
))
