"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2.

[arXiv:2402.19427] De et al., "Griffin: Mixing Gated Linear Recurrences
with Local Attention".  26 layers, d_model=2560, 10 heads (MQA kv=1),
d_ff=7680, vocab=256000.  Pattern (rec, rec, attn): two RG-LRU recurrent
blocks per local-attention block; local attention window 2048.
10 heads are not divisible by the 16-way model axis -> replicated-head
fallback (d_model/d_ff sharded instead).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rec", "rec", "attn"),
    lru_width=2560,
    window=2048,
    rope_theta=10_000.0,
    tie_embeddings=True,
    citation="arXiv:2402.19427",
))
