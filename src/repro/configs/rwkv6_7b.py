"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892] Peng et al., "Eagle and Finch: RWKV with Matrix-Valued
States and Dynamic Recurrence".  32 layers, d_model=4096 (64 wkv heads of
size 64), d_ff=14336, vocab=65536.  Decode state is O(1) in sequence
length -> ``long_500k`` runs natively.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                # wkv heads = d_model / wkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    wkv_head_dim=64,
    citation="arXiv:2404.05892",
))
