"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24 layers, d_model=2048, 32 heads (GQA kv=32 == MHA), d_ff=5632,
vocab=100352.  ``long_500k`` runs with the sliding-window attention
variant (window 8192), the brief's allowed path for dense archs.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_theta=10_000.0,
    citation="hf:stabilityai/stablelm-2-1_6b",
))
