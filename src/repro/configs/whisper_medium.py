"""whisper-medium [audio] — enc-dec, conv/mel frontend stubbed.

[arXiv:2212.04356] Radford et al., "Robust Speech Recognition via
Large-Scale Weak Supervision".  24 enc + 24 dec layers, d_model=1024,
16 heads (kv=16), d_ff=4096, vocab=51865 (padded to 51968 for sharding).
The mel-spectrogram + conv feature extractor is a stub: ``input_specs``
supplies post-conv frame embeddings (B, 1500, d_model) directly.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,              # decoder layers
    n_enc_layers=24,
    n_enc_tokens=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    rope_theta=0.0,           # whisper uses learned/sinusoidal pos, not rope
    citation="arXiv:2212.04356",
    notes="long_500k skipped: enc-dec full-attention decoder with a "
          "by-design 448-token context; see DESIGN.md §4.",
))
