"""``repro.core`` — the stable kernel layer under ``repro.chain``.

Everything re-exported here is declared in ``__all__``; anything else
(``repro.core.executor.FullResult`` internals, ``repro.core.es``, …) is
reachable by direct module import but is not part of the stable surface.
"""
from repro.core.authority import (
    ReviewReport, RuntimeAuthority, classic_jash,
)
from repro.core.difficulty import DifficultyController, work_for_runtime
from repro.core.executor import (
    FullResult, OptimalResult, run_full, run_optimal,
)
from repro.core.jash import (
    Jash, JashMeta, JashValidationError, bounded_while, collatz_jash,
)
from repro.core.ledger import Block, Ledger, merkle_root
from repro.core.pow_train import PoUWTrainer
from repro.core.rewards import CreditBook, reward_full, reward_optimal
from repro.core.verify import (VerifyReport, quorum_verify,
                               quorum_verify_batched, verify_inclusion)

__all__ = [
    "Block",
    "CreditBook",
    "DifficultyController",
    "FullResult",
    "Jash",
    "JashMeta",
    "JashValidationError",
    "Ledger",
    "OptimalResult",
    "PoUWTrainer",
    "ReviewReport",
    "RuntimeAuthority",
    "VerifyReport",
    "bounded_while",
    "classic_jash",
    "collatz_jash",
    "merkle_root",
    "quorum_verify",
    "quorum_verify_batched",
    "reward_full",
    "reward_optimal",
    "run_full",
    "run_optimal",
    "verify_inclusion",
    "work_for_runtime",
]
