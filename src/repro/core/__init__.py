from repro.core.authority import RuntimeAuthority, classic_jash  # noqa: F401
from repro.core.executor import run_full, run_optimal  # noqa: F401
from repro.core.jash import (  # noqa: F401
    Jash, JashMeta, JashValidationError, bounded_while, collatz_jash,
)
from repro.core.ledger import Block, Ledger, merkle_root  # noqa: F401
from repro.core.pow_train import PoUWTrainer  # noqa: F401
from repro.core.rewards import CreditBook, reward_full, reward_optimal  # noqa: F401
from repro.core.verify import quorum_verify, verify_inclusion  # noqa: F401
