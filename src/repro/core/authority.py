"""The Runtime Authority (PNPCoin §3.3, Fig. 1).

"The role of the Runtime Authority is to review code submitted by
researchers, publish jash functions to be used at a given block, and
aggregate results. It does not intervene in the ledger or blockchain."

Review pipeline (all-but-veto automated, exactly the paper's list):
  1. validate: bounded-complexity jaxpr walk (``Jash.validate``)
  2. compile check: ``jit(fn).lower().compile()``
  3. runtime estimation: "performing runs on random inputs" -> mean/std
     wall time + ``cost_analysis`` FLOPs
  4. prioritization: upper-bound complexity, data size, runtime estimate,
     importance (0..1), and a veto flag
  5. publication: one jash per block; when the queue is empty, a
     "Classic" SHA-256 jash is published (§3.4 back-compatibility).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import cost_analysis_dict
from repro.core.jash import Jash, JashMeta, JashValidationError
from repro.kernels.ops import sha256_words


@dataclasses.dataclass
class ReviewReport:
    jash_id: str
    compiled: bool
    flops_estimate: float
    runtime_mean_s: float
    runtime_std_s: float
    loop_bound_ok: bool
    priority: float
    vetoed: bool = False
    reason: str = ""


@dataclasses.dataclass(order=True)
class _QueueEntry:
    neg_priority: float
    seq: int
    jash: Jash = dataclasses.field(compare=False)
    report: ReviewReport = dataclasses.field(compare=False)


class RuntimeAuthority:
    def __init__(self, *, loop_bound: int = 1 << 20,
                 runtime_probe_n: int = 4) -> None:
        self.loop_bound = loop_bound
        self.runtime_probe_n = runtime_probe_n
        self._queue: List[_QueueEntry] = []
        self._seq = 0
        self.reviews: Dict[str, ReviewReport] = {}

    # ------------------------------------------------------------------
    def submit(self, jash: Jash, veto: bool = False) -> ReviewReport:
        """Full §3.3 review.  Raises JashValidationError on hard failures;
        a veto (human criterion) parks the jash without publication."""
        jid = jash.source_id()
        jash.validate(loop_bound=self.loop_bound)

        compiled = jash.lower_compile()
        cost = cost_analysis_dict(compiled.cost_analysis())
        flops = float(cost.get("flops", 0.0))

        # runtime estimation on random inputs (paper: "estimating mean
        # runtime and deviation by performing runs on random inputs")
        fn = jax.jit(jash.fn)
        times = []
        rng = np.random.RandomState(0)
        for _ in range(self.runtime_probe_n):
            arg = jnp.uint32(rng.randint(0, max(jash.meta.n_args, 2)))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            times.append(time.perf_counter() - t0)
        mean_t, std_t = float(np.mean(times[1:])), float(np.std(times[1:]))

        # prioritization: cheap + important first (§3.3 criteria)
        data_penalty = 1.0 + len(jash.meta.data_checksum) * 0.0
        priority = jash.meta.importance / (
            (1e-9 + flops) ** 0.25 * (1e-6 + mean_t) ** 0.25 * data_penalty)

        report = ReviewReport(
            jash_id=jid, compiled=True, flops_estimate=flops,
            runtime_mean_s=mean_t, runtime_std_s=std_t,
            loop_bound_ok=True, priority=priority, vetoed=veto,
            reason="veto" if veto else "")
        self.reviews[jid] = report
        if not veto:
            heapq.heappush(self._queue,
                           _QueueEntry(-priority, self._seq, jash, report))
            self._seq += 1
        return report

    # ------------------------------------------------------------------
    def publish_next(self) -> Tuple[Jash, str]:
        """Pop the highest-priority jash for the next block; if the queue
        is empty, publish a Classic SHA-256 jash (§3.4)."""
        if self._queue:
            entry = heapq.heappop(self._queue)
            return entry.jash, "queued"
        return classic_jash(), "classic"

    def requeue(self, jash: Jash) -> None:
        """Return a published-but-unmined jash to the queue at its
        reviewed priority (the chain layer uses this when a mined block
        fails self-verification, so a researcher's submission is not
        silently lost)."""
        report = self.reviews.get(jash.source_id())
        priority = report.priority if report is not None else 0.0
        heapq.heappush(self._queue,
                       _QueueEntry(-priority, self._seq, jash, report))
        self._seq += 1

    @property
    def queue_depth(self) -> int:
        return len(self._queue)


def _classic_fn(arg: "jax.Array") -> "jax.Array":
    # module-level (stable identity) so every classic block — across
    # blocks and across in-process nodes — hits the executors' compiled
    # caches instead of re-jitting a fresh closure per publication
    msg = jnp.stack([arg.astype(jnp.uint32),
                     jnp.uint32(0x504e5043)])[None]        # "PNPC" salt
    h1 = sha256_words(msg)
    return sha256_words(h1)[0]                              # double-SHA256


def classic_jash(arg_bits: int = 20) -> Jash:
    """§3.4: 'jash functions containing the SHA-256 hashes with fixed
    input, and empty meta files' — plain double-SHA-256 proof of work."""
    meta = JashMeta(arg_bits=arg_bits, res_bits=256, data_checksum="",
                    data_acquisition="none", importance=0.0,
                    description="Classic SHA-256 block (back-compat §3.4)")
    return Jash("classic-sha256", _classic_fn, meta,
                example_args=(jnp.uint32(0),))
