"""Small shims over jax API drift so the repo runs on the installed jax.

``cost_analysis()`` returned a per-computation *list* of dicts in older
jax releases and a plain dict in newer ones; every caller here wants the
aggregate dict.
"""
from __future__ import annotations

from typing import Any, Dict


def cost_analysis_dict(cost: Any) -> Dict[str, float]:
    """Normalize ``Lowered/Compiled.cost_analysis()`` output to one dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
