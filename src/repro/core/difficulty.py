"""Block-work retargeting (PNPCoin §3.1 granularity + §5 limitation).

Bitcoin retargets the leading-zero difficulty every 2016 blocks so block
time tracks 10 minutes.  PNPCoin's analogue is the *amount of useful
work per block*: the RA controls ``meta.max_arg`` ("to achieve greater
granularity than powers of two", §3.1), so the controller adjusts the
published arg-space size to hit a target block time — directly
addressing the paper's own §5 limitation that "jash functions are
computed on a one-per-block basis, putting an inconvenient limitation on
the runtime of each node".

A standard EMA controller: work_{t+1} = work_t * clip(target/ema, 1/4, 4)
(Bitcoin clips retargets to 4x as well).  Before any observation the
controller proposes the current work unchanged, and the EMA seeds from
the *mean of the first ``seed_samples`` observations* rather than
locking the first (often cold-compile-skewed) block time in with full
weight.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class DifficultyController:
    target_block_s: float
    min_work: int = 1
    max_work: int = 1 << 32
    ema_alpha: float = 0.3
    max_retarget: float = 4.0
    seed_samples: int = 4

    _ema: Optional[float] = None
    _warmup: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.seed_samples < 1:
            raise ValueError(
                f"seed_samples must be >= 1, got {self.seed_samples} "
                "(the EMA needs at least one sample to seed from)")

    def observe(self, block_time_s: float) -> None:
        if len(self._warmup) < self.seed_samples:
            # seed phase: the EMA is the running mean of the first k
            # samples, so one outlier block can't dominate the seed
            self._warmup.append(block_time_s)
            self._ema = sum(self._warmup) / len(self._warmup)
        else:
            self._ema = (1 - self.ema_alpha) * self._ema + \
                self.ema_alpha * block_time_s

    @property
    def ema_block_s(self) -> Optional[float]:
        return self._ema

    def propose_work(self, current_work: int) -> int:
        """args-per-block for the next publication.  With no observation
        yet there is nothing to retarget against: the current work is
        returned unchanged."""
        if self._ema is None or self._ema <= 0:
            return current_work
        ratio = self.target_block_s / self._ema
        ratio = min(max(ratio, 1.0 / self.max_retarget), self.max_retarget)
        work = int(current_work * ratio)
        return min(max(work, self.min_work), self.max_work)

    # back-compat alias (pre-chain-API name)
    next_work = propose_work


def work_for_runtime(runtime_mean_s: float, target_block_s: float,
                     n_miners: int, *, safety: float = 0.9) -> int:
    """Initial work sizing from the RA's §3.3 runtime estimate: how many
    args fit the target block time across the miner fleet."""
    if runtime_mean_s <= 0:
        return 1
    return max(1, int(n_miners * target_block_s * safety / runtime_mean_s))
