"""Block-work retargeting (PNPCoin §3.1 granularity + §5 limitation).

Bitcoin retargets the leading-zero difficulty every 2016 blocks so block
time tracks 10 minutes.  PNPCoin's analogue is the *amount of useful
work per block*: the RA controls ``meta.max_arg`` ("to achieve greater
granularity than powers of two", §3.1), so the controller adjusts the
published arg-space size to hit a target block time — directly
addressing the paper's own §5 limitation that "jash functions are
computed on a one-per-block basis, putting an inconvenient limitation on
the runtime of each node".

A standard EMA controller: work_{t+1} = work_t * clip(target/ema, 1/4, 4)
(Bitcoin clips retargets to 4x as well).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class DifficultyController:
    target_block_s: float
    min_work: int = 1
    max_work: int = 1 << 32
    ema_alpha: float = 0.3
    max_retarget: float = 4.0

    _ema: Optional[float] = None

    def observe(self, block_time_s: float) -> None:
        if self._ema is None:
            self._ema = block_time_s
        else:
            self._ema = (1 - self.ema_alpha) * self._ema + \
                self.ema_alpha * block_time_s

    @property
    def ema_block_s(self) -> Optional[float]:
        return self._ema

    def next_work(self, current_work: int) -> int:
        """args-per-block for the next publication."""
        if self._ema is None or self._ema <= 0:
            return current_work
        ratio = self.target_block_s / self._ema
        ratio = min(max(ratio, 1.0 / self.max_retarget), self.max_retarget)
        work = int(current_work * ratio)
        return min(max(work, self.min_work), self.max_work)


def work_for_runtime(runtime_mean_s: float, target_block_s: float,
                     n_miners: int, *, safety: float = 0.9) -> int:
    """Initial work sizing from the RA's §3.3 runtime estimate: how many
    args fit the target block time across the miner fleet."""
    if runtime_mean_s <= 0:
        return 1
    return max(1, int(n_miners * target_block_s * safety / runtime_mean_s))
