"""Optimal-mode training: population search over parameter perturbations.

PNPCoin §1 names "finding the next optimum in hyperdimensional stochastic
gradient descent" as a target workload and §3.3's **optimal** mode accepts
the lowest result.  The natural fit is evolution-strategies-style
candidate search: every miner perturbs the params with its own seed,
evaluates the loss on the block's batch, and the chain accepts the lowest
loss — the winning perturbation IS the block's "res".

Memory discipline: candidates are never materialized as a population;
noise is regenerated from ``fold_in(key, candidate_id)`` (deterministic —
a verifier can re-derive any candidate bit-exactly, which is what makes
this auditable like any other jash).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def perturb(params: Any, key, sigma: float, antithetic_sign: float = 1.0):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    new = [
        (l + antithetic_sign * sigma *
         jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype))
        if jnp.issubdtype(l.dtype, jnp.floating) else l
        for l, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, new)


def es_block(eval_fn: Callable[[Any, Dict], jax.Array], params: Any,
             batch: Dict, key, *, pop_size: int, sigma: float
             ) -> Tuple[jax.Array, jax.Array]:
    """Evaluate ``pop_size`` candidates (antithetic pairs); returns
    (losses (pop,), best_idx).  Candidate i's params are reproducible via
    ``candidate_params(params, key, i, sigma)``."""

    def eval_candidate(i):
        cand = candidate_params(params, key, i, sigma)
        return eval_fn(cand, batch)

    losses = jax.lax.map(eval_candidate, jnp.arange(pop_size))
    return losses, jnp.argmin(losses)


def candidate_params(params: Any, key, i, sigma: float):
    """Candidate 0 is the UNPERTURBED params (a miner may re-submit the
    incumbent optimum, so the chain never regresses on the block batch);
    candidates 2j+1 / 2j+2 are the antithetic pair +/- sigma*noise_j."""
    sub = jax.random.fold_in(key, jnp.maximum(i - 1, 0) // 2)
    sign = jnp.where(i % 2 == 1, 1.0, -1.0)
    eff_sigma = jnp.where(i == 0, 0.0, sigma)
    return perturb(params, sub, eff_sigma * sign, 1.0)


def es_update(params: Any, key, losses: jax.Array, *, sigma: float,
              lr: float):
    """Beyond-hillclimb option: the standard ES gradient estimate from all
    submitted results (the chain already paid for them — full-mode reuse)."""
    pop = losses.shape[0]
    adv = (losses - losses.mean()) / (losses.std() + 1e-8)

    # theta <- theta - lr * (1/pop) sum_i adv_i * eps_i   (eps = unit noise,
    # regenerated; adv normalized so the step scale is ~lr/sqrt(pop))
    def body(i, acc):
        cand = candidate_params(params, key, i, 1.0)   # unit noise
        return jax.tree.map(
            lambda a, c, p: a - (lr / pop) * adv[i] *
            (c.astype(jnp.float32) - p.astype(jnp.float32)),
            acc, cand, params)

    acc = jax.lax.fori_loop(0, pop, body,
                            jax.tree.map(lambda p: p.astype(jnp.float32),
                                         params))
    return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, params)
