"""Execution engines: how miners (mesh devices) evaluate a published jash
over its argument space (PNPCoin §3.3).

**full** mode — "Full execution returns the output of every valid input":
the arg space [0, n_args) is sharded over the mesh's miner axis with
``shard_map``; each miner vmaps the jash over its slice and emits
(results, sha256(arg || res)) — the paper's "concatenated plain results
with hashed results".  The hash uses the batched SHA-256 kernel.

**optimal** mode — "accepts the lowest res, the result with most leading
zeros": each miner reduces its slice to a (res, arg) minimum and a global
all-reduce-min picks the block winner.

On the CPU container the same code runs on a 1-device mesh; on the
production mesh the miner axis is ("data",) (256 miners/pod) or
("pod", "data") (512).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.jash import Jash
from repro.kernels.ops import sha256_words


@dataclasses.dataclass(frozen=True)
class FullResult:
    args: np.ndarray           # (n,) uint32
    results: np.ndarray        # (n, res_words) uint32
    hashes: np.ndarray         # (n, 8) uint32  sha256(arg || res)
    miner_of: np.ndarray       # (n,) int32 — first submitter per arg
    merkle_leaves: Tuple[bytes, ...]


@dataclasses.dataclass(frozen=True)
class OptimalResult:
    best_arg: int
    best_res: np.ndarray       # (res_words,) uint32
    winner: int                # miner id
    n_evaluated: int


def _as_words(res) -> jax.Array:
    """Canonicalize a jash result pytree to a flat uint32 vector."""
    leaves = jax.tree.leaves(res)
    flat = [jnp.atleast_1d(x).astype(jnp.uint32).reshape(-1) for x in leaves]
    return jnp.concatenate(flat) if len(flat) > 1 else flat[0]


def _miner_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def run_full(jash: Jash, *, mesh: Optional[Mesh] = None,
             block_reward: float = 1.0) -> FullResult:
    """Evaluate every valid arg (§3.3 full mode)."""
    n = jash.meta.n_args
    axes = _miner_axes(mesh)
    n_miners = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    n_pad = -n % n_miners
    args = jnp.arange(n + n_pad, dtype=jnp.uint32)

    def eval_all(args_slice):
        res = jax.vmap(lambda a: _as_words(jash.fn(a)))(args_slice)
        msg = jnp.concatenate([args_slice[:, None], res], axis=1)
        hashes = sha256_words(msg)
        return res, hashes

    if mesh is not None and axes:
        spec = P(axes)
        fn = shard_map(eval_all, mesh=mesh, in_specs=(spec,),
                       out_specs=(spec, spec))
        with mesh:
            res, hashes = jax.jit(fn)(args)
    else:
        res, hashes = jax.jit(eval_all)(args)

    res = np.asarray(res)[:n]
    hashes = np.asarray(hashes)[:n]
    args_np = np.asarray(args)[:n]
    miner_of = (args_np % n_miners).astype(np.int32) if n_miners > 1 \
        else np.zeros(n, np.int32)
    leaves = tuple(
        args_np[i].tobytes() + res[i].tobytes() for i in range(n))
    return FullResult(args=args_np, results=res, hashes=hashes,
                      miner_of=miner_of, merkle_leaves=leaves)


def run_optimal(jash: Jash, *, mesh: Optional[Mesh] = None) -> OptimalResult:
    """Distributed argmin of res (§3.3 optimal mode).  The res ordering is
    lexicographic on words == 'most leading zeros' for hash-like outputs."""
    n = jash.meta.n_args
    axes = _miner_axes(mesh)
    n_miners = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    n_pad = -n % n_miners
    args = jnp.arange(n + n_pad, dtype=jnp.uint32)
    valid = args < n

    MAXW = jnp.uint32(0xFFFFFFFF)

    def eval_and_reduce(args_slice, valid_slice):
        res = jax.vmap(lambda a: _as_words(jash.fn(a)))(args_slice)
        w0 = jnp.where(valid_slice, res[:, 0], MAXW)
        w1 = res[:, 1] if res.shape[1] > 1 else jnp.zeros_like(res[:, 0])
        w1 = jnp.where(valid_slice, w1, MAXW)
        # lexicographic min on (w0, w1) == "most leading zeros" (§3.3)
        i = jnp.lexsort((w1, w0))[0]
        return w0[i], w1[i], args_slice[i], res[i]

    if mesh is not None and axes:
        def sharded(args_all, valid_all):
            w0, w1, arg, res = eval_and_reduce(args_all, valid_all)
            w0g = jax.lax.all_gather(w0, axes)
            w1g = jax.lax.all_gather(w1, axes)
            argsg = jax.lax.all_gather(arg, axes)
            resg = jax.lax.all_gather(res, axes)
            best = jnp.lexsort((w1g, w0g))[0]
            return argsg[best], resg[best], best.astype(jnp.int32)

        fn = shard_map(sharded, mesh=mesh, in_specs=(P(axes), P(axes)),
                       out_specs=(P(), P(), P()))
        with mesh:
            best_arg, best_res, winner = jax.jit(fn)(args, valid)
    else:
        _, _, best_arg, best_res = jax.jit(eval_and_reduce)(args, valid)
        winner = 0

    return OptimalResult(best_arg=int(best_arg),
                         best_res=np.atleast_1d(np.asarray(best_res)),
                         winner=int(winner), n_evaluated=n)
