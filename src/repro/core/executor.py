"""Execution engines: how miners (mesh devices) evaluate a published jash
over its argument space (PNPCoin §3.3).

**full** mode — "Full execution returns the output of every valid input":
the arg space [0, n_args) is processed in fixed-size chunks; each chunk is
one jitted ``shard_map`` dispatch that fuses jash eval, the submission
hash ``sha256(arg || res)``, and the Merkle *leaf digest*
``sha256(arg_bytes || res_bytes)`` (the batched SHA-256 kernel runs both).
Chunking bounds device memory for large ``n_args`` — only one chunk of
results is ever resident on device — and every chunk reuses the same
compiled executable.  The block commitment (Merkle root over all leaf
digests) is a single fused device reduction (``kernels/merkle``).

**optimal** mode — "accepts the lowest res, the result with most leading
zeros": each miner reduces its slice to the lexicographic (res, arg)
minimum in a single vectorized pass (min + tie-masked min + argmax — no
O(n log n) sort), and a global gather-min picks the block winner.

**multi-lane mining** — ``lanes=k`` emulates a k-miner fleet on one
device: the arg space is partitioned over k miner lanes and the whole
fleet runs as one vmapped dispatch (full mode: a strided
``(width, lanes)`` re-tile inside the fused chunk executor, so
``miner_of = arg % lanes`` attribution matches the mesh convention;
optimal mode: contiguous per-lane slices, each reduced to its
lexicographic minimum, with a cross-lane argmin picking the winner
lane).  Lane partitioning never changes the mined bits: full-mode
results/hashes and the optimal ``(best_arg, best_res)`` are bit-identical
to ``lanes=1`` — contiguous optimal slices preserve the global
first-occurrence tie-break — which is what lets a verifier replay with
``lanes=1`` and still match a multi-lane miner's commitment exactly.

On the CPU container the same code runs on a 1-device mesh; on the
production mesh the miner axis is ("data",) (256 miners/pod) or
("pod", "data") (512).  ``lanes`` and a sharded mesh are mutually
exclusive: a real fleet already has its miner axes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.jash import Jash
from repro.kernels.merkle import bswap32, merkle_root_from_digests
from repro.kernels.ops import sha256_words

# Default ceiling on per-dispatch rows in full mode: bounds device-resident
# results while keeping each dispatch large enough to stay kernel-bound.
DEFAULT_CHUNK = 1 << 16


@dataclasses.dataclass(frozen=True)
class FullResult:
    args: np.ndarray           # (n,) uint32
    results: np.ndarray        # (n, res_words) uint32
    hashes: np.ndarray         # (n, 8) uint32  sha256(arg || res)
    miner_of: np.ndarray       # (n,) int32 — first submitter per arg
    leaf_digests: np.ndarray   # (n, 8) uint32  sha256(leaf bytes)
    _leaves: Optional[Tuple[bytes, ...]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _packed: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def packed_words(self) -> np.ndarray:
        """(n, 1 + res_words) little-endian uint32 message words — each
        row is the ``arg || res`` Merkle-leaf message.  This is the array
        the fused executor hashes in-dispatch (after an in-kernel
        ``bswap32``) and the batched verifier re-hashes independently;
        ``merkle_leaves`` is its byte view.  Cached: batched
        verification reads it once for the dedup key and once for the
        root recompute."""
        if self._packed is None:
            object.__setattr__(self, "_packed", np.ascontiguousarray(
                np.concatenate([self.args[:, None], self.results],
                               axis=1).astype("<u4")))
        return self._packed

    @property
    def merkle_leaves(self) -> Tuple[bytes, ...]:
        """Leaf byte strings ``arg.tobytes() + res.tobytes()``, materialized
        lazily from the packed arrays (one buffer slice per leaf, no per-row
        ``tobytes`` loop)."""
        if self._leaves is None:
            packed = self.packed_words()
            buf = packed.tobytes()
            stride = packed.shape[1] * 4
            leaves = tuple(buf[i * stride:(i + 1) * stride]
                           for i in range(packed.shape[0]))
            object.__setattr__(self, "_leaves", leaves)
        return self._leaves

    def commit_root(self) -> str:
        """Block-commitment Merkle root over the leaf digests (device)."""
        return merkle_root_from_digests(self.leaf_digests)


@dataclasses.dataclass(frozen=True)
class OptimalResult:
    best_arg: int
    best_res: np.ndarray       # (res_words,) uint32
    winner: int                # miner id
    n_evaluated: int


def _as_words(res) -> jax.Array:
    """Canonicalize a jash result pytree to a flat uint32 vector."""
    leaves = jax.tree.leaves(res)
    flat = [jnp.atleast_1d(x).astype(jnp.uint32).reshape(-1) for x in leaves]
    return jnp.concatenate(flat) if len(flat) > 1 else flat[0]


def _miner_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@functools.lru_cache(maxsize=128)
def _chunk_executor(jash_fn: Callable, mesh: Optional[Mesh],
                    axes: Tuple[str, ...], lanes: int = 1):
    """Compiled full-mode chunk dispatcher, cached on the jash function so
    repeated ``run_full`` calls (and all chunks within one) reuse one
    executable instead of re-jitting a fresh closure per call.

    With ``lanes > 1`` (single-device multi-lane mode) the chunk is
    re-tiled to ``(width, lanes)`` and the jash is vmapped over both
    axes: lane ``l`` evaluates exactly the args ``≡ l (mod lanes)`` it is
    credited for (``miner_of = arg % lanes``), and the whole lane fleet
    is still one device dispatch.  Element-wise independence makes the
    outputs bit-identical to the ``lanes=1`` layout."""

    def eval_chunk(args_slice):
        if lanes > 1:
            # strided lane partition: row-major (width, lanes) puts arg
            # a in column a % lanes == its miner lane
            lane_args = args_slice.reshape(-1, lanes)
            res = jax.vmap(jax.vmap(lambda a: _as_words(jash_fn(a))))(
                lane_args)
            res = res.reshape(args_slice.shape[0], -1)
        else:
            res = jax.vmap(lambda a: _as_words(jash_fn(a)))(args_slice)
        msg = jnp.concatenate([args_slice[:, None], res], axis=1)
        hashes = sha256_words(msg)
        # Merkle leaf = little-endian bytes of (arg, res) words; bswap
        # re-expresses them in the kernel's big-endian word convention.
        leaf_digests = sha256_words(bswap32(msg))
        return res, hashes, leaf_digests

    if mesh is not None and axes:
        spec = P(axes)
        fn = shard_map(eval_chunk, mesh=mesh, in_specs=(spec,),
                       out_specs=(spec, spec, spec))
    else:
        fn = eval_chunk
    return jax.jit(fn)


def run_full(jash: Jash, *, mesh: Optional[Mesh] = None,
             block_reward: float = 1.0,
             chunk_size: Optional[int] = None,
             lanes: int = 1) -> FullResult:
    """Evaluate every valid arg (§3.3 full mode), ``chunk_size`` rows per
    dispatch (None = whole space in one dispatch, capped at
    ``DEFAULT_CHUNK``).  ``lanes`` partitions the arg space over that
    many single-device miner lanes (one vmapped dispatch; ``miner_of =
    arg % lanes``); results are bit-identical to ``lanes=1``."""
    if chunk_size is not None and chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    n = jash.meta.n_args
    axes = _miner_axes(mesh)
    if axes and lanes != 1:
        raise ValueError(
            "lanes is the single-device miner partition; a sharded mesh "
            "already defines the miner fleet via its axes — use one or "
            "the other")
    lanes = min(lanes, n)
    n_miners = int(np.prod([mesh.shape[a] for a in axes])) if axes else lanes

    chunk = min(n, chunk_size or DEFAULT_CHUNK)
    chunk += -chunk % n_miners                 # dispatch divisible by miners
    n_chunks = -(-n // chunk)

    jitted = _chunk_executor(jash.fn, mesh, axes, lanes)
    ctx = mesh if (mesh is not None and axes) else None

    # the last chunk is right-sized (rounded up to the miner count) so a
    # ragged tail doesn't evaluate and hash a whole chunk of discarded args
    tail = n - (n_chunks - 1) * chunk
    tail += -tail % n_miners

    res_parts, hash_parts, leaf_parts = [], [], []
    for c in range(n_chunks):
        width = chunk if c < n_chunks - 1 else tail
        args_c = jnp.arange(c * chunk, c * chunk + width, dtype=jnp.uint32)
        if ctx is not None:
            with ctx:
                r, h, d = jitted(args_c)
        else:
            r, h, d = jitted(args_c)
        res_parts.append(np.asarray(r))
        hash_parts.append(np.asarray(h))
        leaf_parts.append(np.asarray(d))

    cat = (lambda ps: ps[0][:n] if len(ps) == 1
           else np.concatenate(ps, axis=0)[:n])
    res, hashes, leaves = cat(res_parts), cat(hash_parts), cat(leaf_parts)
    args_np = np.arange(n, dtype=np.uint32)
    miner_of = (args_np % n_miners).astype(np.int32) if n_miners > 1 \
        else np.zeros(n, np.int32)
    return FullResult(args=args_np, results=res, hashes=hashes,
                      miner_of=miner_of, leaf_digests=leaves)


MAXW = jnp.uint32(0xFFFFFFFF)


def _lex_argmin(w0: jax.Array, w1: jax.Array) -> jax.Array:
    """Index of the lexicographic minimum of (w0, w1) — first occurrence,
    single vectorized pass (three reductions, no sort)."""
    tie = w0 == jnp.min(w0)
    m1 = jnp.min(jnp.where(tie, w1, MAXW))
    # `tie & (w1 == m1)` keeps the edge case where every tied w1 is MAXW
    # from escaping the tie set (a plain argmin over the masked w1 would).
    return jnp.argmax(tie & (w1 == m1))


def _eval_and_reduce(jash_fn: Callable, args_slice, valid_slice):
    """One miner's slice -> its lexicographic (res, arg) minimum, first
    occurrence (three reductions, no sort)."""
    res = jax.vmap(lambda a: _as_words(jash_fn(a)))(args_slice)
    w0 = jnp.where(valid_slice, res[:, 0], MAXW)
    w1 = res[:, 1] if res.shape[1] > 1 else jnp.zeros_like(res[:, 0])
    w1 = jnp.where(valid_slice, w1, MAXW)
    i = _lex_argmin(w0, w1)
    return w0[i], w1[i], args_slice[i], res[i]


@functools.lru_cache(maxsize=128)
def _optimal_executor(jash_fn: Callable, lanes: int):
    """Compiled single-device optimal-mode reducer, cached on the jash
    function (repeated mining/verification replays reuse one executable
    instead of re-jitting a fresh closure per call — the same fix
    ``_chunk_executor`` applies to full mode).

    ``lanes > 1`` vmaps the per-miner reduction over contiguous
    per-lane slices of the arg space in one dispatch; a cross-lane
    lex-argmin then picks the winner lane.  Contiguous slices preserve
    the global first-occurrence tie-break, so ``(best_arg, best_res)``
    is bit-identical for every lane count."""

    def reduce_all(args, valid):
        lane_args = args.reshape(lanes, -1)
        lane_valid = valid.reshape(lanes, -1)
        w0s, w1s, argss, ress = jax.vmap(
            lambda a, v: _eval_and_reduce(jash_fn, a, v))(
                lane_args, lane_valid)
        best = _lex_argmin(w0s, w1s)
        return argss[best], ress[best], best.astype(jnp.int32)

    return jax.jit(reduce_all)


def run_optimal(jash: Jash, *, mesh: Optional[Mesh] = None,
                lanes: int = 1) -> OptimalResult:
    """Distributed argmin of res (§3.3 optimal mode).  The res ordering is
    lexicographic on words == 'most leading zeros' for hash-like outputs.

    ``lanes`` partitions the arg space into that many contiguous
    single-device miner lanes mined in one vmapped dispatch; ``winner``
    is the lane holding the block minimum.  ``(best_arg, best_res)`` is
    independent of the lane count, so a verifier replaying with
    ``lanes=1`` reproduces a multi-lane miner's commitment bit-exactly.
    """
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    n = jash.meta.n_args
    axes = _miner_axes(mesh)

    if mesh is not None and axes:
        if lanes != 1:
            raise ValueError(
                "lanes is the single-device miner partition; a sharded "
                "mesh already defines the miner fleet via its axes — use "
                "one or the other")
        n_miners = int(np.prod([mesh.shape[a] for a in axes]))
        n_pad = -n % n_miners
        args = jnp.arange(n + n_pad, dtype=jnp.uint32)
        valid = args < n

        def sharded(args_all, valid_all):
            w0, w1, arg, res = _eval_and_reduce(jash.fn, args_all,
                                                valid_all)
            w0g = jax.lax.all_gather(w0, axes)
            w1g = jax.lax.all_gather(w1, axes)
            argsg = jax.lax.all_gather(arg, axes)
            resg = jax.lax.all_gather(res, axes)
            best = _lex_argmin(w0g, w1g)
            return argsg[best], resg[best], best.astype(jnp.int32)

        fn = shard_map(sharded, mesh=mesh, in_specs=(P(axes), P(axes)),
                       out_specs=(P(), P(), P()))
        with mesh:
            best_arg, best_res, winner = jax.jit(fn)(args, valid)
    else:
        lanes = min(lanes, n)
        n_pad = -n % lanes
        args = jnp.arange(n + n_pad, dtype=jnp.uint32)
        valid = args < n
        best_arg, best_res, winner = _optimal_executor(jash.fn, lanes)(
            args, valid)

    return OptimalResult(best_arg=int(best_arg),
                         best_res=np.atleast_1d(np.asarray(best_res)),
                         winner=int(winner), n_evaluated=n)
