"""The *jash* (PNPCoin §3): an arbitrary deterministic bounded-complexity
function replacing Bitcoin's SHA-256 in the proof-of-work step.

Paper requirements -> JAX enforcement:

  1. "compiles with the current gcc"       -> traces + lowers + compiles
     under ``jax.jit`` (checked by the Runtime Authority at submission).
  2. "deterministic across runs/archs"     -> pure jaxpr, fixed HLO; no
     RNG primitives without explicit keys, no callbacks/IO (validated).
  3. single binary argument of n bits      -> ``arg: uint32[n_words]``
     (``JashMeta.arg_bits`` + optional ``max_arg`` for sub-power-of-two
     granularity, §3.1).
  4. returns an m-bit string               -> ``res: uint32[m_words]``;
     ordering for **optimal** mode = lexicographic (most leading zeros
     wins, as in the paper).
  5. no while loops / recursion, loops run <= s times -> the traced jaxpr
     is walked recursively and any ``while`` primitive whose trip count
     is not statically bounded is REJECTED.  ``fori_loop`` with constant
     bounds and ``scan`` with static length lower to bounded loops and
     pass — this is the §3.2 bounded-complexity discipline, natively.

``bounded_while`` reproduces the paper's Fig.2->Fig.3 conversion: an
unbounded ``while`` becomes a ``fori_loop`` with an upper bound ``s`` and
an early-termination flag ("did not terminate" is a result code the
researcher handles, §4).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.extend
import jax.numpy as jnp

# primitives that would break the paper's determinism/boundedness rules
_FORBIDDEN = {"while"}
_IO_FORBIDDEN = {"io_callback", "pure_callback", "python_callback",
                 "outside_call"}


class JashValidationError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class JashMeta:
    """The meta file accompanying every jash (§3): data checksum, how data
    is acquired, and the argument bound."""
    arg_bits: int
    res_bits: int
    max_arg: Optional[int] = None          # §3.1 granularity bound
    data_checksum: str = ""                # sha256 of the data bundle
    data_acquisition: str = "none"         # "direct" | "p2p" | "none"
    importance: float = 0.5                # §3.3 prioritization (0..1)
    description: str = ""

    @property
    def n_args(self) -> int:
        upper = 1 << self.arg_bits
        return min(upper, self.max_arg) if self.max_arg else upper


def _check_jaxpr(jaxpr, *, allow_loops_up_to: int = 1 << 20,
                 path: str = "") -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _FORBIDDEN:
            # a `while` with a known trip count lowers from fori_loop/scan;
            # jax keeps those as scan/fori in the jaxpr, so any surviving
            # `while` primitive is genuinely unbounded.
            raise JashValidationError(
                f"unbounded `while` at {path or '<jash>'} — PNPCoin §3 "
                "requires every loop to have a static bound (req. 5). "
                "Use repro.core.jash.bounded_while.")
        if prim in _IO_FORBIDDEN:
            raise JashValidationError(
                f"IO/callback primitive `{prim}` — jash functions must be "
                "deterministic and must not communicate (§3 req. 2).")
        if prim == "scan":
            length = eqn.params.get("length", 0)
            if length > allow_loops_up_to:
                raise JashValidationError(
                    f"scan length {length} exceeds the RA loop bound "
                    f"s={allow_loops_up_to} (§3 req. 5)")
        for sub in eqn.params.values():
            if isinstance(sub, jax.extend.core.ClosedJaxpr):
                _check_jaxpr(sub.jaxpr, allow_loops_up_to=allow_loops_up_to,
                             path=f"{path}/{prim}")
            elif isinstance(sub, (tuple, list)):
                for s in sub:
                    if isinstance(s, jax.extend.core.ClosedJaxpr):
                        _check_jaxpr(s.jaxpr,
                                     allow_loops_up_to=allow_loops_up_to,
                                     path=f"{path}/{prim}")


@dataclasses.dataclass
class Jash:
    """A validated jash: ``fn(arg: uint32[..]) -> uint32[..]`` plus meta.

    ``fn`` may be any JAX-traceable callable over arbitrary pytrees — the
    training-step jash maps (state, batch) pytrees; the canonical binary
    form wraps them via the encoder in ``core/executor``."""
    name: str
    fn: Callable
    meta: JashMeta
    example_args: Tuple = ()
    _jaxpr_ok: bool = dataclasses.field(default=False, init=False)

    def validate(self, *example_args, loop_bound: int = 1 << 20) -> None:
        """§3.3 automated review, step 1: trace + bounded-complexity walk."""
        args = example_args or self.example_args
        closed = jax.make_jaxpr(self.fn)(*args)
        _check_jaxpr(closed.jaxpr, allow_loops_up_to=loop_bound)
        object.__setattr__(self, "_jaxpr_ok", True)

    def lower_compile(self, *example_args):
        """§3.3 step 2: 'checking whether it compiles'."""
        args = example_args or self.example_args
        return jax.jit(self.fn).lower(*args).compile()

    def source_id(self) -> str:
        """Unique ID under which the jash circulates on the fileshare (§4)."""
        h = hashlib.sha256()
        h.update(self.name.encode())
        h.update(repr(self.meta).encode())
        return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# §3.2 — conversion of unbounded loops to bounded complexity
# ---------------------------------------------------------------------------


def bounded_while(cond: Callable, body: Callable, init: Any, *,
                  max_steps: int) -> Tuple[Any, jax.Array]:
    """The paper's Fig.2 -> Fig.3 transform: run ``body`` while ``cond``
    holds, for at most ``max_steps`` iterations.  Returns
    ``(final_state, terminated)`` where ``terminated`` is False if the
    bound was hit first — the §4 "did not terminate" result code."""

    def step(i, carry):
        state, done = carry
        active = jnp.logical_and(jnp.logical_not(done), cond(state))
        new_state = jax.tree.map(
            lambda a, b: jnp.where(active, b, a), state, body(state))
        done = jnp.logical_or(done, jnp.logical_not(cond(new_state)))
        return new_state, done

    state, done = jax.lax.fori_loop(
        0, max_steps, step, (init, jnp.bool_(False)))
    return state, done


def collatz_jash(max_steps: int = 1024) -> Jash:
    """The paper's own worked example (§3.2 Figs. 2-3): bounded Collatz.
    res = number of steps to reach 1, or max_steps if not terminated."""

    def fn(arg: jax.Array) -> jax.Array:
        b0 = jnp.maximum(arg.astype(jnp.uint32), 1)

        def cond(s):
            return s[0] != 1

        def body(s):
            b, n = s
            nxt = jnp.where(b % 2 == 0, b // 2, 3 * b + 1)
            return nxt, n + 1

        (b, n), terminated = bounded_while(
            cond, body, (b0, jnp.uint32(0)), max_steps=max_steps)
        return jnp.where(terminated, n, jnp.uint32(max_steps))

    meta = JashMeta(arg_bits=16, res_bits=32,
                    description="Collatz stopping time (paper Fig. 2-3)")
    return Jash("collatz", fn, meta,
                example_args=(jnp.uint32(27),))
