"""Blockchain ledger: blocks, SHA-256 chaining, Merkle trees over results.

The ledger does what PNPCoin keeps from Bitcoin (§3.1): results are shared
by nodes communicating the hash of the chain, timestamps are the block
sequence, and each block commits to (jash id, Merkle root of all submitted
results, winner, previous hash).  The Runtime Authority "does not
intervene in the ledger" (Fig. 1) — nothing in core/authority writes here
except by publishing a jash id the miners then commit.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# Below this leaf count the Python tree wins (no dispatch overhead); above
# it the batched device reduction does (measured in BENCH_pipeline.json).
_DEVICE_MIN_LEAVES = 256


def merkle_root(leaves: Sequence[bytes], *, backend: str = "auto") -> str:
    """Bitcoin-style Merkle tree (duplicate last node on odd levels).

    ``backend="hashlib"`` is the reference implementation; ``"device"``
    runs the level-by-level batched reduction on the SHA-256 kernel
    (bit-identical, O(log N) fused into one dispatch); ``"auto"`` picks
    by leaf count."""
    if backend == "auto":
        backend = "device" if len(leaves) >= _DEVICE_MIN_LEAVES \
            else "hashlib"
    if backend == "device":
        from repro.kernels.merkle import merkle_root_device
        return merkle_root_device(leaves)
    if backend != "hashlib":
        raise ValueError(f"unknown merkle backend {backend!r} "
                         "(expected 'auto', 'device' or 'hashlib')")
    if not leaves:
        return sha256_hex(b"")
    level = [hashlib.sha256(x).digest() for x in leaves]
    while len(level) > 1:
        if len(level) % 2:
            level.append(level[-1])
        level = [hashlib.sha256(level[i] + level[i + 1]).digest()
                 for i in range(0, len(level), 2)]
    return level[0].hex()


def merkle_proof(leaves: Sequence[bytes], index: int, *,
                 backend: str = "hashlib") -> List[Dict]:
    """Inclusion proof for ``leaves[index]`` -> list of (side, hash).

    Raises ``IndexError`` outside the leaf set on every backend — a
    proof over a duplicated odd-level pad node would verify against
    the root without corresponding to any submitted result."""
    if not 0 <= index < len(leaves):
        raise IndexError(
            f"proof index {index} out of range for {len(leaves)} leaves")
    if backend == "auto":
        backend = "device" if len(leaves) >= _DEVICE_MIN_LEAVES \
            else "hashlib"
    if backend == "device":
        from repro.kernels.merkle import merkle_proof_device
        return merkle_proof_device(leaves, index)
    if backend != "hashlib":
        raise ValueError(f"unknown merkle backend {backend!r} "
                         "(expected 'auto', 'device' or 'hashlib')")
    level = [hashlib.sha256(x).digest() for x in leaves]
    proof = []
    idx = index
    while len(level) > 1:
        if len(level) % 2:
            level.append(level[-1])
        sib = idx ^ 1
        proof.append({"side": "left" if sib < idx else "right",
                      "hash": level[sib].hex()})
        level = [hashlib.sha256(level[i] + level[i + 1]).digest()
                 for i in range(0, len(level), 2)]
        idx //= 2
    return proof


def verify_merkle_proof(leaf: bytes, proof: List[Dict], root: str) -> bool:
    h = hashlib.sha256(leaf).digest()
    for step in proof:
        sib = bytes.fromhex(step["hash"])
        h = hashlib.sha256(sib + h if step["side"] == "left" else h + sib
                           ).digest()
    return h.hex() == root


@dataclasses.dataclass(frozen=True)
class Block:
    height: int
    prev_hash: str
    jash_id: str
    mode: str                      # "full" | "optimal" | "classic"
    merkle_root: str
    winner: Optional[int]          # miner id of the optimal submission
    best_res: Optional[str]        # hex of the lowest res (optimal mode)
    n_results: int
    state_digest: str = ""         # PoUW: checkpoint digest chained in
    timestamp: float = 0.0

    def header_bytes(self) -> bytes:
        # field-by-field, not dataclasses.asdict: every field is a
        # scalar, and asdict's recursive deep-copy is measurable on the
        # gossip hot path (one header hash per delivered block).  The
        # serialized bytes are unchanged — sort_keys orders the same
        # key set, so existing chains re-hash identically.
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "timestamp"}
        return json.dumps(d, sort_keys=True).encode()

    @functools.cached_property
    def block_hash(self) -> str:
        # cached: duplicate detection on the gossip hot path compares
        # hashes against whole chains, and the frozen dataclass never
        # changes after construction (cached_property writes straight to
        # __dict__, bypassing the frozen __setattr__)
        return sha256_hex(self.header_bytes())


class Ledger:
    """Append-only chain with integrity verification."""

    GENESIS_HASH = sha256_hex(b"PNPCoin genesis (Kolar 2022)")

    def __init__(self) -> None:
        self.blocks: List[Block] = []

    @property
    def tip_hash(self) -> str:
        return self.blocks[-1].block_hash if self.blocks else self.GENESIS_HASH

    @property
    def height(self) -> int:
        return len(self.blocks)

    def append(self, *, jash_id: str, mode: str, merkle: str,
               winner: Optional[int], best_res: Optional[str],
               n_results: int, state_digest: str = "") -> Block:
        blk = Block(height=self.height, prev_hash=self.tip_hash,
                    jash_id=jash_id, mode=mode, merkle_root=merkle,
                    winner=winner, best_res=best_res, n_results=n_results,
                    state_digest=state_digest, timestamp=time.time())
        self.blocks.append(blk)
        return blk

    def verify_chain(self) -> bool:
        prev = self.GENESIS_HASH
        for i, blk in enumerate(self.blocks):
            if blk.height != i or blk.prev_hash != prev:
                return False
            prev = blk.block_hash
        return True

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(b) for b in self.blocks],
                          indent=2)
