"""Proof-of-useful-work training: blocks == training steps.

This is the paper's flagship payload (§1: replace hashes with "stochastic
optimizations such as Deep Net training").  Each block:

  1. the RA publishes the block's jash — the (validated, bounded-
     complexity) train step with the block's data-batch meta;
  2. miners execute it — **full** mode is synchronous data-parallel SGD
     (every miner's shard-gradient is a submitted result; the all-reduce
     is the aggregation the RA performs in Fig. 1), **optimal** mode is
     ES candidate search (core/es) where the lowest loss wins;
  3. results are Merkle-committed, the new state digest is chained into
     the ledger, and rewards are credited (full: split across miners;
     optimal: winner takes the block).

The determinism requirement (§3 req. 2) makes this auditable: any
verifier re-derives batch (seed, step) from the meta, re-runs the step,
and must reproduce the state digest bit-exactly.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.core import es as es_mod
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import Ledger, merkle_root
from repro.core.rewards import CreditBook, reward_full, reward_optimal
from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.steps import (TrainHparams, TrainState, make_eval_step,
                               make_train_state, make_train_step,
                               params_digest)


@dataclasses.dataclass
class BlockRecord:
    height: int
    mode: str
    loss: float
    state_digest: str
    block_hash: str


def _metrics_digest(metrics: Dict[str, Any], step: int) -> str:
    h = hashlib.sha256()
    h.update(np.int64(step).tobytes())
    for k in sorted(metrics):
        h.update(k.encode())
        h.update(np.asarray(metrics[k], np.float64).tobytes())
    return h.hexdigest()


def _light_state_digest(state: TrainState) -> str:
    """Per-block state digest: sha256 of the canonical params bytes
    (``train.steps.params_digest`` — gathered, little-endian,
    dtype+shape framed).  The old projection digest hashed the first 64
    elements + a float sum per leaf straight out of device memory,
    which tied the commitment to device layout and silently collided
    for params differing outside the projection; the canonical digest
    is sharding-invariant and collision-resistant over the full
    weights, and is the same helper ``ModelTrainingWorkload`` commits
    on-chain."""
    return params_digest(state)


class PoUWTrainer:
    """Block-driven trainer.  ``mode``: "full" (data-parallel SGD) or
    "optimal" (ES candidate search, §3.3)."""

    def __init__(self, cfg: ModelConfig, shape: InputShape, *,
                 hp: TrainHparams = TrainHparams(), mode: str = "full",
                 n_miners: int = 8, block_reward: float = 50.0,
                 pop_size: int = 8, sigma: float = 0.01,
                 seed: int = 0, block_microsteps: int = 1,
                 fixed_batch: bool = False) -> None:
        assert mode in ("full", "optimal")
        if block_microsteps < 1:
            raise ValueError(
                f"block_microsteps must be >= 1, got {block_microsteps} "
                "(a block with no microsteps commits no work)")
        self.cfg, self.shape, self.hp, self.mode = cfg, shape, hp, mode
        self.fixed_batch = fixed_batch
        self.n_miners = n_miners
        self.block_reward = block_reward
        self.pop_size, self.sigma = pop_size, sigma
        self.block_microsteps = block_microsteps
        self._seed = seed
        self.pipeline = SyntheticTokenPipeline(cfg, shape, seed=seed)
        self.ledger = Ledger()
        self.book = CreditBook()
        self.state = make_train_state(cfg, jax.random.key(seed))
        self._train_step = jax.jit(make_train_step(cfg, hp))
        self._eval_step = jax.jit(make_eval_step(cfg))
        self._block_step = self._make_block_step(make_train_step(cfg, hp),
                                                 block_microsteps)
        self._replay_cache: Dict[int, "PoUWTrainer"] = {}
        eval_fn = make_eval_step(cfg)
        self._es_block = jax.jit(
            lambda params, batch, key: es_mod.es_block(
                eval_fn, params, batch, key,
                pop_size=self.pop_size, sigma=self.sigma))
        self.key = jax.random.key(seed + 1)
        self.history: List[BlockRecord] = []

        # The published payload is itself a jash: validated for bounded
        # complexity exactly like any researcher submission.
        self.step_jash = Jash(
            name=f"train-{cfg.name}-{shape.name}",
            fn=lambda st, b: self._train_step(st, b),
            meta=JashMeta(arg_bits=32, res_bits=256,
                          data_checksum=self.pipeline.checksum(),
                          data_acquisition="p2p",
                          importance=1.0,
                          description="one PoUW training step"),
        )
        self.step_jash.validate(self.state, self.pipeline.batch(0))

    # ------------------------------------------------------------------
    @staticmethod
    def _make_block_step(train_step, n_micro: int):
        """All of a block's microsteps under one ``lax.scan`` — a single
        dispatch per block instead of one per microstep, with the incoming
        train state donated (the block owns its state buffers)."""

        def block_step(state, batch):
            def body(st, _):
                st, metrics = train_step(st, batch)
                return st, metrics

            state, stacked = jax.lax.scan(body, state, None, length=n_micro)
            return state, jax.tree.map(lambda x: x[-1], stacked)

        # buffer donation is a no-op (warning) on CPU — only ask for it
        # where XLA implements it
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(block_step, donate_argnums=donate)

    def run_block(self) -> BlockRecord:
        step = self.ledger.height
        batch = self.pipeline.batch(0 if self.fixed_batch else step)

        if self.mode == "full":
            self.state, metrics = self._block_step(self.state, batch)
            loss = float(metrics["loss"])
            # every miner's shard-result is a first submission (§3.3)
            leaves = [
                f"{step}|{m}|{_metrics_digest(metrics, step)}".encode()
                for m in range(self.n_miners)]
            winner = None
            best_res = None
            first_submitter = list(range(self.n_miners))
            reward_full(self.book, first_submitter, self.block_reward)
        else:
            self.key, sub = jax.random.split(self.key)
            losses, best = self._es_block(self.state.params, batch, sub)
            best = int(best)
            loss = float(losses[best])
            new_params = es_mod.candidate_params(
                self.state.params, sub, best, self.sigma)
            self.state = TrainState(params=new_params, opt=self.state.opt)
            leaves = [f"{step}|{i}|{float(l):.8f}".encode()
                      for i, l in enumerate(np.asarray(losses))]
            winner = best % self.n_miners
            best_res = f"{loss:.8f}"
            reward_optimal(self.book, winner, self.block_reward)

        digest = _light_state_digest(self.state)
        blk = self.ledger.append(
            jash_id=self.step_jash.source_id(), mode=self.mode,
            merkle=merkle_root(leaves), winner=winner, best_res=best_res,
            n_results=len(leaves), state_digest=digest)
        rec = BlockRecord(height=blk.height, mode=self.mode, loss=loss,
                          state_digest=digest, block_hash=blk.block_hash)
        self.history.append(rec)
        return rec

    def run(self, n_blocks: int) -> List[BlockRecord]:
        return [self.run_block() for _ in range(n_blocks)]

    # ------------------------------------------------------------------
    def audit_block(self, height: int, seed: Optional[int] = None) -> bool:
        """Verifier path: replay the chain up to ``height`` and compare the
        recorded state digest (determinism, §3 req. 2).  ``seed`` defaults
        to the trainer's own construction seed.  The replay trainer is
        cached per seed, so successive audits are incremental — O(delta
        blocks), not O(height) replay-from-genesis per call."""
        seed = self._seed if seed is None else seed
        replay = self._replay_cache.get(seed)
        if replay is None:
            replay = PoUWTrainer(self.cfg, self.shape, hp=self.hp,
                                 mode=self.mode, n_miners=self.n_miners,
                                 pop_size=self.pop_size, sigma=self.sigma,
                                 seed=seed,
                                 block_microsteps=self.block_microsteps,
                                 fixed_batch=self.fixed_batch)
            self._replay_cache[seed] = replay
        while replay.ledger.height <= height:
            replay.run_block()
        return (replay.history[height].state_digest
                == self.history[height].state_digest)
