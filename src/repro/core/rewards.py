"""Reward distribution (PNPCoin §3.3/§4).

**full** mode: "the reward is distributed evenly across all first
submissions of results" — miners earn block_reward / n_args for each arg
they were first to submit, plus (§4) a leading-zeros bonus on
sha256(input || output).

**optimal** mode: "the first lowest solution is accepted" — the winner
takes the block reward.

The credit table is the PoUW analogue of the coin: conservation
(sum of all credits == sum of all block rewards) is a property test.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class CreditBook:
    balances: Dict[int, float] = dataclasses.field(default_factory=dict)
    total_issued: float = 0.0

    def credit(self, miner: int, amount: float) -> None:
        self.balances[miner] = self.balances.get(miner, 0.0) + amount
        self.total_issued += amount


def reward_full(book: CreditBook, first_submitter: Sequence[int],
                block_reward: float,
                bonus_winner: Optional[int] = None,
                bonus_fraction: float = 0.1) -> None:
    """``first_submitter[i]`` = miner id first to return arg i's result."""
    n = len(first_submitter)
    if n == 0:
        return
    base = block_reward * (1.0 - (bonus_fraction if bonus_winner is not None
                                  else 0.0))
    per = base / n
    for miner in first_submitter:
        book.credit(int(miner), per)
    if bonus_winner is not None:
        book.credit(int(bonus_winner), block_reward * bonus_fraction)


def reward_optimal(book: CreditBook, winner: int,
                   block_reward: float) -> None:
    book.credit(int(winner), block_reward)
