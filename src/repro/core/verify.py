"""Result verification: the consensus stand-in (DESIGN.md §2, §10).

PNPCoin requires jash determinism "across runs, architectures, and
compilations" (§3 req. 2) — that is what lets any node audit any miner.
``quorum_verify`` re-executes a random fraction of the arg space on
verifier devices and compares digests bit-exactly; one mismatch marks the
block invalid.  ``verify_inclusion`` checks a single (arg, res) pair
against the block's Merkle root — the light-client path.

Because every peer re-verifies every mined block (§3.3), verification —
not mining — dominates network compute at scale.  The batched
counterparts amortize it across a chain segment:

* ``quorum_verify_batched`` stacks every block's sampled args into one
  cached jitted dispatch per distinct jash function (identical
  per-block sampling, so accept/reject is bit-identical to N calls of
  ``quorum_verify``);
* ``recompute_roots_batched`` re-commits every block's Merkle root
  independently from its raw ``(arg, res)`` arrays on the words-major
  device reducer (one fused leaf-digest dispatch + one forest
  reduction), with a ``hashlib`` spot-check of one block's root per
  shape group — the reference code path stays exercised against every
  shape-specialized kernel used, and a spot-check mismatch falls back
  to recomputing *every* root with ``hashlib`` so the accept/reject
  decision never depends on the device kernel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import FullResult, _as_words
from repro.core.jash import Jash
from repro.core.ledger import merkle_proof, merkle_root, verify_merkle_proof
from repro.kernels.merkle import bswap32, merkle_roots_from_digests
from repro.kernels.ops import sha256_words


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    n_checked: int
    n_mismatch: int
    ok: bool
    mismatched_args: tuple = ()


@functools.lru_cache(maxsize=128)
def _recompute_fn(jash_fn):
    """Compiled subset re-executor, cached on the jash function so every
    audit of the same jash (a network's worth of receive-side verifies)
    reuses one executable instead of re-jitting per call."""
    return jax.jit(jax.vmap(lambda a: _as_words(jash_fn(a))))


def _sample_indices(n: int, *, fraction: float, seed: int,
                    min_checks: int) -> np.ndarray:
    """The quorum sample for one block — shared by the scalar and
    batched paths so their accept/reject decisions are bit-identical."""
    rng = np.random.RandomState(seed)
    k = max(min_checks, int(n * fraction))
    return rng.choice(n, size=min(k, n), replace=False)


def quorum_verify(jash: Jash, full: FullResult, *, fraction: float = 0.05,
                  seed: int = 0, min_checks: int = 4) -> VerifyReport:
    """Deterministic re-execution of a random subset of args."""
    idx = _sample_indices(len(full.args), fraction=fraction, seed=seed,
                          min_checks=min_checks)

    args = jnp.asarray(full.args[idx], jnp.uint32)
    recomputed = np.asarray(_recompute_fn(jash.fn)(args))

    mism = [int(full.args[i]) for j, i in enumerate(idx)
            if not np.array_equal(recomputed[j], full.results[i])]
    return VerifyReport(n_checked=len(idx), n_mismatch=len(mism),
                        ok=not mism, mismatched_args=tuple(mism))


def quorum_verify_batched(pairs: Sequence[Tuple[Jash, FullResult]], *,
                          fraction: float = 0.05, seed: int = 0,
                          min_checks: int = 4) -> List[VerifyReport]:
    """``quorum_verify`` over a chain segment in one dispatch per jash.

    Each block samples exactly the indices its scalar call would (same
    seeded draw), then all sampled args of blocks sharing a jash
    function are stacked into a single cached jitted re-execution —
    padded up to a power of two so segment lengths don't accumulate
    executables.  Reports are bit-identical to per-block
    ``quorum_verify`` calls."""
    samples = [
        _sample_indices(len(full.args), fraction=fraction, seed=seed,
                        min_checks=min_checks)
        for _, full in pairs]
    by_fn: dict = {}
    for b, (jash, _) in enumerate(pairs):
        by_fn.setdefault(jash.fn, []).append(b)

    recomputed: List[Optional[np.ndarray]] = [None] * len(pairs)
    for fn, blocks in by_fn.items():
        stacked = np.concatenate(
            [pairs[b][1].args[samples[b]] for b in blocks])
        total = len(stacked)
        padded_n = 1 << max(total - 1, 1).bit_length()
        padded = np.zeros(padded_n, np.uint32)
        padded[:total] = stacked
        out = np.asarray(_recompute_fn(fn)(jnp.asarray(padded)))[:total]
        off = 0
        for b in blocks:
            k = len(samples[b])
            recomputed[b] = out[off:off + k]
            off += k

    reports = []
    for b, (_, full) in enumerate(pairs):
        idx, out = samples[b], recomputed[b]
        expect = full.results[idx]      # same indexing as the scalar path
        bad = ~(out.reshape(len(idx), -1) == expect.reshape(len(idx), -1)
                ).all(axis=1)
        mism = tuple(int(full.args[i]) for i in idx[bad])
        reports.append(VerifyReport(n_checked=len(idx),
                                    n_mismatch=len(mism), ok=not mism,
                                    mismatched_args=mism))
    return reports


def recompute_roots_batched(fulls: Sequence[FullResult], *,
                            seed: int = 0) -> List[str]:
    """Independent Merkle-root re-commitment for a segment of blocks.

    Re-derives each block's root from its raw ``(arg, res)`` arrays —
    never trusting the evidence ``leaf_digests`` — via one fused
    device leaf-digest dispatch and one forest reduction per distinct
    block shape.  One seeded-random block per shape group is
    additionally re-committed end-to-end with ``hashlib`` (the
    reference path, exercised for every shape-specialized kernel this
    call used); a mismatch there means the device kernel disagrees
    with the reference, and *every* root is then recomputed with
    ``hashlib`` so batched accept/reject stays bit-identical to the
    per-block path."""
    if not fulls:
        return []
    packed = [full.packed_words() for full in fulls]
    by_shape: dict = {}
    for b, words in enumerate(packed):
        by_shape.setdefault(words.shape, []).append(b)

    roots: List[Optional[str]] = [None] * len(fulls)
    for shape, blocks in by_shape.items():
        words = np.stack([packed[b] for b in blocks])
        flat = jnp.asarray(words.reshape(-1, shape[1]), jnp.uint32)
        digests = np.asarray(sha256_words(bswap32(flat))) \
            .reshape(len(blocks), shape[0], 8)
        for b, root in zip(blocks, merkle_roots_from_digests(digests)):
            roots[b] = root

    # hashlib spot-check of one root per *shape group*: each group took
    # its own device path (leaf width and forest executable are shape-
    # specialized), so probing one member per group keeps the distinct
    # reference code path live on every kernel actually used this call,
    # catching a device regression on real traffic instead of only in
    # tests
    rng = np.random.RandomState(seed)
    for blocks in by_shape.values():
        probe = blocks[int(rng.randint(len(blocks)))]
        reference = merkle_root(list(fulls[probe].merkle_leaves),
                                backend="hashlib")
        if reference != roots[probe]:        # device kernel is wrong:
            return [merkle_root(list(f.merkle_leaves), backend="hashlib")
                    for f in fulls]          # fall back to the reference
    return roots


def verify_inclusion(full: FullResult, arg_index: int, root: str) -> bool:
    """Merkle inclusion proof for one submitted result.

    Raises ``IndexError`` for an index outside the block's arg space —
    there is no leaf (and hence no meaningful proof) to check."""
    if not 0 <= arg_index < len(full.args):
        raise IndexError(
            f"arg_index {arg_index} out of range for a block of "
            f"{len(full.args)} results")
    leaves = list(full.merkle_leaves)
    proof = merkle_proof(leaves, arg_index)
    return verify_merkle_proof(leaves[arg_index], proof, root)
