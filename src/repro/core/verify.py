"""Result verification: the consensus stand-in (DESIGN.md §2).

PNPCoin requires jash determinism "across runs, architectures, and
compilations" (§3 req. 2) — that is what lets any node audit any miner.
``quorum_verify`` re-executes a random fraction of the arg space on
verifier devices and compares digests bit-exactly; one mismatch marks the
block invalid.  ``verify_inclusion`` checks a single (arg, res) pair
against the block's Merkle root — the light-client path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import FullResult, _as_words
from repro.core.jash import Jash
from repro.core.ledger import merkle_proof, merkle_root, verify_merkle_proof


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    n_checked: int
    n_mismatch: int
    ok: bool
    mismatched_args: tuple = ()


@functools.lru_cache(maxsize=128)
def _recompute_fn(jash_fn):
    """Compiled subset re-executor, cached on the jash function so every
    audit of the same jash (a network's worth of receive-side verifies)
    reuses one executable instead of re-jitting per call."""
    return jax.jit(jax.vmap(lambda a: _as_words(jash_fn(a))))


def quorum_verify(jash: Jash, full: FullResult, *, fraction: float = 0.05,
                  seed: int = 0, min_checks: int = 4) -> VerifyReport:
    """Deterministic re-execution of a random subset of args."""
    n = len(full.args)
    rng = np.random.RandomState(seed)
    k = max(min_checks, int(n * fraction))
    idx = rng.choice(n, size=min(k, n), replace=False)

    args = jnp.asarray(full.args[idx], jnp.uint32)
    recomputed = np.asarray(_recompute_fn(jash.fn)(args))

    mism = [int(full.args[i]) for j, i in enumerate(idx)
            if not np.array_equal(recomputed[j], full.results[i])]
    return VerifyReport(n_checked=len(idx), n_mismatch=len(mism),
                        ok=not mism, mismatched_args=tuple(mism))


def verify_inclusion(full: FullResult, arg_index: int, root: str) -> bool:
    """Merkle inclusion proof for one submitted result."""
    leaves = list(full.merkle_leaves)
    proof = merkle_proof(leaves, arg_index)
    return verify_merkle_proof(leaves[arg_index], proof, root)
