"""Deterministic synthetic data pipeline.

PNPCoin §3 requires every jash's data bundle to be *checksummed* and its
acquisition deterministic.  The pipeline mirrors that: batches are a pure
function of (seed, step) — any miner/verifier reproduces the exact bytes
from the meta alone, which is what makes result verification (core/verify)
bit-exact.  The token stream is a Zipf-ish mixture with Markov structure
so the LM loss actually decreases (unlike uniform noise).

Also provides modality stubs (audio frames / image patch embeddings) per
the brief's frontend carve-out.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticTokenPipeline:
    cfg: ModelConfig
    shape: InputShape
    seed: int = 0

    def checksum(self) -> str:
        """The PNPCoin meta checksum for this data bundle."""
        h = hashlib.sha256(
            f"{self.cfg.name}|{self.shape.name}|{self.seed}".encode())
        return h.hexdigest()

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.key(self.seed), step)

    def batch(self, step: int) -> Dict[str, Any]:
        """Global batch for ``step`` (pure function of seed+step)."""
        return self._batch_from_key(self._key(step))

    def microbatch(self, height: int, micro: int) -> Dict[str, Any]:
        """The chain-train stream: microbatch ``micro`` of block
        ``height`` — a pure function of ``(seed, height, micro)``, so
        any fresh pipeline instance constructed from the same meta
        reproduces the exact bytes (the verification-soundness
        precondition for ``ModelTrainingWorkload``: a verifier
        re-derives the miner's batches from the chain position alone).
        Keyed by a second ``fold_in`` so block ``h`` microstep ``m``
        never aliases the plain ``batch(step)`` stream."""
        if micro < 0:
            raise ValueError(f"micro index must be >= 0, got {micro}")
        return self._batch_from_key(
            jax.random.fold_in(self._key(height), micro))

    def _batch_from_key(self, key) -> Dict[str, Any]:
        cfg, shape = self.cfg, self.shape
        B = shape.global_batch
        S = shape.seq_len if shape.kind == "train" else (
            shape.seq_len if shape.kind == "prefill" else 1)
        k1, k2, k3 = jax.random.split(key, 3)
        v = cfg.vocab_size
        # Markov-ish stream: next token = (a*tok + drift) % v with noise
        base = jax.random.randint(k1, (B, 1), 0, v)
        drift = jax.random.randint(k2, (B, S), 0, 16)
        toks = jnp.cumsum(drift, axis=1) * 31 + base
        noise = jax.random.randint(k3, (B, S), 0, v)
        mix = jax.random.bernoulli(k3, 0.05, (B, S))
        tokens = jnp.where(mix, noise, jnp.mod(toks, v)).astype(jnp.int32)
        out: Dict[str, Any] = {"tokens": tokens}
        if shape.kind == "train":
            labels = jnp.concatenate(
                [tokens[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1)
            out["labels"] = labels
        if cfg.family == "vlm" and shape.kind != "decode":
            out["image_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 7),
                (B, cfg.n_img_tokens, cfg.d_vision), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))
        if cfg.family == "encdec" and shape.kind != "decode":
            out["audio_frames"] = jax.random.normal(
                jax.random.fold_in(key, 8),
                (B, cfg.n_enc_tokens, cfg.d_model), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))
        return out


def make_batch_specs(cfg: ModelConfig, shape: InputShape):
    from repro.models.model import input_specs
    return input_specs(cfg, shape)
