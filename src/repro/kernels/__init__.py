from repro.kernels.ops import (  # noqa: F401
    decay_scan, flash_attention, sha256_words, wkv6,
)
