"""Pallas TPU kernel: gated linear decay scan  h_t = a_t * h_{t-1} + b_t.

This is the RG-LRU inner recurrence (recurrentgemma).  TPU adaptation:
the GPU way is a warp-level chunked scan; on TPU we tile the *channel*
dimension to the 128-lane VPU and keep the sequential loop over time in
VMEM — sequence chunks stream HBM->VMEM while the carry ``h`` lives in a
VMEM scratch accumulator.  Grid: (B, C // TILE_C); ops.py chunks long
sequences and carries h across calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_C = 128


def _decay_scan_kernel(a_ref, b_ref, h0_ref, out_ref, hT_ref):
    """a,b: (1, S, TILE_C); h0: (1, TILE_C); out: (1, S, TILE_C)."""
    S = a_ref.shape[1]

    def step(t, h):
        h = a_ref[0, t, :] * h + b_ref[0, t, :]
        out_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, S, step, h0_ref[0, :])
    hT_ref[0, :] = h


def decay_scan_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                      interpret: bool = True):
    """a, b: (B, S, C) float32; h0: (B, C) -> (out (B,S,C), hT (B,C)).
    C must be a multiple of TILE_C (ops.py pads)."""
    B, S, C = a.shape
    assert C % TILE_C == 0, C
    grid = (B, C // TILE_C)
    return pl.pallas_call(
        _decay_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, TILE_C), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, TILE_C), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, TILE_C), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, TILE_C), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, TILE_C), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), a.dtype),
            jax.ShapeDtypeStruct((B, C), a.dtype),
        ],
        interpret=interpret,
    )(a, b, h0)
