"""Pallas TPU kernel: flash attention (online softmax).

The roofline (EXPERIMENTS §Roofline) shows attention O(S²) dominating
compute at prefill_32k and its unfused score intermediates dominating the
memory term — exactly the hot spot flash attention removes.  TPU
adaptation: the canonical (batch·heads, q-block, kv-block) grid; the
kv-block dimension is the innermost (sequential) grid axis, so the
running (m, l, acc) state lives in VMEM scratch across kv steps and the
(S, S) score matrix never exists.  Block shapes default to (512, 512)
— MXU-aligned (multiples of 128) with a working set
(BQ·hd + BK·hd + BQ·BK) · 4 B ≈ 1.6 MB, comfortably inside VMEM.

ops.flash_attention handles GQA (kv-head broadcast), scaling, and the
jnp fallback; ref = repro.models.attention.chunked_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, bq: int, bk: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:, :] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, :]                                  # (BQ, hd)
    k = k_ref[0, :, :]                                  # (BK, hd)
    v = v_ref[0, :, :]

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ()))) * scale               # (BQ, BK)

    if causal:
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[:]                                   # (BQ,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])                     # (BQ, BK)
    l_cur = alpha * l_scr[:] + p.sum(axis=1)
    acc_scr[:, :] = acc_scr[:, :] * alpha[:, None] + \
        p @ v.astype(jnp.float32)
    m_scr[:] = m_cur
    l_scr[:] = l_cur

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, :, :] = (acc_scr[:, :] /
                          jnp.maximum(l_scr[:], 1e-20)[:, None]
                          ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512,
                           bk: int = 512, interpret: bool = True
                           ) -> jax.Array:
    """q: (BH, S, hd); k, v: (BH, T, hd) -> (BH, S, hd).

    S % bq == 0 and T % bk == 0 (ops pads)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_flash_kernel, causal=causal, bq=bq, bk=bk,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),             # running max m
            pltpu.VMEM((bq,), jnp.float32),             # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),          # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
