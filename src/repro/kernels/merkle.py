"""Batched Merkle-tree reduction on a vectorized SHA-256 (DESIGN.md §6).

The block-commitment hot path: Bitcoin-style Merkle trees (duplicate the
last node on odd levels) computed level-by-level with a batched SHA-256
compression instead of per-leaf ``hashlib`` calls.  All wide levels of a
tree are traced into one jitted function — a root over N leaves is ONE
device dispatch doing ~2N compressions across lanes instead of 2N
Python-interpreter round-trips.

Three implementation choices matter for throughput:

- **words-major layout**: the level lives as 8 contiguous rows of width n
  (one row per digest word), so every round's vector ops stream over
  contiguous lanes and LLVM/Mosaic can actually vectorize them.
- **constant padding schedule**: an interior node hashes a 64-byte
  message, so its second compression block is the *fixed* SHA-256 padding
  block; its message schedule (and ``K[t] + W[t]``) is precomputed into
  the ``_KW`` table, cutting that compression's op count by ~40%.
- **hybrid cutover**: below ``_CUTOVER`` lanes the per-op dispatch cost
  exceeds the hashing cost, so the narrow top of the tree finishes on the
  host with ``hashlib`` — bit-identical either way.

Word convention: SHA-256 serializes uint32 words big-endian, and digests
are big-endian words — so an internal node over two child digests is just
their 16 words concatenated, and a byte string of length 4k hashes
identically to its ``>u4`` word view.  ``bswap32`` converts little-endian
word buffers (e.g. ``np.uint32.tobytes()`` leaves built by the executor)
into this convention in-kernel.
"""
from __future__ import annotations

import functools
import hashlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import sha256_words
from repro.kernels.ref import _H0, _K

# Tree levels narrower than this run on the host: at ~64 lanes the
# fixed per-op dispatch cost of the traced compression exceeds hashlib's
# per-call cost (measured in BENCH_pipeline.json).
_CUTOVER = 64


def bswap32(x: jax.Array) -> jax.Array:
    """Byte-swap each uint32 lane (little-endian words -> big-endian)."""
    x = x.astype(jnp.uint32)
    return ((x << jnp.uint32(24))
            | ((x & jnp.uint32(0xFF00)) << jnp.uint32(8))
            | ((x >> jnp.uint32(8)) & jnp.uint32(0xFF00))
            | (x >> jnp.uint32(24)))


# ---------------------------------------------------------------------------
# packing: bytes <-> big-endian word arrays
# ---------------------------------------------------------------------------


def pack_leaves(leaves: Sequence[bytes]) -> Optional[np.ndarray]:
    """Uniform word-aligned leaves -> (N, L//4) big-endian uint32 words.

    Returns None when the leaf set is ragged or not 4-byte aligned (the
    caller then falls back to hashlib for the leaf level only)."""
    if not leaves:
        return None
    L = len(leaves[0])
    if L == 0 or L % 4 or any(len(x) != L for x in leaves):
        return None
    buf = b"".join(leaves)
    return np.frombuffer(buf, dtype=">u4").reshape(len(leaves), L // 4) \
        .astype(np.uint32)


def pack_digests(digests: Sequence[bytes]) -> np.ndarray:
    """32-byte digests -> (N, 8) uint32 word rows."""
    return np.frombuffer(b"".join(digests), dtype=">u4").reshape(-1, 8) \
        .astype(np.uint32)


def words_to_hex(words: np.ndarray) -> str:
    """(8,) uint32 digest words -> hex string (big-endian serialization)."""
    return np.asarray(words, np.uint32).astype(">u4").tobytes().hex()


def _words_to_digest_list(level: np.ndarray) -> List[bytes]:
    buf = np.ascontiguousarray(level.astype(">u4")).tobytes()
    return [buf[i:i + 32] for i in range(0, len(buf), 32)]


# ---------------------------------------------------------------------------
# vectorized SHA-256 compression, words-major
# ---------------------------------------------------------------------------


def _pad_block_schedule() -> List[int]:
    """Message schedule of the constant padding block of a 64-byte msg."""
    w = [0x80000000] + [0] * 14 + [512]

    def rr(x, n):
        return ((x >> n) | (x << (32 - n))) & 0xFFFFFFFF

    for t in range(16, 64):
        s0 = rr(w[t - 15], 7) ^ rr(w[t - 15], 18) ^ (w[t - 15] >> 3)
        s1 = rr(w[t - 2], 17) ^ rr(w[t - 2], 19) ^ (w[t - 2] >> 10)
        w.append((w[t - 16] + s0 + w[t - 7] + s1) & 0xFFFFFFFF)
    return w


# K[t] + W[t] folded into one constant per round of the padding block
_KW = tuple((int(k) + w) & 0xFFFFFFFF
            for k, w in zip(_K, _pad_block_schedule()))


def _rotr(x, n):
    return (x >> jnp.uint32(n)) | (x << jnp.uint32(32 - n))


def _round(s, kw):
    a, b, c, d, e, f, g, h = s
    S1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
    ch = g ^ (e & (f ^ g))
    t1 = h + S1 + ch + kw
    S0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
    maj = (a & b) | (c & (a | b))
    return (t1 + S0 + maj, a, b, c, d + t1, e, f, g)


def _node_hash(w16):
    """SHA-256 of 64-byte messages given as 16 word rows of (n,) lanes."""
    n = w16[0].shape[0]
    init = tuple(jnp.full((n,), h, jnp.uint32) for h in _H0)
    # block 1: the message, rolling 64-entry schedule
    w = list(w16)
    s = init
    for t in range(64):
        if t >= 16:
            s0 = _rotr(w[t - 15], 7) ^ _rotr(w[t - 15], 18) \
                ^ (w[t - 15] >> jnp.uint32(3))
            s1 = _rotr(w[t - 2], 17) ^ _rotr(w[t - 2], 19) \
                ^ (w[t - 2] >> jnp.uint32(10))
            w.append(w[t - 16] + s0 + w[t - 7] + s1)
        s = _round(s, w[t] + jnp.uint32(int(_K[t])))
    mid = tuple(x + y for x, y in zip(init, s))
    # block 2: constant padding, precomputed K+W schedule
    s = mid
    for t in range(64):
        s = _round(s, jnp.uint32(_KW[t]))
    return tuple(x + y for x, y in zip(mid, s))


# Bounded: each entry is a fully-unrolled executable compiled per leaf
# count (static shapes are what make the dispatch fast); the bound keeps a
# workload with many distinct block sizes from accumulating executables
# forever.
@functools.lru_cache(maxsize=32)
def _tree_fn(n: int, keep_levels: bool):
    """Jitted device reduction of an (8, n) words-major digest level down
    to width <= ``_CUTOVER``.  Levels are unrolled at trace time (the tree
    shape is static).  Root path returns only the boundary level; with
    ``keep_levels`` every intermediate level comes back already odd-padded
    — exactly the rows a proof's sibling lookup indexes into — except the
    last (the host continues from it)."""

    def reduce(rows8):
        rows = [rows8[i] for i in range(8)]      # contiguous (n,) lanes
        width, levels = n, []
        while width > _CUTOVER:
            if width % 2:
                rows = [jnp.concatenate([r, r[-1:]]) for r in rows]
                width += 1
            levels.append(rows)
            pairs = [r[0::2] for r in rows] + [r[1::2] for r in rows]
            rows = list(_node_hash(pairs))
            width //= 2
        levels.append(rows)
        if not keep_levels:
            levels = levels[-1:]
        return tuple(jnp.stack(lv) for lv in levels)     # (8, m) each

    return jax.jit(reduce)


# ---------------------------------------------------------------------------
# the hybrid tree
# ---------------------------------------------------------------------------


def _host_levels(digests: List[bytes]) -> List[List[bytes]]:
    """Reference tail: hashlib over a pre-joined buffer, one level a pass."""
    levels, level = [], list(digests)
    sha = hashlib.sha256
    while len(level) > 1:
        if len(level) % 2:
            level.append(level[-1])
        levels.append(level)
        buf = b"".join(level)
        level = [sha(buf[i:i + 64]).digest() for i in range(0, len(buf), 64)]
    levels.append(level)
    return levels


def _hybrid_levels(digests: np.ndarray, *,
                   keep_levels: bool = True) -> Tuple[List[np.ndarray], str]:
    """(N, 8) leaf digests -> (padded levels as (m, 8) arrays, root hex)."""
    n = int(digests.shape[0])
    if n == 0:
        return [], hashlib.sha256(b"").hexdigest()
    device_levels: List[np.ndarray] = []
    if n > _CUTOVER:
        rows8 = jnp.asarray(
            np.ascontiguousarray(np.asarray(digests, np.uint32).T))
        out = _tree_fn(n, keep_levels)(rows8)
        device_levels = [np.asarray(lv).T for lv in out[:-1]]
        boundary = _words_to_digest_list(np.asarray(out[-1]).T)
    else:
        boundary = _words_to_digest_list(np.asarray(digests, np.uint32))
    host = _host_levels(boundary)
    levels = device_levels + [pack_digests(lv) for lv in host]
    return levels, host[-1][0].hex()


def leaf_digests_device(packed: np.ndarray | jax.Array) -> jax.Array:
    """(N, W) big-endian word leaves -> (N, 8) leaf digests on device."""
    return sha256_words(jnp.asarray(packed, jnp.uint32))


def _digests_for(leaves: Sequence[bytes]) -> np.ndarray:
    packed = pack_leaves(leaves)
    if packed is not None and len(leaves) >= _CUTOVER:
        return np.asarray(leaf_digests_device(packed))
    return pack_digests([hashlib.sha256(x).digest() for x in leaves])


def merkle_root_from_digests(digests: np.ndarray | jax.Array) -> str:
    """(N, 8) uint32 leaf-digest words -> root hex."""
    return _hybrid_levels(np.asarray(digests), keep_levels=False)[1]


# Bounded like ``_tree_fn``: one executable per per-block leaf count
# (the batch dimension is specialized inside jax.jit).  Unroll depth —
# the dominant CPU compile cost, ~tens of seconds per level on the dev
# container — matches ``_tree_fn`` exactly: levels stop at ``_CUTOVER``
# per-block width and the narrow tops finish on the host.
@functools.lru_cache(maxsize=32)
def _forest_fn(width: int):
    """Jitted reduction of a *forest*: (8, B, W) words-major digest
    levels down to per-block width <= ``_CUTOVER``, every wide level of
    every tree in one dispatch.  Pairing happens within each block's
    lanes (odd levels duplicate the block's own last node), so each of
    the B trees is reduced exactly as ``_tree_fn`` would reduce it
    alone — but the compression runs over B * w/2 lanes at once, which
    is what keeps the device busy when the segment is long."""

    def reduce(rows8):
        rows = [rows8[i] for i in range(8)]          # (B, w) each
        w = width
        while w > _CUTOVER:
            if w % 2:
                rows = [jnp.concatenate([r, r[:, -1:]], axis=1)
                        for r in rows]
                w += 1
            pairs = [r[:, 0::2].reshape(-1) for r in rows] \
                + [r[:, 1::2].reshape(-1) for r in rows]
            out = _node_hash(pairs)                  # (B * w/2,) lanes
            rows = [o.reshape(rows8.shape[1], -1) for o in out]
            w //= 2
        return jnp.stack(rows)                       # (8, B, w)

    return jax.jit(reduce)


def merkle_roots_from_digests(digests: np.ndarray | jax.Array
                              ) -> List[str]:
    """(B, N, 8) uint32 leaf-digest words -> B root hex strings.

    The batched analogue of ``merkle_root_from_digests``: B same-shaped
    trees reduced together, all wide levels in one jitted dispatch,
    then B narrow tops (<= ``_CUTOVER`` digests each) finished on the
    host.  Bit-identical per block to the single-tree reducers."""
    d = np.asarray(digests, np.uint32)
    if d.ndim != 3 or d.shape[-1] != 8:
        raise ValueError(f"expected (B, N, 8) digest words, got {d.shape}")
    B, n, _ = d.shape
    if B == 0:
        return []
    if n == 0:
        return [hashlib.sha256(b"").hexdigest()] * B
    if n > _CUTOVER:
        rows8 = jnp.asarray(np.ascontiguousarray(d.transpose(2, 0, 1)))
        d = np.asarray(_forest_fn(n)(rows8)).transpose(1, 2, 0)
    return [_host_levels(_words_to_digest_list(d[b]))[-1][0].hex()
            for b in range(B)]


def merkle_root_device(leaves: Sequence[bytes]) -> str:
    """Device analogue of ``core.ledger.merkle_root`` — bit-identical."""
    if not leaves:
        return hashlib.sha256(b"").hexdigest()
    return merkle_root_from_digests(_digests_for(leaves))


def merkle_levels_device(leaves: Sequence[bytes]) -> List[np.ndarray]:
    """All (odd-padded) tree levels, leaf digests first, root level last."""
    return _hybrid_levels(_digests_for(leaves))[0]


def merkle_proof_device(leaves: Sequence[bytes], index: int) -> List[dict]:
    """Inclusion proof in the ``core.ledger`` format, tree built on device.

    Raises ``IndexError`` for an index outside the leaf set — a proof
    over a duplicated odd-level pad node would verify against the root
    without corresponding to any submitted result."""
    if not 0 <= index < len(leaves):
        raise IndexError(
            f"proof index {index} out of range for {len(leaves)} leaves")
    levels = merkle_levels_device(leaves)
    proof = []
    idx = index
    for level in levels[:-1]:
        sib = idx ^ 1
        proof.append({"side": "left" if sib < idx else "right",
                      "hash": words_to_hex(level[sib])})
        idx //= 2
    return proof
