"""Jitted public wrappers around the Pallas kernels, with batch padding,
sequence chunking, and an automatic jnp fallback.

``interpret`` defaults to True on CPU (this container) and False on real
TPU; the pure-jnp reference path (``backend="jnp"``) is what the model
forward uses by default so the 512-device dry-run lowers to plain HLO
(DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decay_scan import TILE_C, decay_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sha256 import TILE_N, sha256_pallas
from repro.kernels.wkv6 import wkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# sha256
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend",))
def sha256_words(msg: jax.Array, backend: str = "jnp") -> jax.Array:
    """msg: uint32 (N, W) -> (N, 8) digests.  backend: "jnp" | "pallas"."""
    if backend == "jnp":
        return _ref.sha256_words_ref(msg)
    padded = _ref.sha256_pad_words(msg)
    N = padded.shape[0]
    pad_n = (-N) % TILE_N
    if pad_n:
        padded = jnp.concatenate(
            [padded, jnp.zeros((pad_n, padded.shape[1]), jnp.uint32)], axis=0)
    out = sha256_pallas(padded, interpret=not _on_tpu())
    return out[:N]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "backend", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, backend: str = "jnp",
                    bq: int = 512, bk: int = 512) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, T, Kv, hd) -> (B, S, H, hd).

    GQA: kv heads are broadcast to H inside the fold.  backend "jnp"
    delegates to the query-chunked model reference."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    if backend == "jnp":
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal)
    G = H // Kv
    kx = jnp.repeat(k, G, axis=2) if G > 1 else k
    vx = jnp.repeat(v, G, axis=2) if G > 1 else v
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)
    out = flash_attention_pallas(fold(q), fold(kx), fold(vx),
                                 causal=causal, bq=bq, bk=bk,
                                 interpret=not _on_tpu())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# decay scan
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "seq_chunk"))
def decay_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None,
               backend: str = "jnp", seq_chunk: int = 2048
               ) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + b_t.  a, b: (B, S, C).  Returns (h, h_last)."""
    B, S, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), a.dtype)
    if backend == "jnp":
        h = _ref.decay_scan_ref(a, b, h0)
        return h, h[:, -1]
    pad_c = (-C) % TILE_C
    if pad_c:
        z = lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad_c)])
        a, b, h0 = z(a), z(b), z(h0)
    outs = []
    h = h0
    for s0 in range(0, S, seq_chunk):
        sl = slice(s0, min(s0 + seq_chunk, S))
        o, h = decay_scan_pallas(a[:, sl], b[:, sl], h,
                                 interpret=not _on_tpu())
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)[..., :C]
    return out, h[..., :C]


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "seq_chunk"))
def wkv6(r, k, v, w, u, s0=None, backend: str = "jnp",
         seq_chunk: int = 1024):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); s0: (B,H,K,V).
    Returns (out (B,S,H,V) f32, s_final f32)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)
    if backend == "jnp":
        return _ref.wkv6_ref(r, k, v, w, u, s0)
    fold = lambda x: x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        B * H, S, x.shape[-1])
    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u.astype(jnp.float32), (B, H, K)).reshape(B * H, K)
    sf = s0.astype(jnp.float32).reshape(B * H, K, V)
    outs = []
    for c0 in range(0, S, seq_chunk):
        sl = slice(c0, min(c0 + seq_chunk, S))
        o, sf = wkv6_pallas(rf[:, sl], kf[:, sl], vf[:, sl], wf[:, sl],
                            uf, sf, interpret=not _on_tpu())
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)
    out = out.reshape(B, H, S, V).transpose(0, 2, 1, 3)
    return out, sf.reshape(B, H, K, V)
