"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

- ``sha256_words_ref``: batched SHA-256 over fixed-width uint32-word
  messages; bit-exact vs hashlib (cross-checked in tests).
- ``decay_scan_ref``: h_t = a_t * h_{t-1} + b_t (RG-LRU inner scan).
- ``wkv6_ref``: RWKV-6 recurrence (o_t = r(S + (u*k)v^T); S' = wS + kv^T).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# SHA-256
# ---------------------------------------------------------------------------

_K = np.array([
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2], dtype=np.uint32)

_H0 = np.array([
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19], dtype=np.uint32)


def _rotr(x, n):
    return (x >> np.uint32(n)) | (x << np.uint32(32 - n))


def sha256_pad_words(msg: jax.Array) -> jax.Array:
    """msg: uint32 (N, W) -> padded blocks (N, nb*16) per FIPS 180-4.

    The message is the big-endian serialization of the W words."""
    N, W = msg.shape
    bit_len = W * 32
    nb = (bit_len + 1 + 64 + 511) // 512
    total = nb * 16
    pad = jnp.zeros((N, total - W), jnp.uint32)
    pad = pad.at[:, 0].set(jnp.uint32(0x80000000))
    pad = pad.at[:, -1].set(jnp.uint32(bit_len & 0xFFFFFFFF))
    pad = pad.at[:, -2].set(jnp.uint32(bit_len >> 32))
    return jnp.concatenate([msg.astype(jnp.uint32), pad], axis=1)


def sha256_compress(state: jax.Array, block: jax.Array) -> jax.Array:
    """state: (N, 8) uint32; block: (N, 16) uint32 -> (N, 8)."""
    w_init = block.transpose(1, 0)                       # (16, N)

    def schedule_step(t, w):
        # w: (64, N) with first 16 filled; fill w[t]
        s0 = _rotr(w[t - 15], 7) ^ _rotr(w[t - 15], 18) ^ (w[t - 15] >> 3)
        s1 = _rotr(w[t - 2], 17) ^ _rotr(w[t - 2], 19) ^ (w[t - 2] >> 10)
        return w.at[t].set(w[t - 16] + s0 + w[t - 7] + s1)

    N = block.shape[0]
    w = jnp.zeros((64, N), jnp.uint32).at[:16].set(w_init)
    w = jax.lax.fori_loop(16, 64, schedule_step, w)

    def round_step(t, s):
        a, b, c, d, e, f, g, h = s
        S1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
        ch = (e & f) ^ (~e & g)
        t1 = h + S1 + ch + jnp.asarray(_K)[t] + w[t]
        S0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
        maj = (a & b) ^ (a & c) ^ (b & c)
        t2 = S0 + maj
        return (t1 + t2, a, b, c, d + t1, e, f, g)

    s = tuple(state[:, i] for i in range(8))
    s = jax.lax.fori_loop(0, 64, lambda t, s: round_step(t, s), s)
    out = jnp.stack([state[:, i] + s[i] for i in range(8)], axis=1)
    return out


def sha256_words_ref(msg: jax.Array) -> jax.Array:
    """msg: uint32 (N, W) -> digest uint32 (N, 8)."""
    padded = sha256_pad_words(msg)
    N = msg.shape[0]
    nb = padded.shape[1] // 16
    state = jnp.broadcast_to(jnp.asarray(_H0), (N, 8))
    for b in range(nb):
        state = sha256_compress(state, padded[:, b * 16:(b + 1) * 16])
    return state


def sha256_words_hashlib(msg: np.ndarray) -> np.ndarray:
    """Ground-truth oracle via hashlib (numpy, non-jitted)."""
    import hashlib
    out = np.zeros((msg.shape[0], 8), np.uint32)
    for i, row in enumerate(np.asarray(msg, np.uint32)):
        data = b"".join(int(wd).to_bytes(4, "big") for wd in row)
        dig = hashlib.sha256(data).digest()
        out[i] = np.frombuffer(dig, ">u4").astype(np.uint32)
    return out


# ---------------------------------------------------------------------------
# decay scan (RG-LRU inner recurrence)
# ---------------------------------------------------------------------------


def decay_scan_ref(a: jax.Array, b: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t.  a, b: (B, S, C); h0: (B, C)."""
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    if h0 is not None:
        b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)


# ---------------------------------------------------------------------------
# RWKV-6 wkv
# ---------------------------------------------------------------------------


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); s0: (B,H,K,V).
    Returns (out (B,S,H,V) float32, s_final (B,H,K,V) float32)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        return wt[..., None] * s + kv, ot

    xs = tuple(x.astype(jnp.float32).transpose(1, 0, 2, 3)
               for x in (r, k, v, w))
    sT, out = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return out.transpose(1, 0, 2, 3), sT
