"""Pallas TPU kernel: batched SHA-256 compression.

PNPCoin keeps SHA-256 in two places — "Classic" back-compat blocks (§3.4)
and the full-mode result hashing ("concatenated plain results with hashed
results", §3) — so batched hashing is the one compute hot-spot the paper
itself names.  TPU adaptation (DESIGN.md §2): instead of an ASIC pipeline,
we lane-parallelize — each of the 64 rounds is a vector op over a tile of
``TILE_N`` messages resident in VMEM, so the VPU processes 8x128 lanes of
independent hashes per cycle.  The sequential 64-round dependency stays in
registers; the message schedule uses a rolling 16-word window (VMEM
footprint 16 words/message, not 64).

Grid: (N // TILE_N,).  BlockSpecs keep one (TILE_N, 16*nb) message tile
and one (TILE_N, 8) digest tile in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import _H0, _K

TILE_N = 128


def _rotr(x, n):
    return (x >> jnp.uint32(n)) | (x << jnp.uint32(32 - n))


def _sha256_kernel(k_ref, msg_ref, out_ref, *, nb: int):
    """k_ref: (64,) round constants; msg_ref: (TILE_N, nb*16) uint32."""
    K = k_ref[:]
    state = tuple(jnp.full((msg_ref.shape[0],), h, jnp.uint32) for h in _H0)

    for b in range(nb):
        block = msg_ref[:, b * 16:(b + 1) * 16]          # (T, 16)

        def round_step(t, carry):
            s, w = carry                                  # w: (T, 16) rolling
            wt = w[:, 0]
            a, bb, c, d, e, f, g, h = s
            S1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
            ch = (e & f) ^ (~e & g)
            t1 = h + S1 + ch + K[t] + wt
            S0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
            maj = (a & bb) ^ (a & c) ^ (bb & c)
            t2 = S0 + maj
            new_s = (t1 + t2, a, bb, c, d + t1, e, f, g)
            # extend the schedule: w16 = w0 + s0(w1) + w9 + s1(w14)
            s0 = _rotr(w[:, 1], 7) ^ _rotr(w[:, 1], 18) ^ (w[:, 1] >> 3)
            s1 = _rotr(w[:, 14], 17) ^ _rotr(w[:, 14], 19) ^ (w[:, 14] >> 10)
            w16 = w[:, 0] + s0 + w[:, 9] + s1
            w = jnp.concatenate([w[:, 1:], w16[:, None]], axis=1)
            return new_s, w

        s, _ = jax.lax.fori_loop(0, 64, round_step, (state, block))
        state = tuple(st + si for st, si in zip(state, s))

    out_ref[:, :] = jnp.stack(state, axis=1)


def sha256_pallas(padded: jax.Array, *,
                  interpret: bool | None = None) -> jax.Array:
    """padded: (N, nb*16) uint32 pre-padded blocks -> (N, 8) digests.

    N must be a multiple of TILE_N (ops.py pads the batch).
    ``interpret=None`` auto-detects the backend (interpreter mode off on
    real TPU, on everywhere else) — the same policy every ``ops.py``
    call site applies explicitly."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, W = padded.shape
    assert W % 16 == 0
    nb = W // 16
    assert N % TILE_N == 0, N
    kernel = functools.partial(_sha256_kernel, nb=nb)
    return pl.pallas_call(
        kernel,
        grid=(N // TILE_N,),
        in_specs=[
            pl.BlockSpec((64,), lambda i: (0,)),
            pl.BlockSpec((TILE_N, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 8), jnp.uint32),
        interpret=interpret,
    )(jnp.asarray(_K), padded)
