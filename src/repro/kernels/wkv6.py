"""Pallas TPU kernel: fused RWKV-6 WKV recurrence.

    o_t = r_t^T (S_{t-1} + (u * k_t) v_t^T);   S_t = Diag(w_t) S_{t-1} + k_t v_t^T

TPU adaptation: CUDA RWKV kernels assign one thread per (batch, head,
channel); here the matrix-valued state S (K x V) lives in a VMEM scratch
accumulator, each time step is a rank-1 update (outer product on the
VPU/MXU), and the grid iterates (B*H) with r/k/v/w streamed through VMEM
in sequence-chunks.  Fusing the whole recurrence avoids materializing
the (B, S, H, K, V) intermediate a parallel-scan formulation would need —
the HBM-traffic win that makes linear attention worthwhile on TPU.

Grid: (B*H,).  ops.py chunks the sequence and carries S across calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref):
    """r,k,w: (1, S, K); v: (1, S, V); u: (1, K); s0: (1, K, V)."""
    S = r_ref.shape[1]
    u = u_ref[0, :]                                        # (K,)

    def step(t, s):                                        # s: (K, V) f32
        rt = r_ref[0, t, :]
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        kv = kt[:, None] * vt[None, :]                     # (K, V)
        o_ref[0, t, :] = (rt[:, None] * (s + u[:, None] * kv)).sum(axis=0)
        return wt[:, None] * s + kv

    sT = jax.lax.fori_loop(0, S, step, s0_ref[0, :, :])
    sT_ref[0, :, :] = sT


def wkv6_pallas(r, k, v, w, u, s0, *, interpret: bool = True):
    """r,k,w: (BH, S, K); v: (BH, S, V); u: (BH, K); s0: (BH, K, V)
    -> (o (BH, S, V), sT (BH, K, V)), all float32."""
    BH, S, K = r.shape
    V = v.shape[-1]
    return pl.pallas_call(
        _wkv6_kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, S, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, V), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K, V), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, V), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, V), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, V), jnp.float32),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, s0)
