import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — hence no `from __future__` in this module.

_DOC = """Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape), lower + compile the step function
on the production mesh with ShapeDtypeStruct inputs (no allocation), then
emit:
  - memory_analysis()   (proves the sharded program fits)
  - cost_analysis()     (HLO FLOPs / bytes for the roofline)
  - collective bytes    (parsed from the compiled HLO: all-gather /
                         all-reduce / reduce-scatter / all-to-all /
                         collective-permute operand+output sizes)

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.core.compat import cost_analysis_dict
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.model import (adapt_for_shape, cache_len_for, input_specs,
                                supports_shape)
from repro.sharding.partition import (batch_specs, cache_specs, param_specs,
                                      use_rules)
from repro.train.steps import (TrainHparams, make_decode_step,
                               make_prefill_step, make_train_step)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output sizes of every collective op in the (SPMD, per-device)
    compiled HLO.  Returns bytes per collective kind."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(?:-start|-done)?\(", rhs) or \
                    re.search(rf"= {k}", ls):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done" in rhs:
            continue                      # counted at -start
        # output shape(s) appear before the op name on the rhs
        head = rhs.split("(")[0]
        nbytes = 0
        for dt, dims in shape_re.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    out["_counts"] = counts               # type: ignore[assignment]
    return out


def build_step(cfg, shape):
    """Returns (step_fn, example_args (SDS pytrees), in_shardings builder,
    donate)."""
    acfg = adapt_for_shape(cfg, shape)
    if shape.kind == "train":
        from repro.train.steps import make_train_state
        step = make_train_step(acfg)
        state_sds = jax.eval_shape(
            lambda: make_train_state(acfg, jax.random.key(0)))
        batch_sds = input_specs(acfg, shape)
        return step, (state_sds, batch_sds), "train"
    model_cache_sds = None
    from repro.models.model import build_model
    model = build_model(acfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch,
                                 cache_len_for(acfg, shape)))
    batch_sds = input_specs(acfg, shape)
    if shape.kind == "prefill":
        step = make_prefill_step(acfg, shape)
    else:
        step = make_decode_step(acfg, shape)
    return step, (params_sds, batch_sds, cache_sds), shape.kind


def shardings_for(kind, args_sds, mesh, shape, cfg=None):
    B = shape.global_batch
    fsdp = cfg.fsdp if cfg is not None else True
    eax = cfg.expert_axis if cfg is not None else "model"
    fpod = cfg.fsdp_pod if cfg is not None else False
    ps = lambda tree: param_specs(tree, mesh, fsdp=fsdp, expert_axis=eax,
                                  fsdp_pod=fpod)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    if kind == "train":
        state_sds, batch_sds = args_sds
        state_spec = jax.tree.map(
            lambda _: None, state_sds,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # params + opt m/v share param specs; step scalar replicated
        pspec = ps(state_sds.params)
        mspec = ps(state_sds.opt.m)
        vspec = ps(state_sds.opt.v)
        state_spec = type(state_sds)(params=pspec, opt=type(state_sds.opt)(
            step=P(), m=mspec, v=vspec))
        bspec = batch_specs(batch_sds, mesh, B)
        in_sh = (ns(state_spec), ns(bspec))
        out_sh = (ns(state_spec), None)
        donate = (0,)
    else:
        params_sds, batch_sds, cache_sds = args_sds
        pspec = ps(params_sds)
        bspec = batch_specs(batch_sds, mesh, B)
        cspec = cache_specs(cache_sds, mesh, B)
        in_sh = (ns(pspec), ns(bspec), ns(cspec))
        if kind == "prefill":
            out_sh = (None, ns(cspec))
            donate = (2,)
        else:
            out_sh = (None, None, ns(cspec))
            donate = (2,)
    return in_sh, out_sh, donate


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp: bool = True, verbose: bool = True,
               unroll: bool = True, overrides: Dict[str, Any] | None = None
               ) -> Dict[str, Any]:
    """Two-tier dry-run (DESIGN.md §5):

    A. scanned SPMD lower+compile on the production mesh — proves the
       sharding lowers, gives memory_analysis and the compiled HLO whose
       collectives we count with loop-trip multipliers;
    B. unrolled single-device lowering + lowered.cost_analysis — faithful
       HLO FLOPs/bytes (scan bodies would be counted once), divided by
       n_chips.  (Measured vs a full unrolled SPMD compile: flops within
       2%, bytes within 9%, at ~40x less compile time.)
    """
    import dataclasses as _dc
    from repro.launch.hlo_analysis import collective_bytes as hlo_coll
    cfg = get_config(arch)
    if not fsdp:
        cfg = _dc.replace(cfg, fsdp=False)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    # --- tier A: scanned SPMD compile -------------------------------------
    t0 = time.time()
    step, args_sds, kind = build_step(cfg, shape)
    in_sh, out_sh, donate = shardings_for(kind, args_sds, mesh, shape, cfg)

    with use_rules(mesh, {"expert": cfg.expert_axis}):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_coll(hlo)
    coll_bytes = float(coll["_total_bytes"])

    # --- tier B: unrolled single-device cost analysis ----------------------
    if unroll:
        from repro.models.attention import unroll_chunks_for_analysis
        ucfg = _dc.replace(cfg, scan_layers=False)
        ustep, uargs, _ = build_step(ucfg, shape)
        with unroll_chunks_for_analysis():
            ulowered = jax.jit(ustep).lower(*uargs)
        ucost = cost_analysis_dict(ulowered.cost_analysis())
        flops = float(ucost.get("flops", 0.0)) / n_chips
        bytes_accessed = float(ucost.get("bytes accessed", 0.0)) / n_chips
    else:
        cost = cost_analysis_dict(compiled.cost_analysis())
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))

    # roofline terms (per-device HLO -> seconds)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll_bytes / ICI_BW

    # model flops: 6·N·D (dense) / 6·N_active·D (moe); decode D=1 token.
    # enc-dec: the encoder's params see B*n_enc_tokens, not B*seq.
    n_params = cfg.param_count(active_only=True)
    factor = 6 if kind == "train" else 2
    B = shape.global_batch
    dec_tokens = B * shape.seq_len if kind != "decode" else B
    if cfg.family == "encdec":
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
        enc_per = (cfg.d_model * cfg.n_heads * hd * 2 +
                   2 * d * cfg.n_kv_heads * hd) + 3 * d * f
        n_enc = cfg.n_enc_layers * enc_per
        enc_tokens = B * cfg.n_enc_tokens if kind != "decode" else 0
        model_flops = factor * ((n_params - n_enc) * dec_tokens +
                                n_enc * enc_tokens)
    else:
        model_flops = factor * n_params * dec_tokens

    result = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": dict(mesh.shape), "n_chips": n_chips,
        "skipped": False,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_collective,
            "dominant": max(
                [("compute", t_compute), ("memory", t_memory),
                 ("collective", t_collective)], key=lambda kv: kv[1])[0],
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / flops if flops else 0.0,
    }
    if verbose:
        r = result["roofline"]
        print(f"[dryrun] {arch} x {shape_name} mesh={tuple(mesh.shape.values())} "
              f"compile={t_compile:.1f}s flops/dev={flops:.3g} "
              f"bytes/dev={bytes_accessed:.3g} coll/dev={coll_bytes:.3g} "
              f"dominant={r['dominant']} useful={result['useful_flops_ratio']:.2f}",
              flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="keep scan-over-layers (fast compile; roofline "
                         "undercounts depth — use for the multi-pod "
                         "coherence pass)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value (hillclimb lever), "
                         "e.g. --set constrain_kv=true --set fsdp=false")
    ap.add_argument("--suffix", default="",
                    help="output filename suffix for perf experiments")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        elif v.lstrip("-").isdigit():
            overrides[k] = int(v)
        else:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for a in list_configs():
            if a == "pnpcoin-demo":
                continue
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    failures = []
    for arch, shape in combos:
        tag = ("multi" if args.multi_pod else "single") + args.suffix
        path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
        try:
            res = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             fsdp=not args.no_fsdp, unroll=not args.scan,
                             overrides=overrides or None)
        except Exception as e:                       # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, str(e)[:200]))
            res = {"arch": arch, "shape": shape, "error": str(e)[:2000]}
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=str)
    if failures:
        print(f"FAILURES ({len(failures)}):")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        sys.exit(1)
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
