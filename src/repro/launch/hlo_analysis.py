"""Compiled-HLO analysis for the roofline.

XLA's ``cost_analysis`` counts a while-loop (scan) body ONCE, and the
compiled HLO text likewise shows each body a single time.  This module
parses the per-device SPMD HLO into its computation graph, reads each
while op's ``known_trip_count`` backend config, and attributes every
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) with the product of enclosing loop trip counts — so a
gradient all-reduce inside a scanned layer stack is counted n_layers
times, as it executes.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class Computation:
    def __init__(self, name: str, is_entry: bool):
        self.name = name
        self.is_entry = is_entry
        self.collectives: List[Tuple[str, int]] = []   # (kind, out_bytes)
        self.whiles: List[Tuple[str, int]] = []        # (body_name, trips)
        self.calls: List[str] = []


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation header: `[ENTRY] %name (params...) -> result {`
        if line.endswith("{") and "->" in line and "=" not in \
                line.split("->")[0].split("(")[0]:
            tok = line.lstrip()
            is_entry = tok.startswith("ENTRY")
            if is_entry:
                tok = tok[len("ENTRY"):].lstrip()
            name = tok.split()[0].split("(")[0].lstrip("%")
            cur = Computation(name, is_entry)
            comps[name] = cur
            continue
        if cur is None or not s or s == "}":
            if s == "}":
                cur = None
            continue
        # while ops (check before collectives; a while line can mention
        # anything in metadata)
        if re.search(r"=.*\bwhile\(", s) and "body=" in s:
            bm = re.search(r"body=%?([\w.\-]+)", s)
            tm = _TRIP_RE.search(s)
            trips = int(tm.group(1)) if tm else 1
            if bm:
                cur.whiles.append((bm.group(1), trips))
            continue
        # conditionals / fusions / calls
        br = _BRANCHES.search(s)
        if br:
            for nm in br.group(1).split(","):
                cur.calls.append(nm.strip().lstrip("%"))
        for nm in _CALLED.findall(s):
            cur.calls.append(nm)
        # collectives (count -start, skip -done)
        for kind in COLLECTIVES:
            if f"{kind}-done" in s:
                break
            if re.search(rf"\b{re.escape(kind)}(?:-start)?\(", s):
                head = s.split("=", 1)[1] if "=" in s else s
                head = re.split(rf"\b{re.escape(kind)}", head)[0]
                cur.collectives.append((kind, _shape_bytes(head)))
                break
    return comps


def collective_bytes(hlo: str) -> Dict:
    """{kind: {"bytes": float, "count": int}} with loop-trip multipliers,
    plus "_total_bytes"."""
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = list(comps.values())[0]

    out: Dict = {k: {"bytes": 0.0, "count": 0} for k in COLLECTIVES}

    def walk(comp: Computation, mult: float, depth: int = 0) -> None:
        if depth > 16:
            return
        for kind, nbytes in comp.collectives:
            out[kind]["bytes"] += nbytes * mult
            out[kind]["count"] += 1
        for body_name, trips in comp.whiles:
            body = comps.get(body_name)
            if body:
                walk(body, mult * trips, depth + 1)
        for name in comp.calls:
            sub = comps.get(name)
            if sub:
                walk(sub, mult, depth + 1)

    if entry is not None:
        walk(entry, 1.0)
    out["_total_bytes"] = sum(
        v["bytes"] for v in out.values() if isinstance(v, dict))
    return out
