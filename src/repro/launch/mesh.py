"""Production mesh definitions (TPU v5e).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the "pod"
axis carries only data parallelism (gradient all-reduce over DCI).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (1 CPU device in the container) as a flat
    miner mesh — used by CPU examples and tests."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants for the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
