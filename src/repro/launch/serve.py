"""Serving driver: batched prefill + decode with the KV/state cache.

Demonstrates the inference side of the framework on CPU with a reduced
config; the production shapes are exercised via the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced 1 \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, get_config, reduced
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model, cache_len_for
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    total = args.prompt_len + args.new_tokens
    shape = InputShape("serve", total, args.batch, "decode")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    pipe = SyntheticTokenPipeline(
        cfg, InputShape("p", args.prompt_len, args.batch, "prefill"))
    batch = pipe.batch(0)

    prefill = jax.jit(make_prefill_step(cfg, shape))
    decode = jax.jit(make_decode_step(cfg, shape))
    cache = model.init_cache(args.batch, cache_len_for(cfg, shape))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1
                     ).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, _, cache = decode(params, {"tokens": tok}, cache)
        tok = tok[:, None]
        out_tokens.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   "
          f"decode: {t_decode / max(args.new_tokens - 1, 1) * 1e3:.2f} ms/tok")
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
