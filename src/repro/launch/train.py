"""End-to-end PoUW training driver (deliverable (b) driver).

Runs the PNPCoin block chain with a training-step payload: every block is
one (or ``--microsteps``) train step(s), the state digest is chained into
the ledger, miners are credited, and periodic checkpoint blocks write a
full ``.npz`` whose SHA-256 digest anchors the chain.

CPU-sized by default (pnpcoin-demo, ~2M params); any assigned arch can
be selected with ``--arch`` (use reduced=1 to smoke-test a family).

  PYTHONPATH=src python -m repro.launch.train --blocks 200 --mode full
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.base import INPUT_SHAPES, InputShape, get_config, reduced
from repro.core.pow_train import PoUWTrainer
from repro.train.checkpoint import save_checkpoint
from repro.train.steps import TrainHparams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pnpcoin-demo")
    ap.add_argument("--reduced", type=int, default=0)
    ap.add_argument("--blocks", type=int, default=200)
    ap.add_argument("--mode", choices=("full", "optimal"), default="full")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microsteps", type=int, default=1)
    ap.add_argument("--miners", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--pop", type=int, default=8)
    ap.add_argument("--sigma", type=float, default=0.02)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--out", default="experiments/train")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = InputShape("cli", args.seq, args.batch, "train")
    hp = TrainHparams(peak_lr=args.lr, warmup_steps=max(args.blocks // 20, 5),
                      total_steps=args.blocks * args.microsteps)
    trainer = PoUWTrainer(cfg, shape, hp=hp, mode=args.mode,
                          n_miners=args.miners, pop_size=args.pop,
                          sigma=args.sigma,
                          block_microsteps=args.microsteps)
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    for b in range(args.blocks):
        rec = trainer.run_block()
        if b % 10 == 0 or b == args.blocks - 1:
            dt = time.time() - t0
            print(f"block {rec.height:4d} mode={rec.mode} "
                  f"loss={rec.loss:.4f} chain={rec.block_hash[:12]} "
                  f"({dt:.1f}s)", flush=True)
        if args.ckpt_every and (b + 1) % args.ckpt_every == 0:
            path = os.path.join(args.out, f"ckpt_{b + 1}.npz")
            digest = save_checkpoint(path, trainer.state,
                                     {"block": b + 1,
                                      "ledger_tip": trainer.ledger.tip_hash})
            print(f"  checkpoint {path} sha256={digest[:16]}", flush=True)

    assert trainer.ledger.verify_chain()
    with open(os.path.join(args.out, "ledger.json"), "w") as f:
        f.write(trainer.ledger.to_json())
    with open(os.path.join(args.out, "credits.json"), "w") as f:
        json.dump(trainer.book.balances, f, indent=2)
    first = trainer.history[0].loss
    last = trainer.history[-1].loss
    print(f"done: {args.blocks} blocks, loss {first:.4f} -> {last:.4f}, "
          f"credits issued {trainer.book.total_issued:.1f}, chain verified.")


if __name__ == "__main__":
    main()
