"""Attention: GQA + qk-norm + rope, query-chunked ("flash-lite") softmax so
32k-token prefill never materialises an (S, S) score matrix, sliding-window
banded variant, ring-buffer KV cache for decode, and cross-attention.

All functions are pure; caches are plain dicts of arrays so they ride
through ``jax.jit`` / ``lax.scan`` unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense_init, head_rms_norm,
                                 stacked_dense_init)
from repro.sharding.partition import constrain

NEG_INF = -1e9

# analysis mode: fully unroll the q-chunk scan so XLA cost_analysis counts
# every chunk (scan bodies are counted once) — set by launch/dryrun tier B
_UNROLL_CHUNKS = contextvars.ContextVar("unroll_chunks", default=False)


@contextlib.contextmanager
def unroll_chunks_for_analysis():
    tok = _UNROLL_CHUNKS.set(True)
    try:
        yield
    finally:
        _UNROLL_CHUNKS.reset(tok)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, qk_norm: bool = False, n_stack: int = 0) -> Dict:
    ks = jax.random.split(key, 4)
    mk = (lambda k, i, o: stacked_dense_init(k, n_stack, i, o, dtype)) if n_stack \
        else (lambda k, i, o: dense_init(k, i, o, dtype))
    p = {
        "wq": mk(ks[0], d, n_heads * head_dim),
        "wk": mk(ks[1], d, n_kv * head_dim),
        "wv": mk(ks[2], d, n_kv * head_dim),
        "wo": mk(ks[3], n_heads * head_dim, d),
    }
    if qk_norm:
        shape = (n_stack, head_dim) if n_stack else (head_dim,)
        p["q_norm"] = jnp.zeros(shape, jnp.float32)
        p["k_norm"] = jnp.zeros(shape, jnp.float32)
    return p


# ---------------------------------------------------------------------------
# core softmax attention (query-chunked)
# ---------------------------------------------------------------------------


def _gqa_scores_out(q, k, v, mask) -> jax.Array:
    """q: (B,Kv,G,Sq,hd); k/v: (B,Kv,T,hd); mask broadcastable (B,1,1,Sq,T)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bkgqh,bkth->bkgqt", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd)) + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqt,bkth->bkgqh", probs, v)


def _split_heads(x, n_kv, group, hd):
    b, s = x.shape[:2]
    return x.reshape(b, s, n_kv, group, hd).transpose(0, 2, 3, 1, 4)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: int = 0, q_offset: int = 0,
                      chunk: int = 512) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, T, Kv, hd).  Returns (B, S, H, hd).

    Scans over query chunks; with ``window > 0`` only a (window + chunk)
    band of K/V is sliced per chunk, so FLOPs and memory are O(S·window)
    instead of O(S²)."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    chunk = min(chunk, S)
    while S % chunk:            # non-power-of-two S (whisper's 1500 frames)
        chunk -= 1
    n_chunks = S // chunk

    kt = k.transpose(0, 2, 1, 3)                      # (B,Kv,T,hd)
    vt = v.transpose(0, 2, 1, 3)
    qs = _split_heads(q, Kv, G, hd)                   # (B,Kv,G,S,hd)
    qs = qs.reshape(B, Kv, G, n_chunks, chunk, hd).transpose(3, 0, 1, 2, 4, 5)

    banded = window > 0 and T > window + chunk
    if banded:
        band = window + chunk
        pad = jnp.zeros(kt.shape[:2] + (window,) + kt.shape[3:], kt.dtype)
        kp = jnp.concatenate([pad, kt], axis=2)        # (B,Kv,window+T,hd)
        vp = jnp.concatenate([pad, vt], axis=2)

    kv_pos = jnp.arange(T)

    def body(carry, xs):
        i, qc = xs                                     # qc: (B,Kv,G,chunk,hd)
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        if banded:
            start = i * chunk                          # band covers [i*chunk-window, ...)
            kc = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=2)
            abs_kv = start - window + jnp.arange(band)
            mask = (abs_kv[None, :] >= 0)
            mask &= (abs_kv[None, :] > q_pos[:, None] - window)
            if causal:
                mask &= (abs_kv[None, :] <= q_pos[:, None])
            out = _gqa_scores_out(qc, kc, vc,
                                  jnp.where(mask, 0.0, NEG_INF)[None, None, None])
        else:
            mask = jnp.ones((chunk, T), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            out = _gqa_scores_out(qc, kt, vt,
                                  jnp.where(mask, 0.0, NEG_INF)[None, None, None])
        return carry, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs),
                           unroll=True if _UNROLL_CHUNKS.get() else 1)
    # outs: (n_chunks, B, Kv, G, chunk, hd) -> (B, S, H, hd)
    outs = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Kv, G, S, hd)
    return outs.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# full attention layer (train / prefill)
# ---------------------------------------------------------------------------


def _project_qkv(p, x, n_heads, n_kv, hd, qk_norm, constrain_kv=False):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, n_kv, hd)
    v = (x @ p["wv"]).reshape(B, S, n_kv, hd)
    if constrain_kv:
        # stop GSPMD splitting head_dim of k/v (which turns the score
        # contraction into a huge all-reduce): shard heads when divisible,
        # else force replication of the head dims (EXPERIMENTS.md §Perf)
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "heads", None)
        v = constrain(v, "batch", None, "heads", None)
    if qk_norm:
        q = head_rms_norm(q, p["q_norm"])
        k = head_rms_norm(k, p["k_norm"])
    return q, k, v


def self_attention(p: Dict, x: jax.Array, *, n_heads: int, n_kv: int,
                   head_dim: int, rope_theta: float, causal: bool = True,
                   window: int = 0, qk_norm: bool = False,
                   constrain_kv: bool = False,
                   positions: Optional[jax.Array] = None) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm,
                           constrain_kv)
    if positions is None:
        positions = jnp.arange(S)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ p["wo"]


def prefill_self_attention(p: Dict, x: jax.Array, cache: Dict, *,
                           n_heads: int, n_kv: int, head_dim: int,
                           rope_theta: float, window: int = 0,
                           qk_norm: bool = False,
                           constrain_kv: bool = False
                           ) -> Tuple[jax.Array, Dict]:
    """Prefill: run full causal attention AND populate the (ring) cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm,
                           constrain_kv)
    positions = jnp.arange(S)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, n_heads * head_dim) @ p["wo"]

    C = cache["k"].shape[1]
    if C >= S:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], positions.astype(jnp.int32), 0, axis=0)
    else:
        # ring cache smaller than the prompt: keep the last C tokens,
        # rolled so that slot = pos % C (matches decode's ring update).
        last_k, last_v = k[:, S - C:], v[:, S - C:]
        shift = S % C
        new_k = jnp.roll(last_k, shift, axis=1)
        new_v = jnp.roll(last_v, shift, axis=1)
        slot_pos = jnp.roll(jnp.arange(S - C, S, dtype=jnp.int32), shift)
    return out, {"k": new_k, "v": new_v, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# decode (single token, ring cache)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int,
                  dtype) -> Dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def decode_self_attention(p: Dict, x: jax.Array, cache: Dict, pos: jax.Array,
                          *, n_heads: int, n_kv: int, head_dim: int,
                          rope_theta: float, qk_norm: bool = False,
                          constrain_kv: bool = False
                          ) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d); pos: scalar int32 = number of tokens already seen.
    The cache is a ring buffer of length C (== window for sliding-window
    archs, == max_seq for full attention)."""
    B = x.shape[0]
    C = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, qk_norm,
                           constrain_kv)
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, posv, rope_theta)
    k = apply_rope(k, posv, rope_theta)

    slot = jnp.mod(pos, C)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], posv, slot, axis=0)

    G = n_heads // n_kv
    qs = q.reshape(B, 1, n_kv, G, head_dim).transpose(0, 2, 3, 1, 4)
    kt = new_k.transpose(0, 2, 1, 3)
    vt = new_v.transpose(0, 2, 1, 3)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    out = _gqa_scores_out(qs, kt, vt, mask)           # (B,Kv,G,1,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"], {"k": new_k, "v": new_v, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder / VLM image layers)
# ---------------------------------------------------------------------------


def cross_kv(p: Dict, src: jax.Array, n_kv: int, head_dim: int) -> Dict:
    B, T, _ = src.shape
    return {
        "k": (src @ p["wk"]).reshape(B, T, n_kv, head_dim),
        "v": (src @ p["wv"]).reshape(B, T, n_kv, head_dim),
    }


def cross_attention(p: Dict, x: jax.Array, kv: Dict, *, n_heads: int,
                    n_kv: int, head_dim: int) -> jax.Array:
    """x: (B, S, d) queries; kv precomputed from the source sequence."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    out = chunked_attention(q, kv["k"], kv["v"], causal=False)
    return out.reshape(B, S, n_heads * head_dim) @ p["wo"]
