"""Whisper-style encoder-decoder.

The mel-spectrogram + conv frontend is a STUB per the brief: the batch
carries precomputed frame embeddings ``audio_frames`` of shape
(B, n_enc_tokens, d_model).  This module implements the transformer:
bidirectional encoder, causal decoder with per-layer cross-attention,
sinusoidal positions (whisper uses no rope).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (init_mlp, mlp, rms_norm, sinusoidal_pos,
                                 sinusoidal_pos_at)
from repro.models.transformer import (Model, _dt, _init_attn_layer, _zeros,
                                      maybe_scan)

Params = Dict[str, Any]


class EncDecModel(Model):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)

    # -- init -----------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 6)
        p = super().init(keys[0])                     # embed, ln_f, decoder self stack
        p["enc"] = {
            "layers": _init_attn_layer(keys[1], cfg, cfg.n_enc_layers),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        # per-decoder-layer cross attention (stacked over decoder layers)
        hd = cfg.resolved_head_dim
        p["cross"] = {
            "ln": _zeros((cfg.d_model,), cfg.n_layers),
            "attn": attn.init_attention(keys[2], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, hd, _dt(cfg), False,
                                        cfg.n_layers),
        }
        return p

    # -- encoder ----------------------------------------------------------------
    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(_dt(cfg))
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]

        def body(x, lp):
            h = rms_norm(x, lp["ln1"])
            out = attn.self_attention(
                lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=0.0, causal=False)
            x = x + out
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
            return x, None

        x, _ = maybe_scan(body, x, p["enc"]["layers"],
                          scan=cfg.scan_layers, n=cfg.n_enc_layers,
                          remat=cfg.remat)
        return rms_norm(x, p["enc"]["ln_f"])

    # -- decoder (train, teacher-forced) ------------------------------------------
    def forward(self, p: Params, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc_out = self.encode(p, batch["audio_frames"])
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        hd = cfg.resolved_head_dim

        def body(x, xs):
            lp, cp = xs
            h = rms_norm(x, lp["ln1"])
            out = attn.self_attention(
                lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=hd, rope_theta=0.0, causal=True)
            x = x + out
            kv = attn.cross_kv(cp["attn"], enc_out, cfg.n_kv_heads, hd)
            x = x + attn.cross_attention(cp["attn"], rms_norm(x, cp["ln"]), kv,
                                         n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads, head_dim=hd)
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
            return x, None

        x, _ = maybe_scan(body, x, (p["groups"]["l0"],
                                    {"ln": p["cross"]["ln"],
                                     "attn": p["cross"]["attn"]}),
                          scan=cfg.scan_layers, n=cfg.n_layers,
                          remat=cfg.remat)
        return self._head(p, x), jnp.float32(0.0)

    # -- cache ---------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> Dict:
        cfg = self.cfg
        cache = super().init_cache(batch, cache_len)
        hd = cfg.resolved_head_dim
        cache["cross_kv"] = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.n_enc_tokens,
                            cfg.n_kv_heads, hd), _dt(cfg)),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.n_enc_tokens,
                            cfg.n_kv_heads, hd), _dt(cfg)),
        }
        return cache

    # -- stateful decoder pass -------------------------------------------------------
    def _dec_stateful(self, p, x, cache, mode, pos):
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def body(x, xs):
            lp, cp, sc, ckv = xs
            h = rms_norm(x, lp["ln1"])
            if mode == "prefill":
                out, nc = attn.prefill_self_attention(
                    lp["attn"], h, sc, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=hd, rope_theta=0.0)
            else:
                out, nc = attn.decode_self_attention(
                    lp["attn"], h, sc, pos, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=hd, rope_theta=0.0)
            x = x + out
            x = x + attn.cross_attention(cp["attn"], rms_norm(x, cp["ln"]), ckv,
                                         n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads, head_dim=hd)
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
            return x, (nc, ckv)

        xs = (p["groups"]["l0"],
              {"ln": p["cross"]["ln"], "attn": p["cross"]["attn"]},
              cache["groups"]["l0"], cache["cross_kv"])
        x, (new_self, new_ckv) = maybe_scan(body, x, xs,
                                            scan=cfg.scan_layers,
                                            n=cfg.n_layers)
        return x, new_self, new_ckv

    def prefill(self, p: Params, batch: Dict, cache: Dict):
        cfg = self.cfg
        enc_out = self.encode(p, batch["audio_frames"])
        hd = cfg.resolved_head_dim

        def make_kv(cp):
            kv = attn.cross_kv(cp, enc_out, cfg.n_kv_heads, hd)
            return kv
        ckv = jax.vmap(lambda cp: make_kv(cp))(p["cross"]["attn"])

        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        cache = dict(cache)
        cache["cross_kv"] = ckv
        x, new_self, new_ckv = self._dec_stateful(p, x, cache, "prefill",
                                                  cache["pos"])
        new_cache = {"groups": {"l0": new_self}, "cross_kv": new_ckv,
                     "pos": cache["pos"] + tokens.shape[1]}
        return self._head(p, x[:, -1:]), new_cache

    def decode_step(self, p: Params, batch: Dict, cache: Dict):
        cfg = self.cfg
        token = batch["tokens"]
        x = self._embed(p, token)
        x = x + sinusoidal_pos_at(cache["pos"], cfg.d_model
                                  ).astype(x.dtype)[None, None]
        x, new_self, new_ckv = self._dec_stateful(p, x, cache, "decode",
                                                  cache["pos"])
        new_cache = {"groups": {"l0": new_self}, "cross_kv": new_ckv,
                     "pos": cache["pos"] + 1}
        return self._head(p, x), new_cache
