"""Shared neural building blocks (pure JAX, pytree params)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def compute_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMSNorm over the trailing head_dim of (..., n_heads, head_dim)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[..., None, :]                          # (1, S, 1, hd/2)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding for a (traced) scalar position -> (d,)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_pos(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# MLP (gated)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype, n_stack: int = 0) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if n_stack:
        return {
            "w1": stacked_dense_init(k1, n_stack, d, f, dtype),
            "w3": stacked_dense_init(k2, n_stack, d, f, dtype),
            "w2": stacked_dense_init(k3, n_stack, f, d, dtype),
        }
    return {
        "w1": dense_init(k1, d, f, dtype),
        "w3": dense_init(k2, d, f, dtype),
        "w2": dense_init(k3, f, d, dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    from repro.sharding.partition import constrain
    h = silu(x @ p["w1"]) * (x @ p["w3"])
    h = constrain(h, "batch", None, "tensor")
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Mean next-token CE; positions with label < 0 are masked.  Handles the
    padded-vocab case by masking logits >= vocab_size."""
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_pad != vocab_size:
        mask = jnp.arange(v_pad) < vocab_size
        logits = jnp.where(mask[None, None, :], logits, -1e9)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    nll = logz - gold
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
