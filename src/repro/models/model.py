"""build_model: config -> Model instance (family dispatch) and
``input_specs``: ShapeDtypeStruct stand-ins for every model input of an
(arch, input-shape) pair — the dry-run contract from the brief."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

# sliding-window width used when a *dense* arch runs long_500k (the brief's
# allowed sub-quadratic variant for full-attention families)
LONG_CONTEXT_WINDOW = 8192


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecModel
        return EncDecModel(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLMModel
        return VLMModel(cfg)
    from repro.models.transformer import Model
    return Model(cfg)


def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-run config adaptation: dense/vlm archs get the sliding-window
    attention variant for the 500k-token decode (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family in ("dense", "vlm") \
            and not cfg.window:
        return dataclasses.replace(cfg, window=LONG_CONTEXT_WINDOW)
    return cfg


def supports_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family == "encdec":
        return False, ("enc-dec full-attention decoder with by-design tiny "
                       "context; skip noted in DESIGN.md §4")
    return True, ""


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.window:
        return min(shape.seq_len, cfg.window)
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape,
                model=None) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step function's *data* arguments."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": tok(S), "labels": tok(S)}
    elif shape.kind == "prefill":
        batch = {"tokens": tok(S)}
    else:  # decode: one new token
        batch = {"tokens": tok(1)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_vision), dt)
    if cfg.family == "encdec":
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_enc_tokens, cfg.d_model), dt)
    if shape.kind == "decode" and cfg.family in ("vlm", "encdec"):
        # decode consumes the prefill-populated cache; the stub inputs are
        # only needed at prefill time.
        batch.pop("image_embeds", None)
        batch.pop("audio_frames", None)
    return batch


def cache_specs(cfg: ModelConfig, shape: InputShape) -> Any:
    """ShapeDtypeStructs for the decode cache (no allocation)."""
    model = build_model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len_for(cfg, shape)))
    return cache
