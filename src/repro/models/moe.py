"""Mixture-of-Experts: top-k router + capacity-based scatter/gather dispatch.

Dispatch is the TPU-idiomatic fixed-capacity permute: tokens are scattered
into an (E, C, d) buffer (E sharded over the ``model`` axis -> GSPMD
inserts the expert-parallel all-to-all), experts run as one batched
einsum, results gather back with router weights.  FLOPs are
O(T·k·d·f·capacity_factor), not O(T·E·d·f).

Supports the Arctic "dense residual" layout (dense FFN in parallel with
the MoE, summed) and emits the standard load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, silu, stacked_dense_init
from repro.sharding.partition import constrain


def init_moe(key, d: int, f: int, n_experts: int, dtype,
             n_stack: int = 0) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if n_stack:
        shape_r = (n_stack, d, n_experts)
        mk = lambda k, i, o: stacked_dense_init(k, n_stack * n_experts, i, o, dtype)\
            .reshape(n_stack, n_experts, i, o)
    else:
        shape_r = (d, n_experts)
        mk = lambda k, i, o: stacked_dense_init(k, n_experts, i, o, dtype)
    return {
        "router": (jax.random.normal(k1, shape_r, jnp.float32) * 0.02).astype(jnp.float32),
        "experts": {
            "w1": mk(k2, d, f),
            "w3": mk(k3, d, f),
            "w2": mk(k4, f, d),
        },
    }


def moe_ffn(p: Dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Fixed-capacity dropless-ish dispatch: capacity C = ceil(T·k/E · cf);
    overflowing tokens are dropped (their combine weight contributes 0).
    """
    B, S, d = x.shape
    E = p["router"].shape[-1]
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)               # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                    # (E,)
    onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)      # (T, k, E)
    ce = onehot.sum(axis=1).mean(axis=0)                       # fraction per expert
    aux = E * jnp.sum(me * ce)

    C = max(1, math.ceil(T * top_k / E * capacity_factor))

    # position of each (token, choice) within its expert's capacity
    # buffer, by stable sort-based ranking.  (The obvious one-hot+cumsum
    # lowers to an O((T*k)^2 * E) reduce-window — measured 15x the expert
    # matmul FLOPs at olmoe train_4k; see EXPERIMENTS.md §Perf P4.)
    flat_i = gate_i.reshape(-1)                                # (T*k,)
    Tk = flat_i.shape[0]
    order = jnp.argsort(flat_i, stable=True)
    sorted_e = flat_i[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))         # (E,)
    ranks_sorted = jnp.arange(Tk) - starts[sorted_e]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    keep = pos < C
    slot = jnp.where(keep, flat_i * C + pos, E * C)            # overflow -> dummy row

    # scatter tokens into (E*C+1, d)
    xk = jnp.repeat(xt, top_k, axis=0)                         # (T*k, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xk)
    buf = buf[: E * C].reshape(E, C, d)
    buf = constrain(buf, "expert", None, None)

    # expert compute (batched over E)
    w = p["experts"]
    h = silu(jnp.einsum("ecd,edf->ecf", buf, w["w1"])) * \
        jnp.einsum("ecd,edf->ecf", buf, w["w3"])
    h = constrain(h, "expert", None, "tensor")
    eout = jnp.einsum("ecf,efd->ecd", h, w["w2"])              # (E, C, d)
    eout = constrain(eout, "expert", None, None)

    # gather back + weighted combine
    flat_out = jnp.concatenate(
        [eout.reshape(E * C, d), jnp.zeros((1, d), eout.dtype)], axis=0)
    tok_out = flat_out[slot].reshape(T, top_k, d)
    w_keep = gate_w * keep.reshape(T, top_k).astype(gate_w.dtype)
    out = jnp.einsum("tkd,tk->td", tok_out.astype(jnp.float32), w_keep)
    return out.reshape(B, S, d).astype(x.dtype), aux
