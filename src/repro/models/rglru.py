"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = sigmoid(x_t @ W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t @ W_x + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` (the recurrence is a
1-D linear scan -> O(log S) depth); decode carries (h, conv_state).
``kernels/decay_scan.py`` is the Pallas TPU version of the same scan.
The block wraps the RG-LRU with the Griffin recurrent-block structure:
input/gate projections, width-4 causal depthwise conv, output projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, silu

_C = 8.0
_CONV_W = 4


def init_rglru_block(key, d: int, lru: int, dtype, n_stack: int = 0) -> Dict:
    ks = jax.random.split(key, 6)
    def mk(k, i, o):
        w = dense_init(k, i, o, dtype)
        return jnp.broadcast_to(w, (n_stack, i, o)).copy() if n_stack else w
    lam = jnp.linspace(0.9, 0.999, lru)
    lam = jnp.log(jnp.exp(-jnp.log(lam) / _C) - 1.0)     # softplus^-1 so a ~ lam
    conv = (jax.random.normal(ks[4], (_CONV_W, lru), jnp.float32) * 0.1).astype(dtype)
    p = {
        "gate_in": mk(ks[0], d, lru),
        "lru_in": mk(ks[1], d, lru),
        "lru_out": mk(ks[2], lru, d),
        "w_a": mk(ks[3], lru, lru),
        "w_x": mk(ks[5], lru, lru),
        "lambda": lam.astype(jnp.float32),
        "conv": conv,
    }
    if n_stack:
        p["lambda"] = jnp.broadcast_to(p["lambda"], (n_stack, lru)).copy()
        p["conv"] = jnp.broadcast_to(conv, (n_stack, _CONV_W, lru)).copy()
    return p


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, width 4.  x: (B, S, C); state: (B, 3, C)."""
    B, S, C = x.shape
    pad = state if state is not None else jnp.zeros((B, _CONV_W - 1, C), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + S] * w[i][None, None, :] for i in range(_CONV_W))
    return out, xp[:, S:][:, - (_CONV_W - 1):] if S >= _CONV_W - 1 else xp[:, -(_CONV_W - 1):]


def rg_lru_scan(xc: jax.Array, p: Dict,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """xc: (B, S, lru) post-conv activations -> (h (B,S,lru), h_last)."""
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ p["w_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r           # (B,S,lru), <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(xc.dtype), h[:, -1]


def rg_lru_step(xc: jax.Array, p: Dict, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step.  xc: (B, 1, lru); h: (B, lru)."""
    x32 = xc[:, 0].astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ p["w_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    h_new = a * h.astype(jnp.float32) + \
        jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    return h_new.astype(xc.dtype)[:, None, :], h_new


def init_rec_state(batch: int, lru: int, dtype) -> Dict:
    return {
        "h": jnp.zeros((batch, lru), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, lru), dtype),
    }


def rglru_block(p: Dict, x: jax.Array, state: Optional[Dict] = None,
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full Griffin recurrent block.  x: (B, S, d).  With ``state`` given,
    runs in stateful (decode/prefill-carry) mode and returns the new state."""
    gate = silu(x @ p["gate_in"])                         # (B,S,lru)
    xin = x @ p["lru_in"]
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv"], conv_state)
    if state is not None and x.shape[1] == 1:
        h_seq, h_last = rg_lru_step(xc, p, state["h"])
    else:
        h0 = state["h"] if state is not None else None
        h_seq, h_last = rg_lru_scan(xc, p, h0)
    out = (gate * h_seq) @ p["lru_out"]
    new_state = {"h": h_last, "conv": new_conv} if state is not None else None
    return out, new_state
