"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time mix with
matrix-valued per-head state and data-dependent decay.

Per head (K = V = head_dim):
    o_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
    S_t = Diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(xw_t @ W_w + b_w)) in (0, 1) data-dependent decay.

Default implementation is an exact sequential ``lax.scan`` over time
(state (B, H, K, V) stays O(1) in sequence length — this is why rwkv6
runs ``long_500k`` natively).  ``kernels/wkv6.py`` is the fused Pallas
version (grid over B*H, state held in VMEM).  Recurrence FLOPs are ~1.5%
of the projection FLOPs at d_model=4096, so the scan path is roofline-
faithful.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, silu


def init_rwkv_layer(key, d: int, f: int, head_dim: int, dtype,
                    n_stack: int = 0) -> Dict:
    ks = jax.random.split(key, 12)
    H = d // head_dim
    def mk(k, i, o):
        w = dense_init(k, i, o, dtype)
        return jnp.broadcast_to(w, (n_stack, i, o)).copy() if n_stack else w
    def vec(val, shape):
        v = jnp.full(shape, val, jnp.float32)
        return jnp.broadcast_to(v, (n_stack,) + shape).copy() if n_stack else v
    return {
        # time mix
        "tm_r": mk(ks[0], d, d), "tm_k": mk(ks[1], d, d),
        "tm_v": mk(ks[2], d, d), "tm_g": mk(ks[3], d, d),
        "tm_w": mk(ks[4], d, d), "tm_out": mk(ks[5], d, d),
        "mu": vec(0.5, (5, d)),                 # token-shift lerp for r,k,v,g,w
        "w_bias": vec(-0.6, (d,)),              # decay bias (w ~ exp(-exp(-0.6)) ~ .58)
        "u": vec(0.3, (H, head_dim)),           # per-head bonus
        "ln_x": vec(0.0, (d,)),                 # per-head group-norm gamma
        # channel mix
        "cm_k": mk(ks[6], d, f), "cm_v": mk(ks[7], f, d),
        "cm_r": mk(ks[8], d, d),
        "mu_c": vec(0.5, (2, d)),               # token-shift lerp for k,r
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """xs_t = x_{t-1}; prev: (B, d) carries across chunks/steps."""
    B, S, d = x.shape
    first = prev[:, None, :] if prev is not None else jnp.zeros((B, 1, d), x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def wkv_ref(r, k, v, w, u, s0):
    """Reference recurrence in float32 (also the kernels/ref.py oracle)."""
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, ot
    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w))
    sT, out = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return out.transpose(1, 0, 2, 3), sT


def time_mix(p: Dict, x: jax.Array, state: Optional[Dict], head_dim: int,
             ) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, d = x.shape
    H = d // head_dim
    prev_tok = state["tok"] if state is not None else None
    xs = _token_shift(x, prev_tok)
    mu = p["mu"].astype(x.dtype)
    def mixed(i):
        return x + (xs - x) * mu[i][None, None, :]
    r = (mixed(0) @ p["tm_r"]).reshape(B, S, H, head_dim)
    k = (mixed(1) @ p["tm_k"]).reshape(B, S, H, head_dim)
    v = (mixed(2) @ p["tm_v"]).reshape(B, S, H, head_dim)
    g = silu(mixed(3) @ p["tm_g"])
    w_raw = (mixed(4) @ p["tm_w"]).astype(jnp.float32) + p["w_bias"]
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, S, H, head_dim)

    s0 = state["wkv"] if state is not None else \
        jnp.zeros((B, H, head_dim, head_dim), jnp.float32)
    out, sT = wkv_ref(r, k, v, w, p["u"].astype(jnp.float32), s0)

    # per-head group norm
    o32 = out.astype(jnp.float32)
    mean = o32.mean(-1, keepdims=True)
    var = o32.var(-1, keepdims=True)
    o32 = (o32 - mean) * jax.lax.rsqrt(var + 1e-5)
    o = (o32.reshape(B, S, d) * (1.0 + p["ln_x"])).astype(x.dtype)
    y = (o * g) @ p["tm_out"]
    new_state = None
    if state is not None:
        new_state = {"tok": x[:, -1], "wkv": sT}
    return y, new_state


def channel_mix(p: Dict, x: jax.Array, state: Optional[Dict],
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
    prev_tok = state if state is not None else None
    xs = _token_shift(x, prev_tok)
    mu = p["mu_c"].astype(x.dtype)
    xk = x + (xs - x) * mu[0][None, None, :]
    xr = x + (xs - x) * mu[1][None, None, :]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (kk @ p["cm_v"])
    return out, (x[:, -1] if state is not None else None)


def init_rwkv_state(batch: int, d: int, head_dim: int, dtype) -> Dict:
    H = d // head_dim
    return {
        "tm": {"tok": jnp.zeros((batch, d), dtype),
               "wkv": jnp.zeros((batch, H, head_dim, head_dim), jnp.float32)},
        "cm_tok": jnp.zeros((batch, d), dtype),
    }
