"""Unified model assembly for all assigned families.

Every family exposes the same interface via ``Model``:

    init(key)                          -> params
    forward(params, batch)             -> (logits, aux)       # teacher-forced
    init_cache(batch, cache_len)       -> cache
    prefill(params, batch, cache)      -> (logits, cache)
    decode_step(params, batch, cache)  -> (logits, cache)     # one token

Layer stacks are ``lax.scan`` over stacked params (HLO depth-independent);
heterogeneous stacks (hybrid 1:2, vlm 1-in-5 cross) scan the repeating
pattern group and unroll the remainder.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (cross_entropy, embed_init, init_mlp, mlp,
                                 rms_norm, sinusoidal_pos)
from repro.sharding.partition import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sub-layer init
# ---------------------------------------------------------------------------


def _init_attn_layer(key, cfg: ModelConfig, n_stack: int) -> Params:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": _zeros((cfg.d_model,), n_stack),
        "ln2": _zeros((cfg.d_model,), n_stack),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    hd, _dt(cfg), cfg.qk_norm, n_stack),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, _dt(cfg), n_stack),
    }
    return p


def _init_moe_layer(key, cfg: ModelConfig, n_stack: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": _zeros((cfg.d_model,), n_stack),
        "ln2": _zeros((cfg.d_model,), n_stack),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    hd, _dt(cfg), cfg.qk_norm, n_stack),
        "moe": moe_mod.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                _dt(cfg), n_stack),
    }
    if cfg.moe_dense_residual:
        p["dense_mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, _dt(cfg), n_stack)
    return p


def _init_rec_layer(key, cfg: ModelConfig, n_stack: int) -> Params:
    k1, k2 = jax.random.split(key)
    lru = cfg.lru_width or cfg.d_model
    return {
        "ln1": _zeros((cfg.d_model,), n_stack),
        "ln2": _zeros((cfg.d_model,), n_stack),
        "rec": rglru_mod.init_rglru_block(k1, cfg.d_model, lru, _dt(cfg), n_stack),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, _dt(cfg), n_stack),
    }


def _init_rwkv_layer(key, cfg: ModelConfig, n_stack: int) -> Params:
    return {
        "ln1": _zeros((cfg.d_model,), n_stack),
        "ln2": _zeros((cfg.d_model,), n_stack),
        "mix": rwkv_mod.init_rwkv_layer(key, cfg.d_model, cfg.d_ff,
                                        cfg.wkv_head_dim, _dt(cfg), n_stack),
    }


def _init_cross_layer(key, cfg: ModelConfig, n_stack: int) -> Params:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "ln1": _zeros((cfg.d_model,), n_stack),
        "ln2": _zeros((cfg.d_model,), n_stack),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    hd, _dt(cfg), False, n_stack),
        "gate": _zeros((), n_stack),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, _dt(cfg), n_stack),
    }


def _zeros(shape, n_stack):
    if n_stack:
        shape = (n_stack,) + shape
    return jnp.zeros(shape, jnp.float32)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# sub-layer apply (one layer, unstacked params)
# ---------------------------------------------------------------------------


def _apply_attn_layer(lp, x, cfg, mode, cache=None, pos=None):
    hd = cfg.resolved_head_dim
    kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
              rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
              constrain_kv=cfg.constrain_kv)
    h = rms_norm(x, lp["ln1"])
    if mode == "train":
        out = attn.self_attention(lp["attn"], h, window=cfg.window, **kw)
        new_cache = None
    elif mode == "prefill":
        out, new_cache = attn.prefill_self_attention(
            lp["attn"], h, cache, window=cfg.window, **kw)
    else:  # decode
        out, new_cache = attn.decode_self_attention(
            lp["attn"], h, cache, pos, **kw)
    x = x + out
    x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
    return x, new_cache, 0.0


def _apply_moe_layer(lp, x, cfg, mode, cache=None, pos=None):
    hd = cfg.resolved_head_dim
    kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
              rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
              constrain_kv=cfg.constrain_kv)
    h = rms_norm(x, lp["ln1"])
    if mode == "train":
        out = attn.self_attention(lp["attn"], h, **kw)
        new_cache = None
    elif mode == "prefill":
        out, new_cache = attn.prefill_self_attention(
            lp["attn"], h, cache, **kw)
    else:
        out, new_cache = attn.decode_self_attention(
            lp["attn"], h, cache, pos, **kw)
    x = x + out
    h2 = rms_norm(x, lp["ln2"])
    ffn, aux = moe_mod.moe_ffn(lp["moe"], h2, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
    if cfg.moe_dense_residual:
        ffn = ffn + mlp(lp["dense_mlp"], h2)
    return x + ffn, new_cache, aux


def _apply_rec_layer(lp, x, cfg, mode, state=None):
    h = rms_norm(x, lp["ln1"])
    out, new_state = rglru_mod.rglru_block(lp["rec"], h, state)
    x = x + out
    x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
    return x, new_state, 0.0


def _apply_rwkv_layer(lp, x, cfg, mode, state=None):
    h = rms_norm(x, lp["ln1"])
    tm_state = state["tm"] if state is not None else None
    out, new_tm = rwkv_mod.time_mix(lp["mix"], h, tm_state, cfg.wkv_head_dim)
    x = x + out
    h2 = rms_norm(x, lp["ln2"])
    cm_state = state["cm_tok"] if state is not None else None
    out2, new_cm = rwkv_mod.channel_mix(lp["mix"], h2, cm_state)
    x = x + out2
    new_state = {"tm": new_tm, "cm_tok": new_cm} if state is not None else None
    return x, new_state, 0.0


def _apply_cross_layer(lp, x, cfg, kv):
    """Gated cross-attention layer (llama-3.2-vision / whisper decoder)."""
    hd = cfg.resolved_head_dim
    h = rms_norm(x, lp["ln1"])
    out = attn.cross_attention(lp["attn"], h, kv, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=hd)
    x = x + (jnp.tanh(lp["gate"]) * out.astype(jnp.float32)).astype(x.dtype)
    x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"]))
    return x


_SUBLAYER = {
    "attn": _apply_attn_layer,
    "moe": _apply_moe_layer,
    "rec": _apply_rec_layer,
    "rwkv": _apply_rwkv_layer,
}

_SUBINIT = {
    "attn": _init_attn_layer,
    "moe": _init_moe_layer,
    "rec": _init_rec_layer,
    "rwkv": _init_rwkv_layer,
}


# ---------------------------------------------------------------------------
# cache init per sub-layer kind
# ---------------------------------------------------------------------------


def _init_sub_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int):
    hd = cfg.resolved_head_dim
    dt = _dt(cfg)
    if kind in ("attn", "moe"):
        C = min(cache_len, cfg.window) if cfg.window else cache_len
        return attn.init_kv_cache(batch, C, cfg.n_kv_heads, hd, dt)
    if kind == "rec":
        return rglru_mod.init_rec_state(batch, cfg.lru_width or cfg.d_model, dt)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(batch, cfg.d_model, cfg.wkv_head_dim, dt)
    raise ValueError(kind)


def _stack(tree, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), tree)


def maybe_scan(body, carry, xs, *, scan: bool, n: int, remat: bool = False):
    """``lax.scan`` or an unrolled python loop over stacked ``xs``.

    Unrolling exists for the dry-run: XLA's cost_analysis counts a scan
    body once, so the roofline would undercount depth by ~n_layers
    (DESIGN.md §5)."""
    if remat:
        body = jax.checkpoint(body)
    if scan:
        return jax.lax.scan(body, carry, xs)
    ys_acc = []
    for i in range(n):
        xi = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, xi)
        ys_acc.append(y)
    if not ys_acc or ys_acc[0] is None:
        return carry, None
    ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_acc)
    return carry, ys


# ---------------------------------------------------------------------------
# Decoder-only model (dense / moe / ssm / hybrid)
# ---------------------------------------------------------------------------


class Model:
    """Decoder-only LM over a (possibly heterogeneous) layer stack."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family == "hybrid" and cfg.pattern:
            pat = list(cfg.pattern)
        elif cfg.family == "ssm":
            pat = ["rwkv"]
        elif cfg.family == "moe":
            pat = ["moe"]
        else:
            pat = ["attn"]
        self.pattern = pat
        self.n_groups = cfg.n_layers // len(pat)
        self.n_rest = cfg.n_layers - self.n_groups * len(pat)
        self.kinds = pat * self.n_groups + pat[: self.n_rest]

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: Params = {
            "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, _dt(cfg)),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = embed_init(keys[1], cfg.padded_vocab, cfg.d_model, _dt(cfg))
        groups = {}
        for j, kind in enumerate(self.pattern):
            groups[f"l{j}"] = _SUBINIT[kind](
                jax.random.fold_in(keys[2], j), cfg, self.n_groups)
        p["groups"] = groups
        for r in range(self.n_rest):
            p[f"rest{r}"] = _SUBINIT[self.pattern[r]](
                jax.random.fold_in(keys[3], r), cfg, 0)
        return p

    # -- embedding / head -----------------------------------------------------
    def _embed(self, p, tokens):
        x = jnp.take(p["embed"], tokens, axis=0).astype(_dt(self.cfg))
        return constrain(x, "batch", None, None)

    def _head(self, p, x):
        x = rms_norm(x, p["ln_f"])
        table = p["embed"] if self.cfg.tie_embeddings else p["unembed"]
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        return constrain(logits, "batch", None, "vocab")

    # -- train forward --------------------------------------------------------
    def forward(self, p: Params, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = self._embed(p, batch["tokens"])

        def group_body(carry, gp):
            x, aux = carry
            for j, kind in enumerate(self.pattern):
                x, _, a = _SUBLAYER[kind](gp[f"l{j}"], x, cfg, "train")
                aux = aux + a
            return (x, aux), None

        (x, aux), _ = maybe_scan(group_body, (x, jnp.float32(0.0)),
                                 p["groups"], scan=cfg.scan_layers,
                                 n=self.n_groups, remat=cfg.remat)
        for r in range(self.n_rest):
            x, _, a = _SUBLAYER[self.pattern[r]](p[f"rest{r}"], x, cfg, "train")
            aux = aux + a
        return self._head(p, x), aux

    def loss(self, p: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = self.forward(p, batch)
        ce = cross_entropy(logits, batch["labels"], self.cfg.vocab_size)
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- cache ----------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> Dict:
        cache: Dict = {"pos": jnp.int32(0)}
        groups = {}
        for j, kind in enumerate(self.pattern):
            groups[f"l{j}"] = _stack(
                _init_sub_cache(kind, self.cfg, batch, cache_len), self.n_groups)
        cache["groups"] = groups
        for r in range(self.n_rest):
            cache[f"rest{r}"] = _init_sub_cache(
                self.pattern[r], self.cfg, batch, cache_len)
        return cache

    # -- prefill / decode -------------------------------------------------------
    def _stateful(self, p: Params, x, cache: Dict, mode: str):
        cfg = self.cfg
        pos = cache["pos"]

        def group_body(x, xs):
            gp, gc = xs
            new_gc = {}
            for j, kind in enumerate(self.pattern):
                if kind in ("attn", "moe"):
                    x, nc, _ = _SUBLAYER[kind](gp[f"l{j}"], x, cfg, mode,
                                               cache=gc[f"l{j}"], pos=pos)
                else:
                    x, nc, _ = _SUBLAYER[kind](gp[f"l{j}"], x, cfg, mode,
                                               gc[f"l{j}"])
                new_gc[f"l{j}"] = nc
            return x, new_gc

        x, new_groups = maybe_scan(group_body, x,
                                   (p["groups"], cache["groups"]),
                                   scan=cfg.scan_layers, n=self.n_groups)
        new_cache: Dict = {"groups": new_groups}
        for r in range(self.n_rest):
            kind = self.pattern[r]
            if kind in ("attn", "moe"):
                x, nc, _ = _SUBLAYER[kind](p[f"rest{r}"], x, cfg, mode,
                                           cache=cache[f"rest{r}"], pos=pos)
            else:
                x, nc, _ = _SUBLAYER[kind](p[f"rest{r}"], x, cfg, mode,
                                           cache[f"rest{r}"])
            new_cache[f"rest{r}"] = nc
        return x, new_cache

    def prefill(self, p: Params, batch: Dict, cache: Dict):
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        x, new_cache = self._stateful(p, x, cache, "prefill")
        new_cache["pos"] = cache["pos"] + tokens.shape[1]
        return self._head(p, x[:, -1:]), new_cache

    def decode_step(self, p: Params, batch: Dict, cache: Dict):
        token = batch["tokens"]                      # (B, 1)
        x = self._embed(p, token)
        x, new_cache = self._stateful(p, x, cache, "decode")
        new_cache["pos"] = cache["pos"] + 1
        return self._head(p, x), new_cache
