"""Llama-3.2-Vision-style VLM: a dense GQA text decoder with a gated
cross-attention layer to the image tokens every ``cross_attn_every``
layers, scanned as groups of (N-1 self + 1 cross).

The ViT/SigLIP vision encoder is a STUB per the brief: the batch carries
precomputed patch embeddings ``image_embeds`` (B, n_img_tokens, d_vision);
the model owns only the linear projector into d_model.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import dense_init, rms_norm
from repro.models.transformer import (Model, _apply_attn_layer,
                                      _apply_cross_layer, _dt,
                                      _init_attn_layer, _init_cross_layer,
                                      _init_sub_cache, _stack, maybe_scan)

Params = Dict[str, Any]


class VLMModel(Model):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        assert cfg.cross_attn_every > 0
        assert cfg.n_layers % cfg.cross_attn_every == 0, \
            "vlm stack must be whole groups"
        self.n_self = cfg.cross_attn_every - 1
        self.n_groups = cfg.n_layers // cfg.cross_attn_every
        self.n_rest = 0

    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 4)
        base = Model(cfg)           # reuse embed/ln_f init
        p = {"embed": base.init(keys[0])["embed"],
             "ln_f": jnp.zeros((cfg.d_model,), jnp.float32)}
        if not cfg.tie_embeddings:
            import repro.models.layers as L
            p["unembed"] = L.embed_init(keys[3], cfg.padded_vocab, cfg.d_model,
                                        _dt(cfg))
        p["img_proj"] = dense_init(keys[1], cfg.d_vision or cfg.d_model,
                                   cfg.d_model, _dt(cfg))
        groups: Dict = {}
        for j in range(self.n_self):
            groups[f"self{j}"] = _init_attn_layer(
                jax.random.fold_in(keys[2], j), cfg, self.n_groups)
        groups["cross"] = _init_cross_layer(
            jax.random.fold_in(keys[2], 99), cfg, self.n_groups)
        p["groups"] = groups
        return p

    def _project_image(self, p, batch):
        img = batch["image_embeds"].astype(_dt(self.cfg))
        return img @ p["img_proj"]                       # (B, N, d)

    # -- train ------------------------------------------------------------------
    def forward(self, p: Params, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        img = self._project_image(p, batch)
        x = self._embed(p, batch["tokens"])
        hd = cfg.resolved_head_dim

        def group_body(x, gp):
            for j in range(self.n_self):
                x, _, _ = _apply_attn_layer(gp[f"self{j}"], x, cfg, "train")
            kv = attn.cross_kv(gp["cross"]["attn"], img, cfg.n_kv_heads, hd)
            x = _apply_cross_layer(gp["cross"], x, cfg, kv)
            return x, None

        x, _ = maybe_scan(group_body, x, p["groups"],
                          scan=cfg.scan_layers, n=self.n_groups,
                          remat=cfg.remat)
        return self._head(p, x), jnp.float32(0.0)

    # -- cache -------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> Dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        groups: Dict = {}
        for j in range(self.n_self):
            groups[f"self{j}"] = _stack(
                _init_sub_cache("attn", cfg, batch, cache_len), self.n_groups)
        groups["cross_kv"] = {
            "k": jnp.zeros((self.n_groups, batch, cfg.n_img_tokens,
                            cfg.n_kv_heads, hd), _dt(cfg)),
            "v": jnp.zeros((self.n_groups, batch, cfg.n_img_tokens,
                            cfg.n_kv_heads, hd), _dt(cfg)),
        }
        return {"groups": groups, "pos": jnp.int32(0)}

    def _stateful(self, p, x, cache, mode):
        cfg = self.cfg
        pos = cache["pos"]

        def group_body(x, xs):
            gp, gc = xs
            new_gc = {}
            for j in range(self.n_self):
                x, nc, _ = _apply_attn_layer(gp[f"self{j}"], x, cfg, mode,
                                             cache=gc[f"self{j}"], pos=pos)
                new_gc[f"self{j}"] = nc
            x = _apply_cross_layer(gp["cross"], x, cfg, gc["cross_kv"])
            new_gc["cross_kv"] = gc["cross_kv"]
            return x, new_gc

        x, new_groups = maybe_scan(group_body, x,
                                   (p["groups"], cache["groups"]),
                                   scan=cfg.scan_layers, n=self.n_groups)
        return x, {"groups": new_groups}

    def prefill(self, p: Params, batch: Dict, cache: Dict):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        img = self._project_image(p, batch)
        ckv = jax.vmap(
            lambda cp: attn.cross_kv(cp, img, cfg.n_kv_heads, hd)
        )(p["groups"]["cross"]["attn"])
        cache = jax.tree.map(lambda x: x, cache)          # shallow copy
        cache["groups"]["cross_kv"] = ckv
        x = self._embed(p, batch["tokens"])
        x, new_cache = self._stateful(p, x, cache, "prefill")
        new_cache["pos"] = cache["pos"] + batch["tokens"].shape[1]
        return self._head(p, x[:, -1:]), new_cache

    def decode_step(self, p: Params, batch: Dict, cache: Dict):
        x = self._embed(p, batch["tokens"])
        x, new_cache = self._stateful(p, x, cache, "decode")
        new_cache["pos"] = cache["pos"] + 1
        return self._head(p, x), new_cache
