"""AdamW in pure JAX (no optax in this container).

Optimizer state is a pytree mirroring the params (m, v in float32), so the
same partition specs shard it.  Update is fully functional:

    state = adamw_init(params)
    params, state = adamw_update(params, grads, state, lr, ...)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # pytree like params (float32)
    v: Any                   # pytree like params (float32)


def adamw_init(params: Any, dtype=jnp.float32) -> AdamWState:
    """``dtype``: storage dtype for m/v. bf16 halves optimizer residency
    (the arctic-480b single-pod memory lever — EXPERIMENTS §Perf); the
    update math always runs in float32."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.int32(0),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(params: Any, grads: Any, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState]:
    step = state.step + 1

    # global-norm clip
    if grad_clip > 0:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay > 0 and p.ndim >= 2:       # decay matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return params_new, AdamWState(step=step, m=m_new, v=v_new)
