from repro.sharding.partition import (  # noqa: F401
    activation_rules,
    constrain,
    param_shardings,
    param_specs,
    use_rules,
)
