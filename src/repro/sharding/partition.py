"""Rule-based sharding.

Two halves:

1. **Parameter specs** — ``param_specs(params)`` walks the param pytree and
   assigns a ``PartitionSpec`` per leaf from its path + shape, sharding the
   biggest dims over ("data", "model") FSDP×TP style, with a divisibility
   fallback (a dim that doesn't divide the mesh axis is replicated).

2. **Activation constraints** — model code calls
   ``constrain(x, "batch", None, "tensor")`` with *logical* axis names; a
   contextvar holds the active mesh + logical→mesh-axis rules.  Outside a
   mesh context (CPU unit tests) it is a no-op, so the same model code runs
   everywhere.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical activation axis -> mesh axes (tuple = sharded over several)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),       # batch dim of activations
    "seq": None,                    # sequence: replicated by default
    "tensor": "model",              # d_ff / head-sharded dims
    "heads": "model",               # attention heads (guarded by
                                    # divisibility; else forced replicated)
    "embed": None,                  # d_model on activations: replicated
    "expert": "model",              # expert-parallel dim
    "vocab": "model",
}

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_rules", default=None)


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    token = _CTX.set((mesh, merged) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(token)


def activation_rules() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return _CTX.get()


def _resolve(mesh: Mesh, rules: Dict[str, Any], names) -> P:
    axes = []
    for n in names:
        if n is None:
            axes.append(None)
            continue
        mapped = rules.get(n, None)
        if mapped is None:
            axes.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(a for a in mapped if a in mesh.axis_names)
        axes.append(present if len(present) > 1 else (present[0] if present else None))
    return P(*axes)


def constrain(x: jax.Array, *names) -> jax.Array:
    """Apply a logical sharding constraint; no-op outside a mesh context or
    when a named dim doesn't divide its mesh axes."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _resolve(mesh, rules, names)
    # guards: drop constraints that don't divide, and duplicate mesh axes
    # (e.g. "expert" and "tensor" both mapping to "model" — first wins)
    fixed = []
    used: set = set()
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axt = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a in used for a in axt):
            fixed.append(None)
            continue
        size = 1
        for a in axt:
            size *= mesh.shape[a]
        if dim % size == 0:
            fixed.append(ax)
            used.update(axt)
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# gather-then-hash
# ---------------------------------------------------------------------------


def gather_tree(tree: Any) -> Any:
    """Fetch every leaf to host memory as a plain ``np.ndarray``,
    reassembling sharded ``jax.Array``s from their addressable shards.

    This is the *gather* half of the gather-then-hash digest contract:
    any digest over training state must hash the globally-assembled
    values, never per-device buffers, so the result is invariant to the
    mesh shape and device layout the producer happened to run on (a
    1-device CPU node and an 8-way FSDP node must commit bit-identical
    ``state_digest``s for the same params)."""
    import numpy as _np

    def gather(leaf):
        if isinstance(leaf, jax.Array):
            return _np.asarray(jax.device_get(leaf))
        return _np.asarray(leaf)

    return jax.tree.map(gather, tree)


# ---------------------------------------------------------------------------
# parameter partitioning
# ---------------------------------------------------------------------------

# Path-regex rules.  Matched against "/"-joined pytree key paths.  Each rule
# gives logical axes per *trailing* dimension (leading scan/stack dims get
# None).  ("fsdp", "tensor") means dim -2 over data, dim -1 over model.
_PARAM_RULES = [
    (r"embed|unembed|pos_table",        ("tensor", "fsdp")),      # (V, d) / (P, d)
    (r"experts/(w1|w3)$",               ("expert", "fsdp", "tensor_in")),  # (E, d, f)
    (r"experts/w2$",                    ("expert", "tensor_in", "fsdp")),  # (E, f, d)
    (r"router",                         ("fsdp", None)),          # (d, E)
    (r"(wq|wk|wv|q_proj|k_proj|v_proj)$", ("fsdp", "tensor")),    # (d, H*hd)
    (r"(wo|o_proj|out_proj)$",          ("tensor", "fsdp")),      # (H*hd, d)
    (r"w1$|w3$|lru_in|gate_in",         ("fsdp", "tensor")),      # (d, f)
    (r"w2$|lru_out",                    ("tensor", "fsdp")),      # (f, d)
    (r"(tm_[rkvgw]|tm_out|cm_[rk])$",   ("fsdp", "tensor")),      # rwkv mats (d, d)/(d,f)
    (r"cm_v$",                          ("tensor", "fsdp")),      # (f, d)
    (r"conv",                           (None, "tensor")),
]

_LOGICAL_PARAM_AXES = {
    "fsdp": ("data",),
    "tensor": ("model",),
    "tensor_in": ("model",),   # secondary tensor dim — replicated by default
    "expert": ("model",),
    None: (),
}


def _spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh,
              fsdp: bool, expert_axis: str = "model",
              fsdp_pod: bool = False) -> P:
    logical = None
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            logical = axes
            break
    if logical is None or not shape:
        return P()
    # align logical axes to the trailing dims; leading stack dims -> None
    n_lead = len(shape) - len(logical)
    if n_lead < 0:
        logical = logical[-len(shape):]
        n_lead = 0
    axes = [None] * n_lead
    used = set()
    for dim, name in zip(shape[n_lead:], logical):
        mesh_axes = _LOGICAL_PARAM_AXES.get(name, ())
        if name == "fsdp" and fsdp and fsdp_pod \
                and "pod" in mesh.axis_names and "pod" not in used \
                and "data" not in used \
                and dim % (mesh.shape["pod"] * mesh.shape["data"]) == 0:
            axes.append(("pod", "data"))
            used.update(("pod", "data"))
            continue
        if name == "expert":
            mesh_axes = (expert_axis,)
        if name == "fsdp" and not fsdp:
            mesh_axes = ()
        if name == "tensor_in":
            # secondary tensor dim: picks up "model" when the expert dim
            # moved to "data" (expert_axis lever), else blocked by `used`
            mesh_axes = ("model",)
        pick = None
        for a in mesh_axes:
            if a in mesh.axis_names and a not in used and dim % mesh.shape[a] == 0:
                pick = a
                used.add(a)
                break
        axes.append(pick)
    return P(*axes)


def param_specs(params: Any, mesh: Mesh, fsdp: bool = True,
                expert_axis: str = "model", fsdp_pod: bool = False) -> Any:
    """PartitionSpec pytree matching ``params`` (which may be arrays or
    ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(_spec_for(pstr, tuple(leaf.shape), mesh, fsdp,
                               expert_axis, fsdp_pod))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Any, mesh: Mesh, fsdp: bool = True,
                    expert_axis: str = "model") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, fsdp, expert_axis))


# ---------------------------------------------------------------------------
# batch / cache partitioning
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch: Any, mesh: Mesh, global_batch: int) -> Any:
    """Shard the leading (batch) dim over (pod, data) when divisible."""
    ba = batch_axes(mesh)
    size = 1
    for a in ba:
        size *= mesh.shape[a]

    def spec(leaf):
        if leaf.ndim and leaf.shape[0] == global_batch \
                and global_batch % size == 0:
            return P(ba)
        return P()

    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, global_batch: int) -> Any:
    """KV-cache/state sharding: batch dim over (pod, data); the LAST dim
    divisible by the model axis gets "model" (head_dim / lru / state dims
    — never the ring-buffer length, which is dynamically indexed)."""
    ba = batch_axes(mesh)
    bsz = 1
    for a in ba:
        bsz *= mesh.shape[a]
    msz = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def spec(leaf):
        axes = [None] * leaf.ndim
        b_at = None
        for i, d in enumerate(leaf.shape):
            if d == global_batch and global_batch % bsz == 0:
                axes[i] = ba
                b_at = i
                break
        if msz > 1 and leaf.ndim >= 2:
            for i in range(leaf.ndim - 1, -1, -1):
                if i != b_at and axes[i] is None \
                        and leaf.shape[i] % msz == 0 and leaf.shape[i] > 1:
                    axes[i] = "model"
                    break
        return P(*axes)

    return jax.tree.map(spec, cache)
