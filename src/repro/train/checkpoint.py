"""Checkpointing with ledger integration.

Checkpoints are flat ``.npz`` bundles of the state pytree; every save
returns a SHA-256 digest of the serialized bytes, which ``core/pow_train``
chains into the PNPCoin ledger — the blockchain timestamps the training
trajectory, making any replayed/forged checkpoint detectable (the paper's
transparency/reproducibility goal, §5).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, state: Any, meta: Dict | None = None
                    ) -> str:
    """Serialize ``state`` to ``path``; returns the SHA-256 hex digest."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(state)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    raw = buf.getvalue()
    digest = hashlib.sha256(raw).hexdigest()
    with open(path, "wb") as f:
        f.write(raw)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump({**meta, "sha256": digest}, f, indent=2)
    return digest


def load_checkpoint(path: str, like: Any) -> Tuple[Any, str]:
    """Restore into the structure of ``like``; returns (state, digest)."""
    with open(path, "rb") as f:
        raw = f.read()
    digest = hashlib.sha256(raw).hexdigest()
    npz = np.load(io.BytesIO(raw))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_k)
        arr = npz[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), digest


def state_digest(state: Any) -> str:
    """Order-stable digest of a live pytree (no file round-trip)."""
    h = hashlib.sha256()
    flat = _flatten(state)
    for key in sorted(flat):
        h.update(key.encode())
        h.update(np.ascontiguousarray(flat[key]).tobytes())
    return h.hexdigest()
