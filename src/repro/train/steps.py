"""Step functions: the jash payloads of the PoUW training/serving system.

``make_train_step(cfg)`` returns a pure
``(state, batch) -> (state, metrics)`` function — *this is what the
Runtime Authority publishes per block* for the training use case
(PNPCoin §1: "finding the next optimum in hyperdimensional SGD").
``make_prefill_step`` / ``make_decode_step`` are the serving analogues.

All of them are bounded-complexity by construction (jaxpr has no
``while_loop`` — see ``core/jash.py``), deterministic, and shardable
under pjit on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import adapt_for_shape, build_model, cache_len_for
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainHparams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def make_train_state(cfg: ModelConfig, key) -> TrainState:
    model = build_model(cfg)
    params = model.init(key)
    return TrainState(params=params,
                      opt=adamw_init(params, jnp.dtype(cfg.opt_dtype)))


def make_train_step(cfg: ModelConfig,
                    hp: TrainHparams = TrainHparams()
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    model = build_model(cfg)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        lr = cosine_schedule(state.opt.step + 1, peak_lr=hp.peak_lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr,
                                   weight_decay=hp.weight_decay,
                                   grad_clip=hp.grad_clip)
        out = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
               "lr": lr}
        return TrainState(params=params, opt=opt), out

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Forward-only loss (used by optimal-mode / ES candidate scoring)."""
    model = build_model(cfg)

    def eval_step(params, batch) -> jax.Array:
        loss, _ = model.loss(params, batch)
        return loss

    return eval_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, shape: InputShape):
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: InputShape):
    """One new token against a ``shape.seq_len``-deep cache."""
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def decode_step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), logits, new_cache

    return decode_step


def make_init_cache(cfg: ModelConfig, shape: InputShape):
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def init_cache():
        return model.init_cache(shape.global_batch, cache_len_for(cfg, shape))

    return init_cache
