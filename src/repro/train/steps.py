"""Step functions: the jash payloads of the PoUW training/serving system.

``make_train_step(cfg)`` returns a pure
``(state, batch) -> (state, metrics)`` function — *this is what the
Runtime Authority publishes per block* for the training use case
(PNPCoin §1: "finding the next optimum in hyperdimensional SGD").
``make_prefill_step`` / ``make_decode_step`` are the serving analogues.

All of them are bounded-complexity by construction (jaxpr has no
``while_loop`` — see ``core/jash.py``), deterministic, and shardable
under pjit on the production mesh.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import adapt_for_shape, build_model, cache_len_for
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.sharding.partition import gather_tree


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


# ---------------------------------------------------------------------------
# digest-stable state canonicalization
# ---------------------------------------------------------------------------


def _canonical_leaf(arr: np.ndarray) -> np.ndarray:
    """Little-endian, C-contiguous view of ``arr`` — the only byte order
    a digest may ever see, regardless of host endianness or the device
    layout the array came back from."""
    if arr.dtype.str.startswith(">"):
        arr = arr.astype(arr.dtype.newbyteorder("<"))
    return np.ascontiguousarray(arr)


def canonical_tree_bytes(tree: Any):
    """Yield the canonical byte framing of a pytree, leaf by leaf:
    ``path | dtype | ndim | shape | little-endian C-order data``.

    The path prefix keeps structurally-different trees with identical
    flattened values apart; the dtype+shape frame keeps reinterpreted
    buffers apart (``float32[4]`` never collides with ``uint8[16]``).
    Leaves are gathered to host first (``sharding.partition.gather_tree``),
    so the stream is sharding- and layout-invariant."""
    flat, _ = jax.tree_util.tree_flatten_with_path(gather_tree(tree))
    for path, leaf in flat:
        arr = _canonical_leaf(np.asarray(leaf))
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        yield pstr.encode() + b"\x00" + arr.dtype.str.encode() + b"\x00"
        yield np.int64(arr.ndim).tobytes()
        yield np.asarray(arr.shape, np.int64).tobytes()
        yield arr.tobytes(order="C")


def tree_digest(tree: Any) -> str:
    """sha256 hex digest of ``canonical_tree_bytes(tree)`` — the generic
    bit-exact commitment for any value pytree (params, batches, metric
    stacks).  Deterministic across processes, platforms, and shardings."""
    h = hashlib.sha256()
    for chunk in canonical_tree_bytes(tree):
        h.update(chunk)
    return h.hexdigest()


def params_digest(state_or_params: Any) -> str:
    """The chain's ``state_digest`` for model training: sha256 of the
    canonical params bytes.  Accepts a ``TrainState`` (digests its
    ``params``) or a bare params pytree.  Shared by ``PoUWTrainer`` and
    ``ModelTrainingWorkload`` so both commit the same digest for the
    same weights."""
    params = (state_or_params.params
              if isinstance(state_or_params, TrainState) else state_or_params)
    return tree_digest(params)


@dataclasses.dataclass(frozen=True)
class TrainHparams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def make_train_state(cfg: ModelConfig, key) -> TrainState:
    model = build_model(cfg)
    params = model.init(key)
    return TrainState(params=params,
                      opt=adamw_init(params, jnp.dtype(cfg.opt_dtype)))


def make_train_step(cfg: ModelConfig,
                    hp: TrainHparams = TrainHparams()
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    model = build_model(cfg)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        lr = cosine_schedule(state.opt.step + 1, peak_lr=hp.peak_lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr,
                                   weight_decay=hp.weight_decay,
                                   grad_clip=hp.grad_clip)
        out = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
               "lr": lr}
        return TrainState(params=params, opt=opt), out

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Forward-only loss (used by optimal-mode / ES candidate scoring)."""
    model = build_model(cfg)

    def eval_step(params, batch) -> jax.Array:
        loss, _ = model.loss(params, batch)
        return loss

    return eval_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, shape: InputShape):
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: InputShape):
    """One new token against a ``shape.seq_len``-deep cache."""
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def decode_step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), logits, new_cache

    return decode_step


def make_init_cache(cfg: ModelConfig, shape: InputShape):
    cfg = adapt_for_shape(cfg, shape)
    model = build_model(cfg)

    def init_cache():
        return model.init_cache(shape.global_batch, cache_len_for(cfg, shape))

    return init_cache
