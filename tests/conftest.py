"""Shared test infrastructure (DESIGN.md §14 flake-proofing).

Two hazards make asyncio TCP tests flaky on loaded CI machines:

* a wedged reader/writer task can hang a test forever (pytest has no
  built-in per-test timeout and ``pytest-timeout`` is not a declared
  dependency), and
* an event loop or socket leaked by one test surfaces as a spurious
  ``ResourceWarning`` — or worse, a port clash — in a *later* test.

``_per_test_alarm`` gives every test in the wire/net modules a hard
SIGALRM deadline (override anywhere with ``@pytest.mark.timeout_s(N)``;
``0`` disables).  The alarm raises ``pytest.fail`` in the main thread,
so a hung ``asyncio.run`` dies with a stack trace instead of eating
the whole CI job.  ``_net_resource_guard`` closes any event loop a
test left behind and forces a GC pass so sockets are reclaimed before
the next test binds.  All TCP tests bind port 0 (the OS picks a free
port) — nothing in this suite hard-codes a port number.
"""
from __future__ import annotations

import asyncio
import gc
import signal
import threading

import pytest

# modules that get a hard deadline even without an explicit marker
_NET_MODULES = ("test_net_peers", "test_wire_protocol", "test_peerbook",
                "test_net_mesh", "test_net_liveness", "test_net_chaos")
_DEFAULT_NET_TIMEOUT_S = 300


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout_s(seconds): hard per-test wall-clock limit enforced "
        "via SIGALRM (0 disables)")


def _alarm_supported() -> bool:
    return (hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread())


@pytest.fixture(autouse=True)
def _per_test_alarm(request):
    limit = None
    marker = request.node.get_closest_marker("timeout_s")
    if marker is not None and marker.args:
        limit = float(marker.args[0])
    elif any(m in request.node.nodeid for m in _NET_MODULES):
        limit = float(_DEFAULT_NET_TIMEOUT_S)
    if not limit or not _alarm_supported():
        yield
        return

    def _on_alarm(signum, frame):
        pytest.fail(f"test exceeded hard timeout of {limit:.0f}s "
                    f"(SIGALRM watchdog)", pytrace=True)

    old_handler = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old_handler)


@pytest.fixture(autouse=True)
def _net_resource_guard(request):
    """Close leaked event loops and reclaim sockets after net tests."""
    yield
    if not any(m in request.node.nodeid for m in _NET_MODULES):
        return
    try:
        loop = asyncio.get_event_loop_policy().get_event_loop()
        if not loop.is_running() and not loop.is_closed():
            loop.close()
    except Exception:
        pass
    asyncio.set_event_loop(None)
    gc.collect()
