"""Per-architecture smoke tests (deliverable (f)): every assigned arch,
reduced variant (2 layers / pattern-group, d_model<=256, <=4 experts),
one forward + one train step on CPU — output shapes + no NaNs — plus the
stronger prefill/decode vs teacher-forced consistency check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_configs, reduced
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model, supports_shape
from repro.train.steps import TrainHparams, make_train_state, make_train_step

ARCHS = [a for a in list_configs() if a != "pnpcoin-demo"]
B, S = 2, 16


def _batch(cfg, key=1, seq=S):
    toks = jax.random.randint(jax.random.key(key), (B, seq), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jnp.concatenate(
                 [toks[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.n_img_tokens, cfg.d_vision))
    if cfg.family == "encdec":
        batch["audio_frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.n_enc_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    logits, aux = model.forward(params, _batch(cfg))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = reduced(get_config(arch))
    state = make_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, TrainHparams(
        peak_lr=1e-3, warmup_steps=2, total_steps=10)))
    new_state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert delta > 0.0
    for leaf in jax.tree.leaves(new_state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, key=3, seq=12)
    toks = batch["tokens"]
    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, 32)
    pre = dict(batch)
    pre["tokens"] = toks[:, :11]
    last, cache = model.prefill(params, pre, cache)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(full_logits[:, 10], np.float32), rtol=3e-2, atol=3e-3)
    step_logits, cache = model.decode_step(
        params, {"tokens": toks[:, 11:12]}, cache)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, 11], np.float32), rtol=3e-2, atol=3e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_unroll_equivalence(arch):
    """scan_layers=False (dry-run roofline mode) is numerically identical."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    a, _ = model.forward(params, batch)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    b, _ = build_model(cfg_u).forward(params, batch)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_variant_matches_on_short_seq():
    """With seq <= window, the sliding-window variant must equal full
    attention (long_500k dense path sanity)."""
    cfg = reduced(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    full, _ = model.forward(params, batch)
    cfg_w = dataclasses.replace(cfg, window=S)          # window == seq
    win, _ = build_model(cfg_w).forward(params, batch)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(win, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_long500k_support_matrix():
    skips = [a for a in ARCHS
             if not supports_shape(get_config(a), INPUT_SHAPES["long_500k"])[0]]
    assert skips == ["whisper-medium"]
