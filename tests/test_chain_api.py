"""Chain API acceptance: Node facade, Workload payloads, and a multi-node
Network that converges to one bit-exact chain across all four workloads
(full / optimal / training / classic §3.4 fallback)."""
import dataclasses

import numpy as np
import pytest

from repro.chain import (
    BlockRecord, ChainError, Network, Node, TrainingWorkload, Workload,
    ClassicSha256Workload, JashFullWorkload, JashOptimalWorkload,
)
from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.pow_train import PoUWTrainer
from repro.train.steps import TrainHparams


def small_collatz(arg_bits: int = 6, max_steps: int = 64,
                  importance: float = 0.9) -> Jash:
    base = collatz_jash(max_steps=max_steps)
    return Jash(base.name, base.fn,
                JashMeta(arg_bits=arg_bits, res_bits=32,
                         importance=importance),
                example_args=base.example_args)


def training_workload(seed: int = 7) -> TrainingWorkload:
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 32, 4, "train")
    return TrainingWorkload(
        lambda: PoUWTrainer(cfg, shape,
                            hp=TrainHparams(peak_lr=1e-3, warmup_steps=2,
                                            total_steps=16),
                            mode="full", n_miners=2, seed=seed))


def two_node_network() -> Network:
    return Network.create(
        2, node_factory=lambda i: Node(
            node_id=i, classic_arg_bits=6,
            workloads={"training": training_workload()}))


# ---------------------------------------------------------------------------
# acceptance: 2 nodes, >= 5 blocks, all four workloads, one verified chain
# ---------------------------------------------------------------------------


class TestNetworkAcceptance:
    def test_five_blocks_four_workloads_converge(self):
        net = two_node_network()
        net.nodes[0].submit(small_collatz(max_steps=64))
        net.nodes[1].submit(small_collatz(max_steps=32))

        # block 3 uses the default policy with empty queues -> classic
        schedule = ["full", "optimal", "training", None, "training"]
        results = net.run(5, schedule)

        modes = [r.receipt.record.workload for r in results]
        assert modes == ["full", "optimal", "training", "classic",
                         "training"]
        assert all(not r.rejected_by for r in results)

        # single verified chain, bit-exact merkle roots at every height
        assert net.converged()
        assert net.heights == [5, 5]
        roots = [[b.merkle_root for b in n.ledger.blocks]
                 for n in net.nodes]
        assert roots[0] == roots[1]
        hashes = [[b.block_hash for b in n.ledger.blocks]
                  for n in net.nodes]
        assert hashes[0] == hashes[1]

        # every block audits on every node
        for node in net.nodes:
            assert all(node.audit(h) for h in range(5))

        # per-node credit books agree and conserve the block rewards
        books = [sorted(n.book.balances.items()) for n in net.nodes]
        assert books[0] == books[1]
        for node in net.nodes:
            assert np.isclose(node.book.total_issued, 5 * 50.0)
            assert np.isclose(sum(node.book.balances.values()),
                              node.book.total_issued)

    def test_concurrent_miners_fork_resolves_to_longest(self):
        """Two nodes mine height-0 concurrently (no broadcast): a fork.
        The next broadcast carries the longer chain and the loser adopts
        it wholesale — ledger and credit book both rebuilt."""
        net = two_node_network()
        r0 = net.nodes[0].mine_block("classic")
        r1 = net.nodes[1].mine_block("classic")
        assert net.nodes[0].ledger.tip_hash != "" and not net.converged()
        issued_before = net.nodes[0].book.total_issued

        # node 1 extends its fork and broadcasts: strictly longer chain
        r2 = net.nodes[1].mine_block("classic")
        res = net.broadcast(1, r2.record.to_block(), r2)
        assert res.accepted_by == [1, 0]
        assert net.converged()
        assert net.heights == [2, 2]
        # node 0's own fork block (and its credits) were discarded
        assert net.nodes[0].book.total_issued == \
            net.nodes[1].book.total_issued
        books = [sorted(n.book.balances.items()) for n in net.nodes]
        assert books[0] == books[1]
        assert r0.record.block_hash not in \
            [b.block_hash for b in net.nodes[0].ledger.blocks]
        assert issued_before == 50.0  # fork block had minted before adopt

    def test_corrupted_payload_rejected_no_credit(self):
        """A node broadcasting a tampered payload is rejected by peers
        (bit-exact re-verification fails) and earns no credit there."""
        net = two_node_network()
        net.nodes[0].submit(small_collatz())
        receipt = net.nodes[0].mine_block("full")

        # tamper: claim different results (inflate one res word)
        full = receipt.payload.full
        bad_results = full.results.copy()
        bad_results[0, 0] ^= 0x1
        bad_full = dataclasses.replace(full, results=bad_results)
        bad_payload = dataclasses.replace(receipt.payload, full=bad_full)
        blk = receipt.record.to_block()

        assert not net.nodes[1].receive(blk, bad_payload)
        assert net.nodes[1].ledger.height == 0
        assert net.nodes[1].book.total_issued == 0.0
        assert net.nodes[1].book.balances == {}

        # a tampered merkle root is equally rejected (header/payload
        # mismatch) even with untouched results
        bad_root = dataclasses.replace(
            receipt.payload, merkle_root="00" * 32)
        assert not net.nodes[1].receive(blk, bad_root)

        # reward-determining fields are enforced too: an inflated
        # block_reward (consensus parameter) and a stolen origin lane
        # (sender attribution) both mint nothing
        greedy = dataclasses.replace(receipt.payload, block_reward=1e9)
        assert not net.nodes[1].receive(blk, greedy, origin=0)
        stolen = dataclasses.replace(receipt.payload, origin=1)
        assert not net.nodes[1].receive(blk, stolen, origin=0)
        assert net.nodes[1].book.total_issued == 0.0

        # the honest payload is accepted by the same peer
        assert net.nodes[1].receive(blk, receipt.payload, origin=0)
        assert net.nodes[1].ledger.height == 1

    def test_optimal_winner_lane_enforced(self):
        """A consistent header+payload crediting another node's miner
        lane is still rejected by the workload's lane check."""
        from repro.chain.workload import MINER_LANE

        net = two_node_network()
        net.nodes[0].submit(small_collatz())
        receipt = net.nodes[0].mine_block("optimal")
        stolen_winner = MINER_LANE + 7          # node 1's lane
        bad_payload = dataclasses.replace(receipt.payload,
                                          winner=stolen_winner)
        bad_blk = dataclasses.replace(receipt.record,
                                      winner=stolen_winner).to_block()
        assert not net.nodes[1].receive(bad_blk, bad_payload, origin=0)
        assert net.nodes[1].book.total_issued == 0.0

    def test_fork_discarding_training_block_rewinds_trainer(self):
        """Adopting a chain that drops a locally-mined training block
        must rewind the trainer too, or the node's future training
        blocks are unverifiable by every peer."""
        net = two_node_network()
        net.nodes[0].mine_block("training")         # private fork block
        net.nodes[1].mine_block("classic")
        r = net.nodes[1].mine_block("classic")
        res = net.broadcast(1, r.record.to_block(), r)
        assert res.accepted_by == [1, 0]
        assert net.converged() and net.heights == [2, 2]
        assert net.nodes[0].workloads["training"].trainer.ledger.height == 0
        # the rewound node can mine training blocks the network accepts
        res2 = net.mine(0, "training")
        assert not res2.rejected_by
        assert net.converged() and net.heights == [3, 3]

    def test_forged_jash_id_rejected(self):
        """A consistent header+payload pair claiming a different jash id
        than the evidence jash must not enter any peer's ledger."""
        net = two_node_network()
        receipt = net.nodes[0].mine_block("classic")
        fake = "deadbeef" * 2
        bad_payload = dataclasses.replace(receipt.payload, jash_id=fake)
        bad_blk = dataclasses.replace(receipt.record,
                                      jash_id=fake).to_block()
        assert not net.nodes[1].receive(bad_blk, bad_payload, origin=0)
        assert net.nodes[1].ledger.height == 0

    def test_corrupted_training_digest_rejected_and_rolled_back(self):
        net = two_node_network()
        receipt = net.nodes[0].mine_block("training")
        bad = dataclasses.replace(receipt.payload,
                                  state_digest="ab" * 32)
        blk_bad = dataclasses.replace(receipt.record,
                                      state_digest="ab" * 32,
                                      merkle_root=receipt.record.merkle_root
                                      ).to_block()
        peer_wl = net.nodes[1].workloads["training"]
        assert not net.nodes[1].receive(blk_bad, bad)
        # the failed verify rolled the peer's trainer back — including
        # its internal credit book (no minting for rejected blocks)
        assert peer_wl.trainer.ledger.height == 0
        assert peer_wl.trainer.book.total_issued == 0.0
        # and the honest block still verifies afterwards
        assert net.nodes[1].receive(receipt.record.to_block(),
                                    receipt.payload)
        assert peer_wl.trainer.ledger.height == 1


# ---------------------------------------------------------------------------
# Node facade
# ---------------------------------------------------------------------------


class TestNode:
    def test_default_policy_full_then_classic_fallback(self):
        node = Node(classic_arg_bits=6)
        node.submit(small_collatz())
        modes = [node.mine_block().record.workload for _ in range(3)]
        assert modes == ["full", "classic", "classic"]
        s = node.state()
        assert s.height == 3 and s.chain_valid
        assert np.isclose(s.total_issued, 3 * 50.0)
        assert all(node.audit(h) for h in range(3))

    def test_mine_block_returns_typed_records(self):
        node = Node(classic_arg_bits=6)
        receipt = node.mine_block()
        assert isinstance(receipt.record, BlockRecord)
        assert receipt.record.workload == "classic"
        assert receipt.record.to_block().block_hash == \
            receipt.record.block_hash
        assert receipt.rewards and receipt.block_time_s > 0

    def test_optimal_workload_explicit(self):
        node = Node(classic_arg_bits=6)
        node.submit(small_collatz())
        receipt = node.mine_block("optimal")
        assert receipt.record.workload == "optimal"
        assert receipt.record.winner is not None
        assert receipt.record.best_res
        assert node.audit(0)

    def test_unknown_workload_raises(self):
        node = Node()
        with pytest.raises(ChainError, match="unknown workload"):
            node.mine_block("espresso")

    def test_explicit_jash_workload_empty_queue_raises(self):
        """An explicit full/optimal request must not silently degrade to
        a classic block (whose payload has no FullResult)."""
        node = Node(classic_arg_bits=6)
        with pytest.raises(ChainError, match="queue is empty"):
            node.mine_block("full")
        with pytest.raises(ChainError, match="queue is empty"):
            node.mine_block("optimal")
        # default policy still falls back to classic (§3.4)
        assert node.mine_block().record.workload == "classic"

    def test_training_block_honors_node_reward(self):
        node = Node(block_reward=100.0,
                    workloads={"training": training_workload()})
        receipt = node.mine_block("training")
        assert receipt.payload.block_reward == 100.0
        assert np.isclose(node.book.total_issued, 100.0)

    def test_failed_self_verify_requeues_jash(self):
        """A mined block that fails self-verification must not cost the
        researcher their queued submission."""
        class _Paranoid(JashFullWorkload):
            def verify(self, payload):
                return False

        node = Node(classic_arg_bits=6)
        node.workloads["full"] = _Paranoid()
        node.submit(small_collatz())
        with pytest.raises(ChainError, match="failed"):
            node.mine_block("full")
        assert node.ra.queue_depth == 1
        assert node.ledger.height == 0
        # the requeued jash mines fine once the workload behaves
        node.workloads["full"] = JashFullWorkload()
        assert node.mine_block().record.workload == "full"

    def test_network_create_rejects_shared_workloads(self):
        with pytest.raises(ValueError, match="node_factory"):
            Network.create(2, workloads={"training": training_workload()})

    def test_target_block_s_without_work_raises(self):
        with pytest.raises(ValueError, match="work"):
            Node(target_block_s=1.0)

    def test_difficulty_integration_adjusts_work(self):
        node = Node(classic_arg_bits=10, target_block_s=1e-9, work=512)
        node.mine_block("classic")
        first_work = 512
        node.mine_block("classic")
        # a nanosecond target against real block times must shrink work
        assert node.work < first_work
        # work target caps the mined arg space via meta.max_arg (§3.1)
        assert node.chain_payloads()[1].jash.meta.n_args <= first_work

    def test_workload_protocol_runtime_checkable(self):
        for wl in (JashFullWorkload(), JashOptimalWorkload(),
                   ClassicSha256Workload(), training_workload()):
            assert isinstance(wl, Workload)

    def test_public_surface(self):
        import repro
        import repro.chain as chain
        assert set(repro.__all__) == {"BlockRecord", "Network", "Node",
                                      "Workload"}
        for name in chain.__all__:
            assert getattr(chain, name) is not None
        import repro.core as core
        for name in core.__all__:
            assert getattr(core, name) is not None
