"""Difficulty/work retargeting (§3.1 granularity, §5 limitation)."""
import numpy as np
import pytest

try:                 # property tests skip cleanly without hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.difficulty import DifficultyController, work_for_runtime


class TestController:
    def test_converges_toward_target(self):
        """Simulated miner: block time proportional to work.  The
        controller must drive block time to the target."""
        ctrl = DifficultyController(target_block_s=1.0, min_work=1)
        work = 10_000
        per_arg = 1.0 / 2_500                 # true miner speed
        for _ in range(20):
            dt = work * per_arg
            ctrl.observe(dt)
            work = ctrl.next_work(work)
        assert abs(work * per_arg - 1.0) < 0.25

    def test_retarget_clipped_to_4x(self):
        ctrl = DifficultyController(target_block_s=100.0)
        ctrl.observe(0.001)                    # wildly fast block
        assert ctrl.next_work(1000) <= 4000

    def test_no_observation_no_change(self):
        ctrl = DifficultyController(target_block_s=1.0)
        assert ctrl.next_work(123) == 123

    def test_first_proposal_unchanged_regression(self):
        """Before any observe() there is nothing to retarget against:
        propose_work must hand the current work back unchanged, for any
        bounds configuration."""
        ctrl = DifficultyController(target_block_s=1.0, min_work=4096,
                                    max_work=1 << 22)
        assert ctrl.ema_block_s is None
        assert ctrl.propose_work(123) == 123       # below min_work: no clamp
        assert ctrl.propose_work(1 << 30) == 1 << 30

    def test_ema_seeds_from_warmup_mean(self):
        """The EMA seed is the mean of the first ``seed_samples``
        observations — a single outlier first block (cold compile) no
        longer locks in with full weight."""
        ctrl = DifficultyController(target_block_s=1.0, seed_samples=4)
        for dt in (4.0, 2.0, 1.0, 1.0):
            ctrl.observe(dt)
        assert ctrl.ema_block_s == pytest.approx(2.0)
        # past the seed window the usual EMA recurrence applies — fed a
        # sample distinct from the warmup mean so a controller stuck in
        # the seed phase (running mean 2.4) would fail here
        ctrl.observe(4.0)
        assert ctrl.ema_block_s == pytest.approx(0.7 * 2.0 + 0.3 * 4.0)

    def test_seed_samples_validated(self):
        with pytest.raises(ValueError, match="seed_samples"):
            DifficultyController(target_block_s=1.0, seed_samples=0)

    def test_outlier_first_sample_diluted(self):
        seeded = DifficultyController(target_block_s=1.0, seed_samples=4)
        single = DifficultyController(target_block_s=1.0, seed_samples=1)
        for c in (seeded, single):
            c.observe(100.0)                       # cold-compile outlier
            c.observe(1.0)
        assert seeded.ema_block_s == pytest.approx(50.5)   # running mean
        assert single.ema_block_s == pytest.approx(0.7 * 100.0 + 0.3 * 1.0)

    def test_next_work_alias(self):
        ctrl = DifficultyController(target_block_s=1.0)
        ctrl.observe(2.0)
        assert ctrl.next_work(1000) == ctrl.propose_work(1000)


if given is not None:
    class TestControllerProperties:
        @given(st.floats(0.001, 100.0), st.integers(1, 1 << 20))
        @settings(max_examples=40, deadline=None)
        def test_work_stays_in_bounds(self, block_time, work):
            ctrl = DifficultyController(target_block_s=1.0, min_work=4,
                                        max_work=1 << 22)
            ctrl.observe(block_time)
            new = ctrl.next_work(work)
            assert 4 <= new <= 1 << 22


class TestInitialSizing:
    def test_work_for_runtime(self):
        # 1 ms/arg, 1 s target, 256 miners, 0.9 safety -> ~230k args
        w = work_for_runtime(1e-3, 1.0, 256)
        assert 200_000 < w < 256_000

    def test_degenerate_runtime(self):
        assert work_for_runtime(0.0, 1.0, 8) == 1
