"""Difficulty/work retargeting (§3.1 granularity, §5 limitation)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.difficulty import DifficultyController, work_for_runtime


class TestController:
    def test_converges_toward_target(self):
        """Simulated miner: block time proportional to work.  The
        controller must drive block time to the target."""
        ctrl = DifficultyController(target_block_s=1.0, min_work=1)
        work = 10_000
        per_arg = 1.0 / 2_500                 # true miner speed
        for _ in range(20):
            dt = work * per_arg
            ctrl.observe(dt)
            work = ctrl.next_work(work)
        assert abs(work * per_arg - 1.0) < 0.25

    def test_retarget_clipped_to_4x(self):
        ctrl = DifficultyController(target_block_s=100.0)
        ctrl.observe(0.001)                    # wildly fast block
        assert ctrl.next_work(1000) <= 4000

    @given(st.floats(0.001, 100.0), st.integers(1, 1 << 20))
    @settings(max_examples=40, deadline=None)
    def test_work_stays_in_bounds(self, block_time, work):
        ctrl = DifficultyController(target_block_s=1.0, min_work=4,
                                    max_work=1 << 22)
        ctrl.observe(block_time)
        new = ctrl.next_work(work)
        assert 4 <= new <= 1 << 22

    def test_no_observation_no_change(self):
        ctrl = DifficultyController(target_block_s=1.0)
        assert ctrl.next_work(123) == 123


class TestInitialSizing:
    def test_work_for_runtime(self):
        # 1 ms/arg, 1 s target, 256 miners, 0.9 safety -> ~230k args
        w = work_for_runtime(1e-3, 1.0, 256)
        assert 200_000 < w < 256_000

    def test_degenerate_runtime(self):
        assert work_for_runtime(0.0, 1.0, 8) == 1
