"""Doc-consistency: docs/api.md covers every exported name, README and
api.md code blocks actually execute (the same checks
``scripts/check_docs.py`` runs in CI — kept in tier-1 so a doc drift
fails fast locally too)."""
import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_api_md_covers_all_exports():
    problems = _load_checker().check_api_coverage()
    assert not problems, "\n".join(problems)


def test_readme_python_blocks_execute():
    problems = _load_checker().run_readme_blocks()
    assert not problems, "\n".join(problems)


def test_api_md_snippets_execute():
    """Every ```python block of docs/api.md runs, in order, in one
    shared namespace (the first block defines the shared ``small_jash``
    helper the entries use)."""
    text = (REPO / "docs" / "api.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert len(blocks) > 40          # one per documented entry, roughly
    ns = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"<api.md block {i}>", "exec"), ns)
        except Exception as e:       # noqa: BLE001
            raise AssertionError(
                f"docs/api.md python block {i} failed "
                f"({type(e).__name__}: {e}):\n{block}") from e


def test_workloads_md_snippets_execute():
    """The authoring guide's blocks — including the minimal-workload
    implementation mined on a 2-node network — run in order in one
    shared namespace, exactly as ``scripts/check_docs.py`` runs them in
    CI."""
    mod = _load_checker()
    problems = mod.run_md_blocks(REPO / "docs" / "workloads.md")
    assert not problems, "\n".join(problems)


def test_every_doc_is_claimed_by_a_check():
    """docs/*.md files must be claimed by DOC_CHECKS — a doc nothing
    executes or cross-checks rots silently."""
    problems = _load_checker().check_docs_coverage()
    assert not problems, "\n".join(problems)


def test_readme_documents_classic_fallback():
    """The §3.4 classic fallback must stay documented in the README
    workload table (it is the default-policy behavior users hit first)."""
    text = (REPO / "README.md").read_text()
    assert "| `classic` | §3.4 |" in text
    assert "default-policy fallback" in text
