"""Dry-run machinery smoke test on a small host mesh (subprocess so the
XLA device-count flag doesn't leak into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, reduced, InputShape
    from repro.core.compat import cost_analysis_dict
    from repro.launch.dryrun import build_step, shardings_for
    from repro.launch.hlo_analysis import collective_bytes
    from repro.sharding.partition import use_rules

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    results = {}
    for arch, shape in [("qwen3-0.6b", InputShape("t", 64, 8, "train")),
                        ("olmoe-1b-7b", InputShape("d", 64, 8, "decode")),
                        ("rwkv6-7b", InputShape("p", 64, 8, "prefill"))]:
        cfg = reduced(get_config(arch))
        step, args_sds, kind = build_step(cfg, shape)
        in_sh, out_sh, donate = shardings_for(kind, args_sds, mesh, shape)
        with use_rules(mesh):
            compiled = jax.jit(step, in_shardings=in_sh,
                               out_shardings=out_sh,
                               donate_argnums=donate
                               ).lower(*args_sds).compile()
        coll = collective_bytes(compiled.as_text())
        results[arch] = {
            "flops": cost_analysis_dict(compiled.cost_analysis())
                     .get("flops", 0.0),
            "coll": coll["_total_bytes"],
        }
    print("RESULT:" + json.dumps(results))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, out.stdout
    results = json.loads(line[0][len("RESULT:"):])
    assert set(results) == {"qwen3-0.6b", "olmoe-1b-7b", "rwkv6-7b"}
    for arch, r in results.items():
        assert r["flops"] > 0
        # a 2x4 sharded train/serve step must communicate something
    assert results["qwen3-0.6b"]["coll"] > 0


def test_hlo_collective_parser_units():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = textwrap.dedent("""\
        HloModule test

        %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
          %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%add
          ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
        }

        ENTRY %main () -> f32[8] {
          %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
          %ag = f32[64]{0} all-gather(%y), dimensions={0}
          ROOT %out = f32[8] get-tuple-element(%w), index=1
        }
    """)
    res = collective_bytes(hlo)
    assert res["all-reduce"]["bytes"] == 8 * 4 * 12      # looped x12
    assert res["all-gather"]["bytes"] == 64 * 4
