"""Executor (full/optimal), verification, and RA review pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.authority import RuntimeAuthority, classic_jash
from repro.core.executor import run_full, run_optimal
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.ledger import merkle_root
from repro.core.verify import quorum_verify, verify_inclusion
from repro.kernels import ref


def _docking_jash(n_r=8, n_p=8):
    """The §4 use case: deterministic score per (receptor, peptide) pair,
    2-bit result {01 binds, 00 no-bind, 10 non-terminated}."""
    def fn(b):
        n_rr = jnp.uint32(n_r)
        r = b % n_rr
        p = b // n_rr
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 200, jnp.uint32(0b01), jnp.uint32(0b00))
    return Jash("dock", fn, JashMeta(arg_bits=6, res_bits=2,
                                     max_arg=n_r * n_p),
                example_args=(jnp.uint32(0),))


class TestFullMode:
    def test_matches_bruteforce(self):
        j = _docking_jash()
        fr = run_full(j)
        fn = jax.jit(j.fn)
        for i in range(0, 64, 7):
            assert int(fr.results[i, 0]) == int(fn(jnp.uint32(i)))

    def test_hashes_are_sha256_of_arg_res(self):
        j = _docking_jash()
        fr = run_full(j)
        msg = np.concatenate([fr.args[:, None], fr.results], axis=1)
        want = ref.sha256_words_hashlib(msg.astype(np.uint32))
        np.testing.assert_array_equal(fr.hashes, want)

    def test_respects_max_arg(self):
        j = _docking_jash(n_r=5, n_p=3)
        fr = run_full(j)
        assert len(fr.args) == 15


class TestOptimalMode:
    def test_finds_global_min(self):
        def fn(a):
            # V-shaped: minimum at arg=37
            return jnp.abs(a.astype(jnp.int32) - 37).astype(jnp.uint32)
        j = Jash("vee", fn, JashMeta(arg_bits=7, res_bits=32),
                 example_args=(jnp.uint32(0),))
        opt = run_optimal(j)
        assert opt.best_arg == 37
        assert int(opt.best_res[0]) == 0

    def test_leading_zero_semantics_on_hash(self):
        """Optimal over sha256 == the arg whose digest is lexicographically
        smallest (Bitcoin's 'most leading zeros')."""
        j = classic_jash(arg_bits=8)
        opt = run_optimal(j)
        msgs = np.stack([np.arange(256, dtype=np.uint32),
                         np.full(256, 0x504e5043, np.uint32)], axis=1)
        digests = ref.sha256_words_hashlib(
            ref.sha256_words_hashlib(msgs))
        keys = [tuple(d) for d in digests]
        assert opt.best_arg == int(np.lexsort(
            np.stack([digests[:, 1], digests[:, 0]])[::-1])[0]) or \
            keys[opt.best_arg] == min(keys)


class TestVerification:
    def test_quorum_passes_honest(self):
        j = _docking_jash()
        fr = run_full(j)
        assert quorum_verify(j, fr, fraction=0.5).ok

    def test_quorum_catches_forged_result(self):
        import dataclasses
        j = _docking_jash()
        fr = run_full(j)
        forged = fr.results.copy()
        forged[5] ^= 1                          # forge one submission
        fr = dataclasses.replace(fr, results=forged)
        rep = quorum_verify(j, fr, fraction=1.0)
        assert not rep.ok
        assert 5 in rep.mismatched_args

    def test_merkle_inclusion(self):
        j = _docking_jash()
        fr = run_full(j)
        root = merkle_root(fr.merkle_leaves)
        assert verify_inclusion(fr, 7, root)
        assert not verify_inclusion(fr, 7, "00" * 32)


class TestMultiLane:
    """lanes=k partitions the arg space over k single-device miner
    lanes in one vmapped dispatch; the mined bits must be identical to
    lanes=1 (that is what lets a single-lane verifier audit a
    multi-lane miner)."""

    def _mix_jash(self, arg_bits=8):
        def fn(a):
            return (a * jnp.uint32(2654435761)) ^ jnp.uint32(0xDEADBEEF)
        return Jash("mix", fn, JashMeta(arg_bits=arg_bits, res_bits=32),
                    example_args=(jnp.uint32(0),))

    def test_full_mode_bit_identical_across_lane_counts(self):
        j = self._mix_jash()
        base = run_full(j)
        for lanes in (2, 3, 4, 8):
            fr = run_full(j, lanes=lanes)
            np.testing.assert_array_equal(fr.results, base.results)
            np.testing.assert_array_equal(fr.hashes, base.hashes)
            np.testing.assert_array_equal(fr.leaf_digests,
                                          base.leaf_digests)
            np.testing.assert_array_equal(
                fr.miner_of, np.arange(256) % lanes)
            assert fr.commit_root() == base.commit_root()

    def test_optimal_mode_winner_lane_and_parity(self):
        j = self._mix_jash()
        base = run_optimal(j)
        for lanes in (2, 3, 7, 256, 300):
            opt = run_optimal(j, lanes=lanes)
            assert opt.best_arg == base.best_arg
            np.testing.assert_array_equal(opt.best_res, base.best_res)
            # winner == the contiguous lane slice holding best_arg
            eff = min(lanes, 256)
            width = (256 + (-256 % eff)) // eff
            assert opt.winner == base.best_arg // width

    def test_optimal_first_occurrence_tie_break_survives_lanes(self):
        # constant jash: every arg ties; the winner must stay arg 0 in
        # lane 0 for every lane count (contiguous lanes preserve the
        # global first-occurrence)
        def fn(a):
            return jnp.uint32(7) + jnp.uint32(0) * a
        j = Jash("const", fn, JashMeta(arg_bits=5, res_bits=32),
                 example_args=(jnp.uint32(0),))
        for lanes in (1, 2, 4, 32):
            opt = run_optimal(j, lanes=lanes)
            assert opt.best_arg == 0 and opt.winner == 0

    def test_lanes_and_mesh_are_mutually_exclusive(self):
        import jax as _jax
        from jax.sharding import Mesh
        mesh = Mesh(np.array(_jax.devices()[:1]), ("data",))
        j = self._mix_jash()
        with pytest.raises(ValueError, match="lanes"):
            run_full(j, mesh=mesh, lanes=2)
        with pytest.raises(ValueError, match="lanes"):
            run_optimal(j, mesh=mesh, lanes=2)

    def test_invalid_lanes_rejected(self):
        j = self._mix_jash()
        with pytest.raises(ValueError, match="lanes"):
            run_full(j, lanes=0)
        with pytest.raises(ValueError, match="lanes"):
            run_optimal(j, lanes=-1)


class TestRuntimeAuthority:
    def test_review_and_priority_order(self):
        ra = RuntimeAuthority()
        cheap = _docking_jash()
        costly = collatz_jash(max_steps=4096)
        r1 = ra.submit(costly)
        r2 = ra.submit(cheap)
        assert r1.compiled and r2.compiled
        assert ra.queue_depth == 2

    def test_veto_blocks_publication(self):
        ra = RuntimeAuthority()
        ra.submit(_docking_jash(), veto=True)
        jash, src = ra.publish_next()
        assert src == "classic"                  # queue empty -> §3.4

    def test_classic_fallback_is_double_sha(self):
        j = classic_jash()
        out = jax.jit(j.fn)(jnp.uint32(7))
        msg = np.array([[7, 0x504e5043]], np.uint32)
        want = ref.sha256_words_hashlib(ref.sha256_words_hashlib(msg))
        np.testing.assert_array_equal(np.asarray(out), want[0])
