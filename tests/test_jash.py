"""Jash validation: the paper's §3 requirements as executable checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.jash import (Jash, JashMeta, JashValidationError,
                             bounded_while, collatz_jash)


def _collatz_py(n: int, max_steps: int = 1024):
    steps = 0
    while n != 1 and steps < max_steps:
        n = n // 2 if n % 2 == 0 else 3 * n + 1
        steps += 1
    return steps if n == 1 else max_steps


class TestValidation:
    def test_rejects_unbounded_while(self):
        def bad(x):
            return jax.lax.while_loop(lambda s: s < x, lambda s: s + 1,
                                      jnp.uint32(0))
        j = Jash("bad", bad, JashMeta(32, 32),
                 example_args=(jnp.uint32(5),))
        with pytest.raises(JashValidationError):
            j.validate()

    def test_rejects_nested_unbounded_while(self):
        def bad(x):
            def outer(i, acc):
                return acc + jax.lax.while_loop(
                    lambda s: s < x, lambda s: s + 1, jnp.uint32(0))
            return jax.lax.fori_loop(0, 4, outer, jnp.uint32(0))
        j = Jash("bad-nested", bad, JashMeta(32, 32),
                 example_args=(jnp.uint32(5),))
        with pytest.raises(JashValidationError):
            j.validate()

    def test_accepts_bounded_forms(self):
        def good(x):
            def body(i, acc):
                return acc * jnp.uint32(3) + x
            acc = jax.lax.fori_loop(0, 16, body, jnp.uint32(1))
            ys = jax.lax.scan(lambda c, _: (c + x, c), acc,
                              None, length=8)[0]
            return jax.lax.cond(x > 0, lambda: ys, lambda: acc)
        Jash("good", good, JashMeta(32, 32),
             example_args=(jnp.uint32(5),)).validate()

    def test_rejects_over_long_scan(self):
        def long_loop(x):
            return jax.lax.scan(lambda c, _: (c + x, None), x,
                                None, length=4096)[0]
        j = Jash("long", long_loop, JashMeta(32, 32),
                 example_args=(jnp.uint32(1),))
        with pytest.raises(JashValidationError):
            j.validate(loop_bound=1024)

    def test_collatz_passes(self):
        collatz_jash().validate()

    def test_source_id_stable(self):
        a, b = collatz_jash(), collatz_jash()
        assert a.source_id() == b.source_id()


class TestBoundedWhile:
    @given(st.integers(min_value=1, max_value=5000))
    @settings(max_examples=30, deadline=None)
    def test_collatz_matches_python(self, n):
        fn = jax.jit(collatz_jash(max_steps=1024).fn)
        assert int(fn(jnp.uint32(n))) == _collatz_py(n)

    def test_nontermination_flag(self):
        # cond never satisfied within the bound
        state, done = bounded_while(
            lambda s: s < 100, lambda s: s + 1, jnp.int32(0), max_steps=10)
        assert not bool(done)
        assert int(state) == 10

    def test_early_termination_freezes_state(self):
        state, done = bounded_while(
            lambda s: s < 3, lambda s: s + 1, jnp.int32(0), max_steps=50)
        assert bool(done)
        assert int(state) == 3
