"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes; SHA-256 additionally vs hashlib."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


class TestSha256:
    @pytest.mark.parametrize("n,w", [(1, 1), (7, 4), (128, 12), (200, 13),
                                     (64, 14), (16, 20), (3, 32)])
    def test_vs_hashlib(self, n, w):
        msg = np.random.RandomState(n * 31 + w).randint(
            0, 2**32, (n, w), dtype=np.uint32)
        gt = ref.sha256_words_hashlib(msg)
        got_jnp = np.asarray(ops.sha256_words(jnp.asarray(msg),
                                              backend="jnp"))
        got_pl = np.asarray(ops.sha256_words(jnp.asarray(msg),
                                             backend="pallas"))
        np.testing.assert_array_equal(got_jnp, gt)
        np.testing.assert_array_equal(got_pl, gt)

    def test_empty_words_vector(self):
        # known vector: sha256 of 4 zero bytes
        import hashlib
        msg = np.zeros((1, 1), np.uint32)
        want = np.frombuffer(hashlib.sha256(b"\x00" * 4).digest(), ">u4")
        got = np.asarray(ops.sha256_words(jnp.asarray(msg)))
        np.testing.assert_array_equal(got[0], want.astype(np.uint32))

    def test_deterministic_across_jit(self):
        msg = jnp.arange(24, dtype=jnp.uint32).reshape(2, 12)
        a = ops.sha256_words(msg)
        b = jax.jit(lambda m: ops.sha256_words(m))(msg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDecayScan:
    @pytest.mark.parametrize("shape", [(1, 4, 8), (2, 37, 130), (3, 64, 256),
                                       (1, 128, 129)])
    @pytest.mark.parametrize("dtype", [np.float32])
    def test_vs_ref(self, shape, dtype):
        B, S, C = shape
        rs = np.random.RandomState(sum(shape))
        a = jnp.asarray(rs.uniform(0.3, 1.0, shape).astype(dtype))
        b = jnp.asarray(rs.normal(size=shape).astype(dtype))
        h0 = jnp.asarray(rs.normal(size=(B, C)).astype(dtype))
        got, gotT = ops.decay_scan(a, b, h0, backend="pallas", seq_chunk=16)
        want = ref.decay_scan_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gotT),
                                   np.asarray(want[:, -1]),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_sequential_python(self):
        B, S, C = 1, 9, 3
        rs = np.random.RandomState(0)
        a = rs.uniform(0.1, 0.9, (B, S, C)).astype(np.float32)
        b = rs.normal(size=(B, S, C)).astype(np.float32)
        h = np.zeros((B, C), np.float32)
        outs = []
        for t in range(S):
            h = a[:, t] * h + b[:, t]
            outs.append(h.copy())
        want = np.stack(outs, axis=1)
        got = ref.decay_scan_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-6)

    def test_chunk_carry_equivalence(self):
        """Chunked execution with h0 carry == single call (ops contract)."""
        B, S, C = 2, 32, 16
        rs = np.random.RandomState(3)
        a = jnp.asarray(rs.uniform(0.3, 1.0, (B, S, C)).astype(np.float32))
        b = jnp.asarray(rs.normal(size=(B, S, C)).astype(np.float32))
        full = ref.decay_scan_ref(a, b)
        h1 = ref.decay_scan_ref(a[:, :16], b[:, :16])
        h2 = ref.decay_scan_ref(a[:, 16:], b[:, 16:], h0=h1[:, -1])
        np.testing.assert_allclose(np.asarray(full[:, 16:]),
                                   np.asarray(h2), rtol=1e-5, atol=1e-5)


class TestWkv6:
    @pytest.mark.parametrize("shape", [(1, 5, 1, 4, 4), (2, 19, 3, 8, 8),
                                       (1, 33, 2, 16, 16)])
    def test_vs_ref(self, shape):
        B, S, H, K, V = shape
        rs = np.random.RandomState(sum(shape))
        mk = lambda *s: jnp.asarray(rs.normal(size=s).astype(np.float32))
        r, k = mk(B, S, H, K), mk(B, S, H, K)
        w = jax.nn.sigmoid(mk(B, S, H, K)) * 0.5 + 0.5
        v = mk(B, S, H, V)
        u = mk(H, K)
        s0 = mk(B, H, K, V)
        got_o, got_s = ops.wkv6(r, k, v, w, u, s0, backend="pallas",
                                seq_chunk=7)
        want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=1e-4, atol=1e-4)

    def test_recurrence_semantics(self):
        """One step by hand: o_0 = r (s0 + (u*k) v^T), s_1 = w*s0 + k v^T."""
        B, S, H, K, V = 1, 1, 1, 3, 2
        rs = np.random.RandomState(7)
        r = rs.normal(size=(B, S, H, K)).astype(np.float32)
        k = rs.normal(size=(B, S, H, K)).astype(np.float32)
        v = rs.normal(size=(B, S, H, V)).astype(np.float32)
        w = rs.uniform(0.5, 1.0, (B, S, H, K)).astype(np.float32)
        u = rs.normal(size=(H, K)).astype(np.float32)
        s0 = rs.normal(size=(B, H, K, V)).astype(np.float32)
        o, sT = ref.wkv6_ref(*map(jnp.asarray, (r, k, v, w, u, s0)))
        kv = np.einsum("k,v->kv", k[0, 0, 0], v[0, 0, 0])
        want_o = np.einsum("k,kv->v", r[0, 0, 0],
                           s0[0, 0] + u[0][:, None] * kv)
        want_s = w[0, 0, 0][:, None] * s0[0, 0] + kv
        np.testing.assert_allclose(np.asarray(o)[0, 0, 0], want_o, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(sT)[0, 0], want_s, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [(1, 32, 32, 2, 1, 8),
                                       (2, 64, 64, 4, 2, 16),
                                       (1, 48, 48, 3, 3, 8)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_chunked_ref(self, shape, causal):
        from repro.models.attention import chunked_attention
        B, S, T, H, Kv, hd = shape
        rs = np.random.RandomState(sum(shape))
        q = jnp.asarray(rs.normal(size=(B, S, H, hd)).astype(np.float32))
        k = jnp.asarray(rs.normal(size=(B, T, Kv, hd)).astype(np.float32))
        v = jnp.asarray(rs.normal(size=(B, T, Kv, hd)).astype(np.float32))
        got = ops.flash_attention(q, k, v, causal=causal, backend="pallas",
                                  bq=16, bk=16)
        want = chunked_attention(q, k, v, causal=causal, chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_online_softmax_stability(self):
        """Large score magnitudes must not overflow (the online-max)."""
        B, S, H, hd = 1, 32, 1, 8
        q = jnp.full((B, S, H, hd), 30.0)
        k = jnp.full((B, S, H, hd), 30.0)
        v = jnp.ones((B, S, H, hd))
        out = ops.flash_attention(q, k, v, causal=True, backend="pallas",
                                  bq=8, bk=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)
