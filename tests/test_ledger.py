"""Ledger/Merkle/reward invariants (property-based)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ledger import (Ledger, merkle_proof, merkle_root,
                               verify_merkle_proof)
from repro.core.rewards import CreditBook, reward_full, reward_optimal

leaves_st = st.lists(st.binary(min_size=1, max_size=40), min_size=1,
                     max_size=40)


class TestMerkle:
    @given(leaves_st)
    @settings(max_examples=40, deadline=None)
    def test_all_proofs_verify(self, leaves):
        root = merkle_root(leaves)
        for i in range(len(leaves)):
            proof = merkle_proof(leaves, i)
            assert verify_merkle_proof(leaves[i], proof, root)

    @given(leaves_st, st.data())
    @settings(max_examples=40, deadline=None)
    def test_tampered_leaf_fails(self, leaves, data):
        root = merkle_root(leaves)
        i = data.draw(st.integers(0, len(leaves) - 1))
        proof = merkle_proof(leaves, i)
        tampered = leaves[i] + b"x"
        assert not verify_merkle_proof(tampered, proof, root)

    @given(leaves_st)
    @settings(max_examples=20, deadline=None)
    def test_root_order_sensitive(self, leaves):
        rev = list(reversed(leaves))
        if rev == leaves:                       # palindromes are invariant
            return
        assert merkle_root(leaves) != merkle_root(rev)


class TestLedger:
    def _mk(self, n=5):
        led = Ledger()
        for i in range(n):
            led.append(jash_id=f"j{i}", mode="full",
                       merkle=merkle_root([bytes([i])]), winner=None,
                       best_res=None, n_results=1, state_digest=f"d{i}")
        return led

    def test_chain_verifies(self):
        assert self._mk().verify_chain()

    def test_tampered_block_detected(self):
        led = self._mk()
        import dataclasses
        led.blocks[2] = dataclasses.replace(led.blocks[2],
                                            state_digest="forged")
        assert not led.verify_chain()

    def test_heights_sequential(self):
        led = self._mk(7)
        assert [b.height for b in led.blocks] == list(range(7))


class TestRewards:
    @given(st.lists(st.integers(0, 15), min_size=1, max_size=100),
           st.floats(1.0, 1000.0, allow_nan=False))
    @settings(max_examples=40, deadline=None)
    def test_full_mode_conservation(self, submitters, reward):
        """Sum of credits == block reward (the coin is conserved)."""
        book = CreditBook()
        reward_full(book, submitters, reward)
        assert np.isclose(book.total_issued, reward)
        assert np.isclose(sum(book.balances.values()), reward)

    @given(st.lists(st.integers(0, 15), min_size=1, max_size=60),
           st.floats(1.0, 100.0, allow_nan=False),
           st.integers(0, 15))
    @settings(max_examples=40, deadline=None)
    def test_full_mode_with_bonus_conserves(self, submitters, reward, bonus):
        book = CreditBook()
        reward_full(book, submitters, reward, bonus_winner=bonus)
        assert np.isclose(book.total_issued, reward)

    def test_optimal_winner_takes_all(self):
        book = CreditBook()
        reward_optimal(book, 3, 50.0)
        assert book.balances == {3: 50.0}

    @given(st.lists(st.integers(0, 7), min_size=1, max_size=50))
    @settings(max_examples=30, deadline=None)
    def test_full_mode_proportional(self, submitters):
        """Each miner's credit is proportional to args it submitted first."""
        book = CreditBook()
        reward_full(book, submitters, 100.0)
        n = len(submitters)
        for m in set(submitters):
            share = submitters.count(m) / n * 100.0
            assert np.isclose(book.balances[m], share)
