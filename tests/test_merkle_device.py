"""Device block-commitment pipeline: Merkle parity vs the hashlib
reference, chunked executor bit-identity, and the scan-fused PoUW block."""
import hashlib
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import run_full, run_optimal
from repro.core.jash import Jash, JashMeta
from repro.core.ledger import (merkle_proof, merkle_root,
                               verify_merkle_proof)
from repro.kernels.merkle import (merkle_proof_device, merkle_root_device,
                                  merkle_root_from_digests, pack_leaves)


def _mix_jash(arg_bits=10):
    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(0xDEADBEEF)
    return Jash("mix", fn, JashMeta(arg_bits=arg_bits, res_bits=32),
                example_args=(jnp.uint32(0),))


class TestMerkleParity:
    # 100/300 cross the _CUTOVER boundary, exercising the device levels
    @pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 13, 31, 64, 100])
    def test_root_matches_hashlib_ragged(self, n):
        rng = random.Random(n)
        leaves = [rng.randbytes(rng.randint(1, 40)) for _ in range(n)]
        assert merkle_root_device(leaves) == \
            merkle_root(leaves, backend="hashlib")

    @pytest.mark.parametrize("n", [1, 4, 7, 33, 300])
    def test_root_matches_hashlib_uniform(self, n):
        rng = random.Random(n)
        leaves = [rng.randbytes(36) for _ in range(n)]
        assert pack_leaves(leaves) is not None       # device leaf path
        assert merkle_root_device(leaves) == \
            merkle_root(leaves, backend="hashlib")

    def test_empty_and_backend_switch(self):
        assert merkle_root([], backend="device") == \
            merkle_root([], backend="hashlib") == \
            hashlib.sha256(b"").hexdigest()
        leaves = [bytes([i % 256]) * 8 for i in range(300)]
        assert merkle_root(leaves) == merkle_root(leaves, backend="hashlib")

    @pytest.mark.parametrize("n", [2, 5, 8, 13, 100])
    def test_proof_roundtrip_against_device_root(self, n):
        rng = random.Random(100 + n)
        leaves = [rng.randbytes(rng.randint(1, 24)) for _ in range(n)]
        root = merkle_root_device(leaves)
        for i in range(n):
            proof = merkle_proof(leaves, i)
            assert proof == merkle_proof_device(leaves, i)
            assert verify_merkle_proof(leaves[i], proof, root)
            assert not verify_merkle_proof(leaves[i] + b"x", proof, root)


class TestProofEdgeCases:
    def _leaves(self, n, seed=0):
        rng = random.Random(seed)
        return [rng.randbytes(rng.randint(1, 24)) for _ in range(n)]

    def test_single_leaf_empty_proof(self):
        leaves = self._leaves(1)
        proof = merkle_proof_device(leaves, 0)
        assert proof == merkle_proof(leaves, 0) == []
        assert verify_merkle_proof(leaves[0], proof,
                                   merkle_root_device(leaves))

    @pytest.mark.parametrize("n", [3, 5, 7, 9])
    def test_duplicated_last_node_index(self, n):
        """Odd levels duplicate their last node; the proof for that last
        leaf must use the duplicate as its sibling and still verify —
        on device and hashlib identically."""
        leaves = self._leaves(n, seed=n)
        root = merkle_root_device(leaves)
        proof = merkle_proof_device(leaves, n - 1)
        assert proof == merkle_proof(leaves, n - 1)
        # level 0 sibling of the duplicated last node is itself
        assert proof[0]["hash"] == hashlib.sha256(leaves[-1]).hexdigest()
        assert verify_merkle_proof(leaves[-1], proof, root)

    def test_tampered_sibling_rejected(self):
        leaves = self._leaves(8, seed=42)
        root = merkle_root_device(leaves)
        for step in range(3):                    # every level of the proof
            proof = merkle_proof_device(leaves, 3)
            assert verify_merkle_proof(leaves[3], proof, root)
            tampered = bytes.fromhex(proof[step]["hash"])
            proof[step]["hash"] = (tampered[:-1]
                                   + bytes([tampered[-1] ^ 1])).hex()
            assert not verify_merkle_proof(leaves[3], proof, root)

    @pytest.mark.parametrize("index", [-1, 5, 8])
    def test_out_of_range_index_raises(self, index):
        """Both backends must agree: a proof for the duplicated
        odd-level pad node would verify against the root without
        corresponding to any submitted result."""
        leaves = self._leaves(5, seed=7)
        with pytest.raises(IndexError, match="out of range"):
            merkle_proof_device(leaves, index)
        with pytest.raises(IndexError, match="out of range"):
            merkle_proof(leaves, index)              # hashlib default

    def test_verify_inclusion_out_of_range_raises(self):
        from repro.core.verify import verify_inclusion
        fr = run_full(_mix_jash(arg_bits=5))
        root = merkle_root(fr.merkle_leaves)
        assert verify_inclusion(fr, 31, root)
        for bad in (-1, 32, 1000):
            with pytest.raises(IndexError, match="out of range"):
                verify_inclusion(fr, bad, root)


class TestChunkedExecutor:
    def test_chunked_bit_identical(self):
        j = _mix_jash()
        a = run_full(j)                        # single dispatch
        b = run_full(j, chunk_size=100)        # ragged chunking
        np.testing.assert_array_equal(a.args, b.args)
        np.testing.assert_array_equal(a.results, b.results)
        np.testing.assert_array_equal(a.hashes, b.hashes)
        np.testing.assert_array_equal(a.leaf_digests, b.leaf_digests)
        assert a.merkle_leaves == b.merkle_leaves
        assert a.commit_root() == b.commit_root()

    def test_leaf_semantics_match_seed(self):
        fr = run_full(_mix_jash(arg_bits=6))
        for i in (0, 31, 63):
            leaf = fr.args[i].tobytes() + fr.results[i].tobytes()
            assert fr.merkle_leaves[i] == leaf
            want = np.frombuffer(hashlib.sha256(leaf).digest(), ">u4")
            np.testing.assert_array_equal(fr.leaf_digests[i],
                                          want.astype(np.uint32))

    def test_commit_root_matches_reference(self):
        fr = run_full(_mix_jash())
        assert fr.commit_root() == \
            merkle_root(fr.merkle_leaves, backend="hashlib")
        assert fr.commit_root() == merkle_root_from_digests(fr.leaf_digests)

    def test_optimal_single_pass_matches_lexsort(self):
        def fn(a):
            h = (a * jnp.uint32(0x9E3779B1)) ^ (a >> jnp.uint32(3))
            return jnp.stack([h % jnp.uint32(7), h ^ jnp.uint32(0xABCD)])
        j = Jash("two-word", fn, JashMeta(arg_bits=9, res_bits=64),
                 example_args=(jnp.uint32(0),))
        fr = run_full(j)
        opt = run_optimal(j)
        order = np.lexsort((fr.results[:, 1], fr.results[:, 0]))
        assert opt.best_arg == int(order[0])
        np.testing.assert_array_equal(opt.best_res, fr.results[order[0]])


class TestBlockMicrostepValidation:
    def test_zero_microsteps_rejected(self):
        from repro.configs import get_config, reduced
        from repro.configs.base import InputShape
        from repro.core.pow_train import PoUWTrainer
        with pytest.raises(ValueError, match="block_microsteps"):
            PoUWTrainer(reduced(get_config("qwen3-0.6b")),
                        InputShape("t", 32, 4, "train"),
                        block_microsteps=0)
