"""Differential parity harness for ``ModelTrainingWorkload`` — real-model
PoUW (ROADMAP "chain-train the transformer zoo").

Pins the digest contract (canonical little-endian dtype+shape-framed
bytes of gathered arrays, shared between ``PoUWTrainer`` and the chain
workload), mesh-vs-single-device bit-identity, miner-vs-verifier replay
parity, reorg rollback snapshot-policy invariance (mirroring the GAN
tests), forged-evidence rejection, journal round-trip +
``Node.recover`` byte-identity, sim convergence with the new family,
and the ISSUE acceptance loop on ``pnpcoin-demo`` (≥4 blocks, 2-node
convergence, crash recovery, mid-chain reorg).
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.chain import Node
from repro.chain.store import ChainStore, encode_payload, decode_payload
from repro.chain.workloads import ModelTrainingWorkload, default_suite
from repro.chain.workloads.model_train import MICRO_KWARGS
from repro.configs import get_config
from repro.core.pow_train import _light_state_digest
from repro.train.steps import (TrainState, make_train_state, params_digest,
                               tree_digest)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def micro_wl(**overrides) -> ModelTrainingWorkload:
    kw = dict(MICRO_KWARGS)
    kw.update(overrides)
    return ModelTrainingWorkload(**kw)


def mt_node(i: int, **node_kwargs) -> Node:
    mesh = node_kwargs.pop("mesh", None)
    return Node(node_id=i, classic_arg_bits=5,
                workloads={"model_train": micro_wl(mesh=mesh)},
                **node_kwargs)


# ---------------------------------------------------------------------------
# the digest contract (satellite: _light_state_digest fragility fix)
# ---------------------------------------------------------------------------


class TestDigestCanonicalization:
    # computed once from the canonical framing; any platform, numpy, or
    # framing drift that changes committed state digests fails here
    PINNED = ("95a659025128acdb00f4e8d98f2542a0"
              "1b5d96804feb77f33a639dce11c383f8")

    @staticmethod
    def _tree():
        return {"a": np.arange(6, dtype="<f4").reshape(2, 3),
                "b": {"w1": np.float64(1.5), "n": np.int32(-7)},
                "c": np.array([True, False])}

    def test_cross_platform_pinned_vector(self):
        assert tree_digest(self._tree()) == self.PINNED

    def test_layout_and_endianness_invariance(self):
        """Fortran-order buffers and big-endian dtypes canonicalize to
        the same bytes — the digest sees values, never memory layout."""
        t = self._tree()
        f = dict(t, a=np.asfortranarray(t["a"]))
        assert tree_digest(f) == self.PINNED
        be = dict(t, a=t["a"].astype(">f4"))
        assert tree_digest(be) == self.PINNED

    def test_dtype_and_shape_framing(self):
        """Same raw bytes under a different dtype or shape must digest
        differently (the old projection digest collided here)."""
        x = np.arange(4, dtype="<f4")
        assert tree_digest({"x": x}) != \
            tree_digest({"x": x.view("<u4")})
        assert tree_digest({"x": x}) != \
            tree_digest({"x": x.reshape(2, 2)})

    def test_path_framing(self):
        assert tree_digest({"a": np.float32(1)}) != \
            tree_digest({"b": np.float32(1)})

    def test_full_params_not_a_projection(self):
        """The digest covers every element — mutating one weight far
        past the old 64-element projection window changes it."""
        x = np.zeros(1024, np.float32)
        base = tree_digest({"w": x})
        y = x.copy()
        y[1000] = 1e-3
        assert tree_digest({"w": y}) != base
        # the old digest summed leaves: a permutation that preserves the
        # sum (and the leading window) must still be detected
        z = x.copy()
        z[100], z[101] = 2.0, -2.0
        zp = x.copy()
        zp[100], zp[101] = -2.0, 2.0
        assert tree_digest({"w": z}) != tree_digest({"w": zp})

    def test_shared_helper_between_trainer_and_workload(self):
        """``PoUWTrainer``'s per-block digest is the same
        ``params_digest`` the chain workload commits."""
        cfg = micro_wl().cfg
        state = make_train_state(cfg, jax.random.key(0))
        trainer_digest = _light_state_digest(state)
        assert trainer_digest == params_digest(state)
        assert trainer_digest == params_digest(state.params)
        assert trainer_digest == tree_digest(state.params)

    def test_jax_and_numpy_trees_agree(self):
        state = make_train_state(micro_wl().cfg, jax.random.key(1))
        host = jax.tree.map(np.asarray, state.params)
        assert params_digest(host) == params_digest(state.params)


class TestShardingInvariance:
    _SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.chain.workloads.model_train import MICRO_CONFIG
        from repro.sharding.partition import param_shardings
        from repro.train.steps import make_train_state, params_digest, \\
            tree_digest

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
        host = {"w": x}
        for spec in [P("data", "model"), P("model", None), P()]:
            sharded = {"w": jax.device_put(x, NamedSharding(mesh, spec))}
            assert tree_digest(sharded) == tree_digest(host), spec
        # a real param tree through the partition rules
        state = make_train_state(MICRO_CONFIG, jax.random.key(0))
        sharded = jax.device_put(
            state.params, param_shardings(state.params, mesh))
        assert params_digest(sharded) == params_digest(state.params)
        print("DIGEST_OK")
    """)

    def test_digest_is_sharding_invariant_8_devices(self):
        """gather-then-hash: the digest of an array sharded across an
        8-device host mesh equals the digest of its host copy, for any
        partition spec (subprocess so the XLA device-count flag doesn't
        leak)."""
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run([sys.executable, "-c", self._SCRIPT], env=env,
                             capture_output=True, text=True, timeout=600,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "DIGEST_OK" in out.stdout


# ---------------------------------------------------------------------------
# mesh-vs-single-device parity
# ---------------------------------------------------------------------------


class TestMeshParity:
    def test_mesh_and_plain_nodes_interverify_bit_identically(self):
        """A node training under a device mesh (sharded state + batch
        placement + activation rules) and a plain single-device node
        must commit bit-identical blocks — each accepts the other's
        work by replaying on its own setup."""
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        a = mt_node(0, mesh=mesh)
        b = mt_node(1)
        ra = a.mine_block("model_train")
        assert b.receive(ra.record.to_block(), ra.payload, origin=0)
        rb = b.mine_block("model_train")
        assert a.receive(rb.record.to_block(), rb.payload, origin=1)
        assert a.workloads["model_train"].state_digest() == \
            b.workloads["model_train"].state_digest()
        assert [blk.block_hash for blk in a.ledger.blocks] == \
            [blk.block_hash for blk in b.ledger.blocks]


# ---------------------------------------------------------------------------
# miner-vs-verifier replay parity
# ---------------------------------------------------------------------------


class TestReplayParity:
    def test_two_nodes_converge_bit_identically(self):
        a, b = mt_node(0), mt_node(1)
        receipts = [a.mine_block("model_train") for _ in range(3)]
        for r in receipts:
            assert b.receive(r.record.to_block(), r.payload, origin=0)
        wa, wb = a.workloads["model_train"], b.workloads["model_train"]
        assert wa.round == wb.round == 3
        assert wa.state_digest() == wb.state_digest()
        assert a.book.balances == b.book.balances
        assert [blk.block_hash for blk in a.ledger.blocks] == \
            [blk.block_hash for blk in b.ledger.blocks]
        # and a third, late-joining node adopts the whole chain by replay
        c = mt_node(2)
        assert c.consider_chain(list(a.ledger.blocks), a.chain_payloads())
        assert c.workloads["model_train"].state_digest() == \
            wa.state_digest()

    def test_every_block_advances_the_state(self):
        """The chain does useful work: each block is real SGD, so every
        block commits a new params digest, a higher train height, and a
        finite loss (the synthetic token stream is near-uniform, so the
        loss itself hovers at the data entropy — progress is pinned by
        the state chain, not by loss descent)."""
        a = mt_node(0)
        seen = set()
        for r in range(5):
            p = a.mine_block("model_train").payload
            assert p.train_height == r
            assert np.isfinite(p.loss)
            assert p.state_digest not in seen
            seen.add(p.state_digest)


# ---------------------------------------------------------------------------
# forged evidence rejection
# ---------------------------------------------------------------------------


class TestForgedEvidenceRejection:
    def _honest_payload(self):
        a = mt_node(0)
        return a.mine_block("model_train").payload

    def _assert_rejected(self, payload):
        v = mt_node(9).workloads["model_train"]
        assert not v.verify(payload)
        assert v.round == 0 and v.is_pristine()

    def test_honest_accepted(self):
        p = self._honest_payload()
        v = mt_node(9).workloads["model_train"]
        assert v.verify(p)
        assert v.round == 1

    def test_forged_state_digest(self):
        self._assert_rejected(dataclasses.replace(
            self._honest_payload(), state_digest="00" * 32))

    def test_forged_loss(self):
        self._assert_rejected(dataclasses.replace(
            self._honest_payload(), loss=0.0))

    def test_corrupted_micro_proof(self):
        p = self._honest_payload()
        proof = np.array(p.micro_proof)
        proof[0, 0] ^= 1
        self._assert_rejected(dataclasses.replace(p, micro_proof=proof))

    def test_stripped_micro_proof(self):
        self._assert_rejected(dataclasses.replace(
            self._honest_payload(), micro_proof=None))

    def test_forged_merkle_root(self):
        self._assert_rejected(dataclasses.replace(
            self._honest_payload(), merkle_root="ff" * 32))

    def test_forged_n_miners_reward_grab(self):
        self._assert_rejected(dataclasses.replace(
            self._honest_payload(), n_miners=1))

    def test_future_height_unverifiable(self):
        b = mt_node(1)
        b.mine_block("model_train")
        r2 = b.mine_block("model_train")
        self._assert_rejected(r2.payload)

    def test_corrupted_params_chain_rejected_by_peer(self):
        """A miner whose *state* is corrupted commits digests no honest
        peer can reproduce — the block is rejected on receive."""
        a, b = mt_node(0), mt_node(1)
        wa = a.workloads["model_train"]
        wa._ensure_state()
        bad = jax.tree.map(lambda x: x + 1e-3, wa._state.params)
        wa._state = TrainState(params=bad, opt=wa._state.opt)
        r = a.mine_block("model_train")
        assert not b.receive(r.record.to_block(), r.payload, origin=0)
        assert b.workloads["model_train"].is_pristine()


# ---------------------------------------------------------------------------
# reorg rollback (mirrors TestGanRollback)
# ---------------------------------------------------------------------------


class TestModelTrainRollback:
    @pytest.mark.parametrize("snapshot_interval", [0, 2])
    def test_reorg_rolls_trainer_back(self, snapshot_interval):
        """A reorg that drops local model-train blocks must rewind the
        train state so the node can re-mine them on the adopted chain —
        and the outcome is invariant to the fork-choice snapshot policy
        (genesis replay == ringed checkpoints)."""
        a = mt_node(0, snapshot_interval=snapshot_interval)
        b = mt_node(1)
        a.mine_block("model_train")
        b_payload = b.mine_block("model_train").payload  # identical step 0
        assert a.workloads["model_train"].state_digest() == \
            b.workloads["model_train"].state_digest()
        a.mine_block("model_train")                      # A: steps 0, 1
        for _ in range(3):                               # B: step 0 + classic
            b.mine_block("classic")
        assert a.workloads["model_train"].round == 2
        assert a.consider_chain(list(b.ledger.blocks), b.chain_payloads())
        # step 1 was reorged away -> train state rewound to step 1's start
        assert a.workloads["model_train"].round == 1
        assert a.workloads["model_train"].state_digest() == \
            b.workloads["model_train"].state_digest()
        # and the chain keeps extending consistently: A re-mines step 1,
        # B accepts it on receive (bit-identical replay)
        receipt = a.mine_block("model_train")
        assert b.receive(receipt.record.to_block(), receipt.payload,
                         origin=0)
        assert b_payload.train_height == 0               # sanity

    def test_failed_candidate_leaves_state_untouched(self):
        a, b = mt_node(0), mt_node(1)
        a.mine_block("model_train")
        digest = a.workloads["model_train"].state_digest()
        b.mine_block("model_train")
        b.mine_block("model_train")
        blocks = list(b.ledger.blocks)
        payloads = b.chain_payloads()
        corrupted = [payloads[0],
                     dataclasses.replace(payloads[1], state_digest="00" * 32)]
        assert not a.consider_chain(blocks, corrupted)
        assert a.workloads["model_train"].round == 1
        assert a.workloads["model_train"].state_digest() == digest


# ---------------------------------------------------------------------------
# journal round-trip + Node.recover
# ---------------------------------------------------------------------------


class TestJournalRecovery:
    def test_payload_roundtrip_byte_identity(self):
        a = mt_node(0)
        for _ in range(2):
            p = a.mine_block("model_train").payload
            enc = encode_payload(p)
            dec = decode_payload(enc)
            assert encode_payload(dec) == enc
            np.testing.assert_array_equal(dec.micro_proof, p.micro_proof)
            assert dec.state_digest == p.state_digest
            assert dec.loss == p.loss

    def test_node_recover_replays_model_train_chain(self):
        store = ChainStore()
        a = Node(node_id=0, classic_arg_bits=5,
                 workloads={"model_train": micro_wl()}, store=store)
        for _ in range(3):
            a.mine_block("model_train")
        a.mine_block("classic")
        # crash: rebuild from the journal into a fresh shell with a
        # fresh workload instance (consensus params, not state, are
        # what survives a crash)
        shell = mt_node(0)
        rec = Node.recover(store, node=shell)
        assert rec.last_recovery.adopted_height == 4
        assert rec.ledger.height == a.ledger.height
        assert [blk.block_hash for blk in rec.ledger.blocks] == \
            [blk.block_hash for blk in a.ledger.blocks]
        assert rec.book.balances == a.book.balances
        # byte-identity: the replayed chain re-encodes to the same bytes
        for p0, p1 in zip(a.chain_payloads(), rec.chain_payloads()):
            assert encode_payload(p0) == encode_payload(p1)
        assert rec.workloads["model_train"].state_digest() == \
            a.workloads["model_train"].state_digest()
        # and the recovered node keeps mining blocks peers accept
        r = rec.mine_block("model_train")
        assert a.receive(r.record.to_block(), r.payload, origin=0)


# ---------------------------------------------------------------------------
# sim convergence with the new family
# ---------------------------------------------------------------------------


class TestSimConvergence:
    def test_heterogeneous_scenario_includes_model_train(self):
        from repro.chain.sim import heterogeneous_scenario
        sim = heterogeneous_scenario(seed=3)
        rep = sim.run()
        assert rep.converged
        assert rep.credit_divergence == 0.0
        honest = sim.honest_nodes
        mined = sum(p is not None and p.workload == "model_train"
                    for p in honest[0].chain_payloads())
        assert mined >= 2
        digests = {n.workloads["model_train"].state_digest()
                   for n in honest}
        assert len(digests) == 1

    def test_default_suite_grows_the_family(self):
        suite = default_suite(seed=5, model_train=dict(MICRO_KWARGS))
        assert isinstance(suite["model_train"], ModelTrainingWorkload)
        assert suite["model_train"].name == "model_train"
        assert suite["model_train"].is_pristine()


# ---------------------------------------------------------------------------
# ISSUE acceptance: pnpcoin-demo end to end
# ---------------------------------------------------------------------------


class TestPnpcoinDemoAcceptance:
    @staticmethod
    def _node(i: int, **kw) -> Node:
        wl = ModelTrainingWorkload(cfg=get_config("pnpcoin-demo"),
                                   seq_len=16, batch=2,
                                   block_microsteps=1, n_miners=2)
        return Node(node_id=i, classic_arg_bits=5,
                    workloads={"model_train": wl}, **kw)

    @pytest.mark.slow
    def test_two_node_chain_with_recovery_and_reorg(self):
        """≥4 model-train blocks on the real ``pnpcoin-demo``
        transformer across two nodes, verified by microbatch
        re-execution, converging bit-identically — then pinned through
        a crash/``Node.recover`` cycle and a mid-chain reorg."""
        store = ChainStore()
        a = self._node(0, store=store)
        b = self._node(1)
        for _ in range(4):
            r = a.mine_block("model_train")
            assert b.receive(r.record.to_block(), r.payload, origin=0)
        assert a.workloads["model_train"].state_digest() == \
            b.workloads["model_train"].state_digest()
        assert a.book.balances == b.book.balances
        # crash/recover cycle: byte-identical chain from the journal
        rec = Node.recover(store, node=self._node(0))
        assert rec.ledger.height == 4
        assert [blk.block_hash for blk in rec.ledger.blocks] == \
            [blk.block_hash for blk in a.ledger.blocks]
        assert rec.workloads["model_train"].state_digest() == \
            a.workloads["model_train"].state_digest()
        # mid-chain reorg: the recovered node mines a private block while
        # b's chain grows longer; fork choice rolls the train state back
        rec.mine_block("model_train")                  # rec: height 5
        r5 = b.mine_block("model_train")
        b.mine_block("classic")                        # b: height 6
        assert rec.consider_chain(list(b.ledger.blocks),
                                  b.chain_payloads())
        assert rec.workloads["model_train"].round == 5
        assert rec.workloads["model_train"].state_digest() == \
            b.workloads["model_train"].state_digest()
        assert r5.payload.train_height == 4            # sanity
