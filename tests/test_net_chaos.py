"""Tier-1 tests for mesh chaos tolerance (DESIGN.md §15): fault events
on the mesh scenario (crash / journal corruption / restart through
``Node.recover`` + wire resync) and the everything-at-once
``mesh_chaos_scenario`` — crashes, corrupted frames, and the eclipse
adversary simultaneously, still byte-identical with the in-process
oracle.  Schedules here are classic-only to keep tier-1 fast; the
full heterogeneous suite runs in the sim CLI and the bench."""
import pytest

from repro.chain.net import mesh_chaos_scenario, mesh_scenario

_CLASSIC8 = ("classic",) * 8
_CLASSIC10 = ("classic",) * 10


def test_mesh_scenario_crash_restart_reconverges_with_oracle():
    """Crash peer2 mid-run, corrupt its journal tail, restart it: the
    recovered node replays its journal, truncates the torn record, and
    resyncs over the wire — everyone reconverges with the oracle."""
    r = mesh_scenario(n_peers=4, seed=3, schedule=_CLASSIC8,
                      faults=((3, "crash", 2), (3, "corrupt_store", 2),
                              (5, "restart", 2)))
    assert r["converged"], r
    assert r["oracle_match"], (r["chain_digest"], r.get("oracle_digest"))
    assert r["n_alive"] == 4
    assert len(r["recoveries"]) == 1
    rec = r["recoveries"][0]
    assert rec["peer"] == 2
    assert rec["truncated_records"] >= 1       # the corrupted tail
    assert len(r["faults"]) == 3


def test_mesh_scenario_without_faults_reports_no_fault_keys():
    """The plain mesh path is untouched: no faults — no fault keys."""
    r = mesh_scenario(n_peers=3, seed=1, schedule=("classic",) * 4,
                      oracle=False)
    assert r["converged"], r
    assert "faults" not in r and "recoveries" not in r


def test_mesh_scenario_rejects_schedule_that_leaves_miner_dead():
    """Crashing the very peer whose round-robin turn is next (and never
    restarting it) is a broken schedule, not a tolerable fault."""
    with pytest.raises(ValueError, match="miner"):
        mesh_scenario(n_peers=3, seed=0, schedule=("classic",) * 4,
                      faults=((1, "crash", 1),))


def test_mesh_chaos_everything_at_once_acceptance():
    """The PR's acceptance oracle: crashes + journal corruption +
    restarts + an addr-flooding eclipse adversary + one corrupted frame
    per block, and the honest mesh still reconverges byte-identically
    with the in-process Network; the victim keeps an honest anchor and
    no gossip source overflows its per-source book quota."""
    r = mesh_chaos_scenario(
        n_peers=5, seed=0, schedule=_CLASSIC10,
        faults=((3, "crash", 2), (3, "corrupt_store", 2),
                (5, "restart", 2), (7, "crash", 3), (8, "restart", 3)))
    assert r["converged"], r
    assert r["oracle_match"], (r["chain_digest"], r.get("oracle_digest"))
    assert r["n_alive"] == 5
    assert len(r["recoveries"]) == 2           # both crashes recovered
    vic = r["victim"]
    assert vic["honest_anchors"] >= 1          # eclipse defense held
    assert vic["honest_conns"] >= 1
    assert vic["max_source_charge"] <= vic["per_source_quota"]
    assert r["attacker"]["addr_frames"] > 0    # the flood really ran
    assert r["quarantined"] >= 1               # corrupted frames seen
    assert r["bans"] == 0                      # no honest peer banned


def test_mesh_chaos_scenario_is_deterministic():
    """Same seed, same schedule, same faults — bit-identical chain and
    identical fault log across runs (the seeded-clock contract)."""
    kw = dict(n_peers=5, seed=4, schedule=_CLASSIC8, oracle=False,
              faults=((2, "crash", 4), (4, "restart", 4)))
    a = mesh_chaos_scenario(**kw)
    b = mesh_chaos_scenario(**kw)
    assert a["converged"] and b["converged"]
    assert a["chain_digest"] == b["chain_digest"]
    assert a["faults"] == b["faults"]
    assert a["recoveries"] == b["recoveries"]


def test_mesh_chaos_starved_victim_fails_over_past_attacker():
    """The attacker answers PINGs (keepalive mimicry) but starves every
    GET_* — liveness deadlines, not keepalive, must route the victim's
    pulls back to honest peers."""
    r = mesh_chaos_scenario(n_peers=5, seed=2, schedule=_CLASSIC8,
                            faults=(), oracle=False)
    assert r["converged"], r
    if r["attacker"]["pulls_starved"] > 0:
        # every starved pull was recovered elsewhere: chains converged,
        # and the timeouts that rescued them are on the books
        assert r["timeouts"] > 0
        assert r["failovers"] > 0
