"""Tier-1 tests for the liveness layer (DESIGN.md §15): request
deadlines with exponential-backoff failover, PING/PONG keepalive,
observed-address adoption, anchor protection, and the stranded-checksum
sweep — a silent or vanished peer must never stall sync."""
import random

import pytest

from repro.chain.net import (Announce, Hello, LoopbackHub, PROTOCOL_VERSION,
                             PeerNode, Ping, Pong, make_announce,
                             make_identities)
from repro.chain.node import Node


def _peer(i, identities, ring, hub, *, name=None, **kw):
    node = Node(node_id=i, classic_arg_bits=6, keyring=ring)
    pn = PeerNode(node, identities[i], ring, **kw)
    pn.attach(hub.register(name or f"peer{i}"))
    return pn


def _silent_port(hub, name):
    """A registered port that never answers — the silent peer."""
    port = hub.register(name)
    port.on_message = lambda src, msg: None
    return port


def _compact_announce(identity, receipt):
    sa = make_announce(identity, receipt.record.to_block(), receipt.payload)
    return Announce(header=sa.header, checksum=sa.checksum,
                    origin=sa.origin, pubkey=sa.pubkey,
                    signature=sa.signature, body=None)


def _hello_from(identity, *, height=0, observed=None):
    return Hello(version=PROTOCOL_VERSION, node_id=identity.node_id,
                 pubkey=identity.pubkey, height=height, addr=None,
                 observed=observed)


# -- deadlines + failover ---------------------------------------------------

def test_body_pull_timeout_fails_over_to_honest_peer():
    """A compact announce relayed by a peer that never serves the body:
    the deadline expires, the silent peer is charged a timeout, and the
    re-ask goes to the next-best connection — which serves it."""
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=0)
    peers = [_peer(i, ids, ring, hub, request_timeout=1.0) for i in range(2)]
    silent = _silent_port(hub, "silent")
    receipt = peers[1].node.mine_block()
    silent.send("peer0", _compact_announce(ids[1], receipt))
    hub.pump()
    assert peers[0].stats.body_requests == 1
    assert len(peers[0]._pending) == 1
    assert peers[0].node.ledger.height == 0    # body never arrives
    hub.advance(1.5)                           # past request_timeout
    peers[0].tick()
    hub.pump()
    assert peers[0].stats.timeouts == 1
    assert peers[0].stats.failovers == 1
    assert peers[0].scores["silent"].timeouts == 1
    assert peers[0].node.ledger.height == 1    # peer1 served the body
    assert not peers[0]._pending


def test_sync_bait_times_out_and_fails_over():
    """A HELLO claiming a tall chain from a peer that never answers
    GET_HEADERS: the pull times out and fails over instead of hanging."""
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=1)
    peers = [_peer(i, ids, ring, hub, request_timeout=1.0) for i in range(2)]
    silent = _silent_port(hub, "silent")
    silent.send("peer0", _hello_from(ids[2], height=50))   # the bait
    hub.pump()
    assert "silent" in peers[0]._sync_req
    hub.advance(1.5)
    peers[0].tick()
    hub.pump()
    assert "silent" not in peers[0]._sync_req
    assert peers[0].stats.timeouts == 1
    assert peers[0].stats.failovers == 1
    assert peers[0].scores["silent"].timeouts == 1


def test_backoff_grows_per_attempt_and_retry_cap_holds():
    """Each failover waits request_timeout * backoff**attempt; past
    max_retries the checksum is abandoned for a headers-first pull."""
    ids, ring = make_identities(2)
    hub = LoopbackHub(seed=2)
    p0 = _peer(0, ids, ring, hub, request_timeout=1.0, backoff=2.0,
               max_retries=2)
    s1 = _silent_port(hub, "s1")
    _silent_port(hub, "s2")
    node1 = Node(node_id=1, classic_arg_bits=6, keyring=ring)
    receipt = node1.mine_block()
    s1.send("peer0", _compact_announce(ids[1], receipt))
    hub.pump()
    (ck, ent0), = p0._pending.items()
    assert ent0.attempt == 0
    start = hub.now
    hub.advance(1.1)
    p0.tick()                                  # attempt 0 expired
    ent1 = p0._pending[ck]
    assert ent1.attempt == 1
    assert ent1.deadline == pytest.approx(hub.now + 2.0)   # 1.0 * 2**1
    hub.advance(2.1)
    p0.tick()                                  # attempt 1 expired
    ent2 = p0._pending[ck]
    assert ent2.attempt == 2
    assert ent2.deadline == pytest.approx(hub.now + 4.0)   # 1.0 * 2**2
    hub.advance(4.1)
    p0.tick()                                  # retry cap reached
    assert ck not in p0._pending               # abandoned...
    assert p0._sync_req                        # ...for a headers pull
    assert hub.now - start > 7.0               # backoff actually waited


# -- keepalive --------------------------------------------------------------

def test_keepalive_pings_then_drops_silent_peer():
    ids, ring = make_identities(2)
    hub = LoopbackHub(seed=0)
    p0 = _peer(0, ids, ring, hub, ping_interval=5.0, keepalive_timeout=10.0)
    _silent_port(hub, "silent")
    p0.tick()                                  # seeds _last_recv
    hub.advance(6.0)
    p0.tick()
    assert p0.stats.pings_sent == 1
    assert "silent" in p0._ping_sent
    hub.advance(11.0)                          # probe unanswered
    p0.tick()
    assert p0.stats.keepalive_drops == 1
    assert "silent" not in p0._peers()         # link torn down


def test_keepalive_pong_keeps_responsive_peer_alive():
    ids, ring = make_identities(2)
    hub = LoopbackHub(seed=0)
    peers = [_peer(i, ids, ring, hub, ping_interval=5.0,
                   keepalive_timeout=10.0) for i in range(2)]
    peers[0].broadcast_hello()
    hub.pump()
    hub.advance(6.0)
    peers[0].tick()
    hub.pump()                                 # PING out, PONG back
    assert peers[0].stats.pings_sent == 1
    assert peers[0].stats.pongs_recv == 1
    assert not peers[0]._ping_sent
    hub.advance(11.0)
    peers[0].tick()
    assert peers[0].stats.keepalive_drops == 0
    assert "peer1" in peers[0]._peers()


def test_unsolicited_or_wrong_nonce_pong_is_punished():
    ids, ring = make_identities(2)
    hub = LoopbackHub(seed=0)
    p0 = _peer(0, ids, ring, hub, ping_interval=5.0)
    silent = _silent_port(hub, "silent")
    silent.send("peer0", Pong(nonce=42))       # nobody asked
    hub.pump()
    assert p0.stats.unsolicited == 1
    assert p0.scores["silent"].unsolicited == 1
    hub.advance(6.0)
    p0.tick()                                  # real probe goes out
    nonce = p0._ping_sent["silent"][0]
    silent.send("peer0", Pong(nonce=nonce + 7))    # forged echo
    hub.pump()
    assert p0.stats.unsolicited == 2
    assert p0.scores["silent"].unsolicited == 2


def test_ping_answered_with_matching_pong():
    ids, ring = make_identities(2)
    hub = LoopbackHub(seed=0)
    p0 = _peer(0, ids, ring, hub)
    got = []
    port = hub.register("probe")
    port.on_message = lambda src, msg: got.append(msg)
    port.send("peer0", Ping(nonce=123456789))
    hub.pump()
    assert any(isinstance(m, Pong) and m.nonce == 123456789 for m in got)


# -- the stranded-checksum sweep (satellite bugfix) -------------------------

def test_dead_connection_pending_reenters_pull_queue_without_waiting():
    """The bugfix: a body fetch whose connection vanished entirely is
    re-targeted on the very next tick — no deadline wait, no timeout
    charged to anyone, and the sweep clears the solicited table."""
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=0)
    peers = [_peer(i, ids, ring, hub, request_timeout=30.0)
             for i in range(2)]
    silent = _silent_port(hub, "silent")
    receipt = peers[1].node.mine_block()
    silent.send("peer0", _compact_announce(ids[1], receipt))
    hub.pump()
    assert len(peers[0]._pending) == 1
    assert "silent" in peers[0]._asked
    hub.unregister("silent")                   # process crash
    peers[0].tick()                            # no time has passed
    hub.pump()
    assert peers[0].stats.timeouts == 0        # nobody was slow
    assert peers[0].stats.failovers == 1
    assert "silent" not in peers[0]._asked     # table swept
    assert peers[0].node.ledger.height == 1    # peer1 served it


def test_dead_connection_sync_pull_fails_over_immediately():
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=0)
    peers = [_peer(i, ids, ring, hub, request_timeout=30.0)
             for i in range(2)]
    silent = _silent_port(hub, "silent")
    silent.send("peer0", _hello_from(ids[2], height=50))
    hub.pump()
    assert "silent" in peers[0]._sync_req
    hub.unregister("silent")
    peers[0].tick()
    assert "silent" not in peers[0]._sync_req
    assert peers[0].stats.timeouts == 0
    assert peers[0].stats.failovers == 1


# -- hostile clock property -------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_hostile_clock_sync_never_stalls(seed):
    """Property: blocks announced only by a peer that never serves
    bodies, while the clock advances by adversarially random steps
    between ticks — the victim must still recover the full chain via
    failover and headers-first pulls, and the pending table drains."""
    rng = random.Random(seed)
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=seed)
    peers = [_peer(i, ids, ring, hub, request_timeout=1.0, max_retries=2,
                   ping_interval=50.0, keepalive_timeout=100.0)
             for i in range(2)]
    silent = _silent_port(hub, "silent")
    k = 3
    for _ in range(k):
        receipt = peers[1].node.mine_block()
        silent.send("peer0", _compact_announce(ids[1], receipt))
    hub.pump()
    for _ in range(60):
        if peers[0].node.ledger.height == k:
            break
        hub.advance(rng.uniform(0.1, 6.0))
        for p in peers:
            p.broadcast_hello()            # the scenarios' beacon
            p.tick()
        hub.pump()
    assert peers[0].node.ledger.height == k, \
        (seed, peers[0].node.ledger.height, dict(peers[0]._pending))
    assert not peers[0]._pending
    # recovery came through a liveness path: deadline failover or a
    # beacon-triggered headers pull — never a silent hang
    assert peers[0].stats.failovers > 0 or peers[0].stats.sync_pulls > 0


# -- observed-address adoption (NAT feedback) -------------------------------

def test_observed_address_adopted_at_quorum_with_listen_port():
    """Two distinct peers echoing the same observed host → the addr-less
    peer signs it as its own, with listen_port replacing the (ephemeral)
    observed source port.  One echo alone is not enough."""
    ids, ring = make_identities(3)
    hub = LoopbackHub(seed=0)
    p0 = _peer(0, ids, ring, hub, listen_port=7777, min_observed=2)
    others = [_peer(i, ids, ring, hub, min_observed=99) for i in (1, 2)]
    hub.set_endpoint("peer0", "198.51.100.7", 40001)
    p0.port.send("peer1", p0.hello())
    hub.pump()
    assert p0.stats.observed_echoes == 1
    assert p0.addr is None                     # quorum not reached
    p0.port.send("peer2", p0.hello())
    hub.pump()
    assert p0.stats.addrs_adopted == 1
    assert p0.addr is not None
    assert (p0.addr.host, p0.addr.port) == ("198.51.100.7", 7777)
    assert p0.addr.verify(keyring=ring)        # self-signed and valid
    assert others[0].addr is None              # they never hit quorum


def test_one_lying_reporter_cannot_steer_adoption():
    """A lone liar echoing a bogus endpoint splits the tally: neither
    endpoint reaches min_observed, so nothing is adopted — until a
    second honest peer confirms the real one."""
    ids, ring = make_identities(4)
    hub = LoopbackHub(seed=0)
    p0 = _peer(0, ids, ring, hub, listen_port=7777, min_observed=2)
    _peer(1, ids, ring, hub)
    _peer(2, ids, ring, hub)
    liar = _silent_port(hub, "liar")
    hub.set_endpoint("peer0", "198.51.100.7", 40001)
    liar.send("peer0", _hello_from(ids[3], observed=("203.0.113.66", 666)))
    hub.pump()
    assert p0.addr is None                     # 1 vote for the lie
    p0.port.send("peer1", p0.hello())
    hub.pump()
    assert p0.addr is None                     # 1 honest vote: still split
    p0.port.send("peer2", p0.hello())
    hub.pump()
    assert p0.addr is not None                 # honest quorum wins
    assert p0.addr.host == "198.51.100.7"


# -- anchors ----------------------------------------------------------------

def test_anchor_connection_survives_cap_eviction():
    """At the connection cap the eviction pool excludes anchors: gossip-
    pushed connections are shed, the chosen anchor link stays."""
    ids, ring = make_identities(4)
    hub = LoopbackHub(seed=0, full_mesh=False)
    p0 = _peer(0, ids, ring, hub, max_peers=2, anchors=(1,))
    anchor = _silent_port(hub, "anchor")
    evil1 = _silent_port(hub, "evil1")
    evil2 = _silent_port(hub, "evil2")
    hub.connect("peer0", "anchor")
    anchor.send("peer0", _hello_from(ids[1]))
    hub.pump()
    hub.connect("peer0", "evil1")
    evil1.send("peer0", _hello_from(ids[2]))
    hub.pump()
    assert sorted(p0._peers()) == ["anchor", "evil1"]    # at cap
    hub.connect("peer0", "evil2")
    evil2.send("peer0", _hello_from(ids[3]))
    hub.pump()
    assert p0.stats.evictions == 1
    assert "anchor" in p0._peers()             # protected link held
    assert len(p0._peers()) == 2
