"""Mesh-layer tier-1 tests (DESIGN.md §14): single-seed discovery to
full-mesh convergence, deterministic scoring/eviction/banning under a
GET_BODIES spammer, and the unknown/pruned body-serving regressions —
all on the seeded loopback hub, so every run is bit-reproducible."""
import dataclasses

import pytest

from repro.chain.net.identity import make_addr, make_identities
from repro.chain.net.messages import Addr, GetBodies, GetHeaders
from repro.chain.net.peer import (PeerNode, chain_digest, drive_discovery,
                                  mesh_scenario)
from repro.chain.net.peerbook import BAN_THRESHOLD, W_RATE
from repro.chain.net.transport import LoopbackHub
from repro.chain.node import Node

_ZERO_CK = b"\x00" * 16


def _mesh_peer(i, identities, ring, hub, **kw):
    node = Node(node_id=i, classic_arg_bits=6, keyring=ring)
    pn = PeerNode(node, identities[i], ring, compact=True,
                  addr=make_addr(identities[i], "loopback", 9000 + i), **kw)
    pn.attach(hub.register(f"peer{i}"))
    return pn


def _bootstrap_single_seed(n, *, seed=0, **kw):
    """N peers on a mesh-mode hub, each linked only to peer0."""
    identities, ring = make_identities(n)
    hub = LoopbackHub(seed=seed, full_mesh=False)
    peers = [_mesh_peer(i, identities, ring, hub, **kw) for i in range(n)]
    for i in range(1, n):
        hub.connect(f"peer{i}", "peer0")
        peers[i].conn_ids["peer0"] = 0
        peers[i].broadcast_hello()
    hub.pump()
    return identities, ring, hub, peers


# -- discovery ------------------------------------------------------------


def test_single_seed_discovery_reaches_full_mesh():
    """Five peers, one seed address: HELLO addr payloads + ADDR gossip
    must propagate every endpoint, and PeerBook-driven dialing must
    complete the mesh in a bounded number of rounds."""
    _, _, hub, peers = _bootstrap_single_seed(5)
    rounds = drive_discovery(hub, peers)
    assert rounds <= 3
    want = {f"peer{i}" for i in range(5)}
    for pn in peers:
        assert set(hub.links_of(pn.port.name)) == want - {pn.port.name}
        # everyone's book learned everyone else, promoted to tried
        assert set(pn.peerbook.tried) == set(range(5)) - {pn.identity.node_id}
    assert sum(pn.stats.addrs_added for pn in peers) >= 4


def test_mesh_scenario_converges_and_matches_oracle():
    """The pinned acceptance scenario: single-seed bootstrap, full
    discovery, round-robin mining — byte-identical with the in-process
    Network oracle (chain digest AND credit books)."""
    r = mesh_scenario(n_peers=5, seed=0, schedule=("classic",) * 6)
    assert r["full_mesh"] and r["converged"]
    assert r["oracle_match"], (r["chain_digest"], r["oracle_digest"])
    assert r["height"] == 6
    assert r["addrs_added"] > 0


def test_mesh_scenario_is_deterministic():
    a = mesh_scenario(n_peers=4, seed=3, schedule=("classic",) * 4,
                      oracle=False)
    b = mesh_scenario(n_peers=4, seed=3, schedule=("classic",) * 4,
                      oracle=False)
    assert a["chain_digest"] == b["chain_digest"]
    assert a["bytes_on_wire"] == b["bytes_on_wire"]
    assert a["links"] == b["links"]


def test_peerbook_ignores_gossip_for_banned_id():
    """An addr for a banned identity re-gossiped later must not
    re-enter the book or be dialed again."""
    identities, ring, hub, peers = _bootstrap_single_seed(3)
    drive_discovery(hub, peers)
    victim = peers[0]
    victim.peerbook.ban(2)
    addr2 = make_addr(identities[2], "loopback", 9002)
    assert not victim.peerbook.add(addr2)
    assert all(a.node_id != 2 for a in victim.peerbook.select(8))


# -- scoring, eviction, banning -------------------------------------------


def test_get_bodies_spammer_is_banned_and_mesh_still_converges():
    """The pinned misbehavior scenario: a peer spamming GET_BODIES far
    past the token bucket accumulates rate violations, crosses the ban
    threshold, and is disconnected — while the honest mesh goes on to
    converge."""
    identities, ring, hub, peers = _bootstrap_single_seed(3)
    drive_discovery(hub, peers)
    spam = hub.register("spammer")
    assert hub.connect("spammer", "peer0")
    victim = peers[0]
    for _ in range(200):
        spam.send("peer0", GetBodies(checksums=(b"\xab" * 16,)))
    hub.pump()
    score = victim.scores["spammer"]
    assert score.rate_violations * W_RATE >= BAN_THRESHOLD
    assert score.banned()
    assert victim.stats.bans == 1
    assert "spammer" in victim._banned_conns
    # the link is torn down: nothing more reaches the victim from it
    assert "spammer" not in hub.links_of("peer0")
    before = victim.port.stats.frames_recv
    spam.send("peer0", GetBodies(checksums=(b"\xab" * 16,)))
    hub.pump()
    assert victim.port.stats.frames_recv == before
    # honest mesh still converges afterwards
    for b in range(4):
        peers[b % 3].mine_and_announce()
        hub.pump()
    digests = {chain_digest(pn.node) for pn in peers}
    assert len(digests) == 1
    assert all(pn.node.ledger.height == 4 for pn in peers)


def test_rate_limited_peer_gets_no_service_while_throttled():
    """Requests past the bucket are not served (no reply at all), and
    each one costs score."""
    identities, ring, hub, peers = _bootstrap_single_seed(2,
                                                          headers_rate=1.0,
                                                          headers_burst=2.0)
    victim, other = peers
    sent_before = victim.port.stats.frames_sent
    for _ in range(6):
        other.port.send("peer0", GetHeaders(from_height=0))
    hub.pump()
    # 2 admitted (burst) + small refill; the rest unanswered
    assert victim.stats.rate_violations >= 3
    assert victim.scores["peer1"].rate_violations >= 3
    replies = victim.port.stats.frames_sent - sent_before
    assert replies <= 3


def test_connection_cap_evicts_worst_scored_peer():
    """At max_peers the worst-scored connection is evicted — and the
    victim choice is deterministic (score, then name)."""
    identities, ring = make_identities(4)
    hub = LoopbackHub(seed=0, full_mesh=False)
    peers = [_mesh_peer(i, identities, ring, hub, max_peers=2)
             for i in range(4)]
    hub.connect("peer0", "peer1")
    hub.connect("peer0", "peer2")
    peers[1].broadcast_hello()
    peers[2].broadcast_hello()
    hub.pump()
    # peer1 misbehaves: worst score at eviction time
    peers[0]._punish("peer1", "unsolicited")
    hub.connect("peer0", "peer3")
    peers[3].broadcast_hello()
    hub.pump()
    assert peers[0].stats.evictions == 1
    links = hub.links_of("peer0")
    assert "peer1" not in links and len(links) == 2
    # eviction is not a ban: peer1 may reconnect later
    assert "peer1" not in peers[0]._banned_conns


# -- body-serving regressions (unknown / pruned checksums) ----------------


def test_get_bodies_unknown_and_pruned_checksums_never_crash():
    """A GET_BODIES for a checksum the peer never had — or for the
    zero-checksum finality sentinel — must be answered (empty) without
    crashing, and must not poison the requester."""
    identities, ring, hub, peers = _bootstrap_single_seed(2)
    serving, asking = peers
    asking.port.send("peer0", GetBodies(checksums=(b"\x5c" * 16,)))
    asking.port.send("peer0", GetBodies(checksums=(_ZERO_CK,)))
    asking.port.send("peer0", GetBodies(checksums=(_ZERO_CK, b"\x5c" * 16)))
    hub.pump()                      # raises if any handler crashed
    assert serving.stats.bodies_served == 0
    # empty replies are not "unsolicited bodies": the asker keeps a
    # clean score on the serving side and vice versa
    assert asking.scores.get("peer0") is None \
        or asking.scores["peer0"].misbehavior() == 0
    # the pair still works: mine and relay a real block
    serving.mine_and_announce()
    hub.pump()
    assert asking.node.ledger.height == 1


def test_requester_falls_back_when_server_pruned_bodies():
    """A peer whose bodies are pruned (serves headers but no bodies)
    must not wedge the requester: the pull is abandoned and a later
    peer with intact bodies completes the sync."""
    identities, ring = make_identities(3)
    hub = LoopbackHub(seed=1, full_mesh=False)
    peers = [_mesh_peer(i, identities, ring, hub) for i in range(3)]
    pruned, behind, intact = peers
    # pruned and intact mine the same chain together first
    hub.connect("peer0", "peer2")
    pruned.conn_ids["peer2"] = 2
    intact.conn_ids["peer0"] = 0
    for _ in range(3):
        pruned.mine_and_announce()
        hub.pump()
    assert intact.node.ledger.height == 3
    # now peer0 "prunes": headers remain, bodies are gone
    pruned._bodies.clear()
    pruned._lookup_body = lambda ck: None
    hub.connect("peer1", "peer0")
    pruned.broadcast_hello()
    hub.pump()
    # the pull was abandoned, not wedged: no sync state, no progress
    assert behind.node.ledger.height == 0
    assert "peer0" not in behind._sync
    assert behind.stats.sync_pulls >= 1
    # a peer with intact bodies completes the catch-up
    hub.connect("peer1", "peer2")
    intact.broadcast_hello()
    hub.pump()
    assert behind.node.ledger.height == 3
    assert chain_digest(behind.node) == chain_digest(intact.node)
