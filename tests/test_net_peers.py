"""Tier-1 tests for ``repro.chain.net.peer``: wire-connected peers
must behave bit-identically to the in-process ``Network`` (the
convergence oracle), enforce signed origin binding on both transports,
save bytes under compact relay, and survive adversarial frames."""
import dataclasses
import subprocess
import sys

import pytest

from repro.chain.net import (Announce, KeyRing, LoopbackHub, PeerNode,
                             chain_digest, encode_message, loopback_scenario,
                             make_announce, make_identities)
from repro.chain.network import Network
from repro.chain.node import Node


def _classic_peer(i, identities, ring, hub, *, compact=True):
    node = Node(node_id=i, classic_arg_bits=6, keyring=ring)
    pn = PeerNode(node, identities[i], ring, compact=compact)
    pn.attach(hub.register(f"peer{i}"))
    return pn


def _classic_ring(n):
    return make_identities(n)


def test_loopback_oracle_parity_full_suite():
    """The acceptance oracle: 4 loopback peers mining the full
    heterogeneous workload suite reconverge bit-identically with the
    in-process Network — ledgers, tips, and credit books."""
    r = loopback_scenario(n_peers=4, seed=0)
    assert r["converged"], r
    assert r["oracle_match"], (r["chain_digest"], r.get("oracle_digest"))
    assert r["quarantined"] == 0


def test_loopback_classic_parity_with_drops():
    """Lossy links: retry/backoff plus hello-triggered pull resync
    still reach the oracle chain."""
    r = loopback_scenario(n_peers=3, seed=2, drop_prob=0.15,
                          schedule=("classic",) * 6)
    assert r["converged"], r
    assert r["oracle_match"], r


def test_compact_relay_saves_bytes():
    """Compact announces (header + checksum) must put measurably fewer
    bytes on the wire than full-body relay for the same chain."""
    compact = loopback_scenario(n_peers=4, seed=1, oracle=False,
                                schedule=("classic",) * 6)
    full = loopback_scenario(n_peers=4, seed=1, oracle=False, compact=False,
                             schedule=("classic",) * 6)
    assert compact["converged"] and full["converged"]
    assert compact["chain_digest"] == full["chain_digest"]
    assert compact["bytes_on_wire"] < full["bytes_on_wire"], \
        (compact["bytes_on_wire"], full["bytes_on_wire"])
    hits = sum(s["compact_hits"] for s in compact["peer_stats"])
    assert hits > 0, "no payload was ever deduplicated"


def test_forged_origin_rejected_on_wire():
    """An announce signed by identity 1 but claiming origin 0 must be
    dropped by every receiver before any body is fetched."""
    ids, ring = _classic_ring(3)
    hub = LoopbackHub(seed=0)
    peers = [_classic_peer(i, ids, ring, hub) for i in range(3)]
    receipt = peers[1].node.mine_block()
    block = receipt.record.to_block()
    honest = make_announce(ids[1], block, receipt.payload)
    forged = Announce(header=honest.header, checksum=honest.checksum,
                      origin=0,               # lies about the origin
                      pubkey=honest.pubkey, signature=honest.signature,
                      body=None)
    peers[1].port.send("peer0", forged)
    hub.pump()
    assert peers[0].stats.sig_rejects == 1
    assert peers[0].stats.body_requests == 0
    assert peers[0].node.ledger.height == 0


def test_unsigned_announce_rejected_when_keyring_set():
    ids, ring = _classic_ring(2)
    hub = LoopbackHub(seed=0)
    peers = [_classic_peer(i, ids, ring, hub) for i in range(2)]
    receipt = peers[1].node.mine_block()
    honest = make_announce(ids[1], receipt.record.to_block(),
                           receipt.payload)
    unsigned = Announce(header=honest.header, checksum=honest.checksum,
                        origin=honest.origin, pubkey=b"\x00" * 32,
                        signature=b"\x00" * 64, body=None)
    peers[1].port.send("peer0", unsigned)
    hub.pump()
    assert peers[0].stats.sig_rejects == 1
    assert peers[0].node.ledger.height == 0


def test_forged_origin_rejected_in_process():
    """Satellite bugfix: ``Node.receive`` routes the origin check
    through signature verification once the node holds a keyring — a
    forged announce (wrong key claiming origin 0) is rejected, the
    honest one accepted, by the very same code path ``Network.deliver``
    and ``PeerNode`` both use."""
    ids, ring = _classic_ring(2)
    miner = Node(node_id=0, classic_arg_bits=6, keyring=ring)
    receiver = Node(node_id=1, classic_arg_bits=6, keyring=ring)
    receipt = miner.mine_block()
    block = receipt.record.to_block()
    forged_identity = dataclasses.replace(ids[1], node_id=0)
    forged = make_announce(forged_identity, block, receipt.payload)
    assert not receiver.receive(block, receipt.payload, announce=forged)
    assert receiver.ledger.height == 0
    honest = make_announce(ids[0], block, receipt.payload)
    assert receiver.receive(block, receipt.payload, announce=honest)
    assert receiver.ledger.height == 1


def test_network_with_identities_converges():
    """With identities configured the in-process Network signs every
    delivery and nodes verify it — convergence must be unaffected."""
    ids, ring = _classic_ring(3)
    net = Network.create(
        3, node_factory=lambda i: Node(node_id=i, classic_arg_bits=6,
                                       keyring=ring),
        identities=ids)
    for res in net.run(5):
        assert not res.rejected_by
    assert net.converged()


def test_keyring_required_for_unknown_origin():
    """A node with a keyring refuses announces from origins the ring
    does not know (no unsigned fallback once signatures are on)."""
    ids, ring = _classic_ring(1)        # ring only knows node 0
    miner = Node(node_id=5, classic_arg_bits=6)
    receiver = Node(node_id=0, classic_arg_bits=6, keyring=ring)
    receipt = miner.mine_block()
    block = receipt.record.to_block()
    assert not receiver.receive(block, receipt.payload, origin=5)
    assert receiver.ledger.height == 0


def test_peer_survives_corrupt_frames_and_resyncs():
    """Adversarial bytes on the wire: quarantined, never raising, and
    the protocol still converges afterwards."""
    ids, ring = _classic_ring(2)
    hub = LoopbackHub(seed=3)
    peers = [_classic_peer(i, ids, ring, hub) for i in range(2)]
    good = encode_message(peers[0].hello())
    corrupt = bytearray(good)
    corrupt[len(corrupt) // 2] ^= 0x10
    hub.inject("peer1", "peer0", bytes(corrupt))
    hub.inject("peer1", "peer0", b"\x00garbage\xff" * 7)
    hub.pump()
    assert hub.ports["peer0"].stats.quarantined == 2
    peers[1].mine_and_announce()
    hub.pump()
    assert peers[0].node.ledger.height == 1
    assert chain_digest(peers[0].node) == chain_digest(peers[1].node)


def test_fork_resolution_over_wire():
    """Two peers mine disjoint chains while isolated; reconnecting and
    announcing resolves the fork to the longer chain via a chain pull,
    bodies transferred by checksum."""
    ids, ring = _classic_ring(2)
    hub = LoopbackHub(seed=0)
    isolated = LoopbackHub(seed=0)
    node0 = Node(node_id=0, classic_arg_bits=6, keyring=ring)
    node1 = Node(node_id=1, classic_arg_bits=6, keyring=ring)
    p0 = PeerNode(node0, ids[0], ring)
    p1 = PeerNode(node1, ids[1], ring)
    # mine apart: peer0 one block, peer1 three (attached to a dead hub
    # so announces go nowhere)
    p0.attach(isolated.register("p0"))
    p1.attach(isolated.register("p1x"))
    p0.mine_and_announce()
    for _ in range(3):
        p1.mine_and_announce()
    isolated.ports.clear()               # drop the isolated wires
    p0.attach(hub.register("peer0"))
    p1.attach(hub.register("peer1"))
    assert node0.ledger.tip_hash != node1.ledger.tip_hash
    # reconnect: height beacons trigger the pull; peer0 adopts the
    # longer chain
    p1.broadcast_hello()
    p0.broadcast_hello()
    hub.pump()
    assert node0.ledger.height == 3
    assert chain_digest(node0) == chain_digest(node1)
    assert p0.stats.reorgs == 1
    assert node0.ledger.verify_chain()


def test_tcp_two_process_convergence():
    """The two-OS-process oracle, classic-only schedule for speed (CI
    runs the full-suite flavor via ``--demo`` defaults)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.chain.net", "--demo",
         "--schedule", "classic,classic,classic,classic",
         "--timeout", "120"],
        capture_output=True, text=True, timeout=180)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert '"oracle_match": true' in proc.stdout, proc.stdout
