"""Network edge cases the async simulator exposed: single-node
networks, duplicate block delivery, genesis mismatches, and fork choice
over chains mixing classic/full/optimal workloads (§3.4 fallback under
forks)."""
import dataclasses

import pytest

from repro.chain import Network, Node
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.ledger import Ledger


def small_collatz(arg_bits: int = 6, max_steps: int = 64) -> Jash:
    base = collatz_jash(max_steps=max_steps)
    return Jash(base.name, base.fn,
                JashMeta(arg_bits=arg_bits, res_bits=32, importance=0.9),
                example_args=base.example_args)


class TestSingleNodeNetwork:
    def test_single_node_mines_and_converges(self):
        """N=1 is a degenerate but legal network: broadcasts have no
        peers, convergence is trivially true, audits still run."""
        net = Network.create(1, classic_arg_bits=6)
        net.nodes[0].submit(small_collatz())
        results = net.run(3, ["full", None, None])
        assert [r.receipt.record.workload for r in results] == \
            ["full", "classic", "classic"]
        assert all(r.accepted_by == [0] and not r.rejected_by
                   for r in results)
        assert net.converged()
        assert all(net.nodes[0].audit(h) for h in range(3))


class TestDuplicateDelivery:
    def test_duplicate_block_is_rejected_without_state_change(self):
        """Delivering the same block twice must not re-commit, re-mint,
        or corrupt the peer chain (gossip is at-least-once)."""
        net = Network.create(2, classic_arg_bits=6)
        res = net.mine(0)
        blk = res.receipt.record.to_block()
        peer = net.nodes[1]
        h, issued = peer.ledger.height, peer.book.total_issued
        roots = [b.merkle_root for b in peer.ledger.blocks]

        # direct re-receive: height/tip mismatch -> False
        assert not peer.receive(blk, res.receipt.payload, origin=0)
        # and the deliver path's consider_chain fallback is a no-op too
        # (the duplicate chain is not strictly longer)
        assert not net.deliver(0, 1, blk, res.receipt.payload)
        assert peer.ledger.height == h
        assert peer.book.total_issued == issued
        assert [b.merkle_root for b in peer.ledger.blocks] == roots
        assert net.converged()

    def test_rebroadcast_counts_as_rejection_in_broadcast(self):
        net = Network.create(2, classic_arg_bits=6)
        res = net.mine(0)
        again = net.broadcast(0, res.receipt.record.to_block(),
                              res.receipt)
        assert again.rejected_by == [1]


class TestGenesisMismatch:
    def test_chain_with_foreign_genesis_rejected(self):
        """A chain whose first block does not link from OUR genesis is
        rejected outright by fork choice, however long it is."""
        net = Network.create(2, classic_arg_bits=6)
        net.run(2)
        assert net.converged()
        donor = net.nodes[0]
        blocks = [dataclasses.replace(b) for b in donor.ledger.blocks]
        blocks[0] = dataclasses.replace(blocks[0], prev_hash="00" * 32)
        victim = Node(node_id=9, classic_arg_bits=6)
        assert not victim.consider_chain(blocks, donor.chain_payloads())
        assert victim.ledger.height == 0
        assert victim.book.total_issued == 0.0
        # sanity: the untampered chain is adopted by the same node
        assert victim.consider_chain(donor.ledger.blocks,
                                     donor.chain_payloads())
        assert victim.ledger.height == 2
        assert victim.ledger.blocks[0].prev_hash == Ledger.GENESIS_HASH

    def test_broken_midchain_link_rejected(self):
        net = Network.create(2, classic_arg_bits=6)
        net.run(3)
        donor = net.nodes[0]
        blocks = list(donor.ledger.blocks)
        blocks[2] = dataclasses.replace(blocks[2], prev_hash="11" * 32)
        victim = Node(node_id=9, classic_arg_bits=6)
        assert not victim.consider_chain(blocks, donor.chain_payloads())
        assert victim.ledger.height == 0


class TestMixedWorkloadFork:
    def test_fork_choice_replays_mixed_workload_chain(self):
        """§3.4 classic fallback under fork choice: a node on a private
        [full, classic] fork adopts a longer [classic, optimal, classic]
        chain — every payload re-verified by its own workload, ledger
        and credit book rebuilt, and the chain keeps extending after."""
        net = Network.create(2, classic_arg_bits=6)
        n0, n1 = net.nodes

        # private fork on node 0: full block then classic (no broadcast)
        n0.submit(small_collatz())
        r_full = n0.mine_block("full")
        n0.mine_block()                           # classic fallback
        assert [b.mode for b in n0.ledger.blocks] == ["full", "classic"]

        # node 1 builds a longer, workload-mixed chain privately
        n1.mine_block()                           # classic (empty queue)
        n1.submit(small_collatz(max_steps=32))
        n1.mine_block("optimal")
        tip = n1.mine_block()                     # classic again
        assert [b.mode for b in n1.ledger.blocks] == \
            ["classic", "optimal", "classic"]

        # broadcasting node 1's tip makes node 0 pull + adopt the chain
        res = net.broadcast(1, tip.record.to_block(), tip)
        assert res.accepted_by == [1, 0]
        assert net.converged()
        assert [b.mode for b in n0.ledger.blocks] == \
            ["classic", "optimal", "classic"]
        # the orphaned full block (and its minted credits) are gone
        assert r_full.record.block_hash not in \
            [b.block_hash for b in n0.ledger.blocks]
        books = {tuple(sorted(n.book.balances.items()))
                 for n in net.nodes}
        assert len(books) == 1
        assert all(n0.audit(h) for h in range(3))

        # the adopted mixed chain keeps extending from either side
        res = net.mine(0)
        assert not res.rejected_by
        assert net.converged() and net.heights == [4, 4]
