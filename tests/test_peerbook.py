"""Property tests for the mesh layer (DESIGN.md §14): token-bucket
admission bounds, PeerScore monotone banning, deterministic eviction,
and PeerBook admission/eviction invariants.

Runs everywhere: when Hypothesis is installed the properties get full
shrinking randomized search; without it, the same properties run over
seeded deterministic event sequences (20 seeds each), so CI without
the extra dependency still exercises every invariant.
"""
from __future__ import annotations

import dataclasses
import random

import pytest

from repro.chain.net.identity import make_addr, make_identities
from repro.chain.net.peerbook import (BAN_THRESHOLD, PeerBook, PeerScore,
                                      TokenBucket, W_INVALID, W_RATE,
                                      eviction_order)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# shared property drivers (called by both the seeded and Hypothesis paths)
# ---------------------------------------------------------------------------


def _drive_bucket(rate, burst, events):
    """Replay (dt, cost) events; assert the admission bound
    admitted_cost <= burst + rate * monotone_elapsed at every step."""
    bucket = TokenBucket(rate, burst)
    t = 100.0
    t0 = hi = None                   # reference = first clock the bucket saw
    admitted_cost = 0.0
    for dt, cost in events:
        t += dt                      # dt may be negative: hostile clock
        if t0 is None:
            t0 = hi = t
        hi = max(hi, t)
        if bucket.allow(t, cost):
            admitted_cost += cost
        assert bucket.tokens >= -1e-9
        assert admitted_cost <= burst + rate * (hi - t0) + 1e-6, (
            f"bucket admitted {admitted_cost} > "
            f"{burst} + {rate}*{hi - t0}")
    return admitted_cost


def _drive_score_monotone(increments):
    """Replay misbehavior increments; assert banned() never reverts."""
    s = PeerScore()
    was_banned = False
    for field, n in increments:
        setattr(s, field, getattr(s, field) + n)
        assert s.misbehavior() >= 0
        if was_banned:
            assert s.banned(), "misbehavior un-banned a peer"
        was_banned = s.banned()
    return was_banned


# ---------------------------------------------------------------------------
# deterministic seeded paths (always run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(20))
def test_token_bucket_admission_bound_seeded(seed):
    rng = random.Random(seed)
    rate = rng.choice([0.5, 1.0, 4.0, 16.0])
    burst = rng.choice([1.0, 2.0, 8.0, 64.0])
    events = [(rng.choice([0.0, 0.001, 0.01, 0.1, 1.0, -0.5, -2.0]),
               rng.choice([0.0, 0.5, 1.0, 2.0, 5.0]))
              for _ in range(300)]
    _drive_bucket(rate, burst, events)


def test_token_bucket_burst_then_starve():
    b = TokenBucket(rate=1.0, burst=4.0)
    assert all(b.allow(0.0) for _ in range(4))      # burst drains
    assert not b.allow(0.0)                          # empty
    assert not b.allow(-10.0)                        # clock rewind: no refill
    assert b.allow(2.0) and b.allow(2.0)             # 2s -> 2 tokens
    assert not b.allow(2.0)
    assert b.admitted == 6 and b.rejected == 3


def test_token_bucket_rejects_bad_config():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=4.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.5)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=4.0).allow(0.0, cost=-1.0)


_MIS_FIELDS = ("invalid_frames", "rate_violations", "stale_tips",
               "unsolicited", "useful_blocks")


@pytest.mark.parametrize("seed", range(20))
def test_peerscore_ban_monotone_seeded(seed):
    rng = random.Random(seed)
    increments = [(rng.choice(_MIS_FIELDS), rng.randint(1, 4))
                  for _ in range(60)]
    _drive_score_monotone(increments)


def test_peerscore_useful_blocks_never_forgive():
    s = PeerScore(invalid_frames=5)                  # 100 points: banned
    assert s.banned()
    s.useful_blocks += 10 ** 6
    assert s.banned(), "useful blocks must not buy un-banning"
    assert s.score() > 0                             # ...but do rank higher


def test_peerscore_thresholds_match_weights():
    assert PeerScore(invalid_frames=5).misbehavior() == 5 * W_INVALID \
        == BAN_THRESHOLD
    assert PeerScore(rate_violations=10).misbehavior() == 10 * W_RATE \
        == BAN_THRESHOLD


def test_eviction_order_deterministic_and_total():
    scores = {"c": PeerScore(useful_blocks=2),
              "a": PeerScore(invalid_frames=1),
              "b": PeerScore(invalid_frames=1),
              "d": PeerScore()}
    order = eviction_order(scores)
    # worst first; equal scores tie-break by name — never insertion order
    assert order == ["a", "b", "d", "c"]
    shuffled = dict(sorted(scores.items(), reverse=True))
    assert eviction_order(shuffled) == order


# ---------------------------------------------------------------------------
# PeerBook invariants
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh_ids():
    return make_identities(8)


def _addrs(mesh_ids):
    identities, _ = mesh_ids         # dict: node id -> PeerIdentity
    return [make_addr(identities[i], "loopback", 9000 + i)
            for i in sorted(identities)]


def test_peerbook_rejects_malformed_and_forged(mesh_ids):
    identities, ring = mesh_ids
    book = PeerBook(self_id=0, keyring=ring)
    good = make_addr(identities[1], "loopback", 9001)
    assert book.add(good)
    bad_port = dataclasses.replace(good, port=0)
    bad_host = dataclasses.replace(good, host="x" * 300)
    bad_sig = dataclasses.replace(
        good, signature=bytes(64))
    forged_id = dataclasses.replace(
        make_addr(identities[2], "loopback", 9002), node_id=3)
    before = len(book)
    for bad in (bad_port, bad_host, bad_sig, forged_id):
        assert not bad.verify(ring)
        assert not book.add(bad)
        # verified=True skips crypto but never structural sanity
        if not bad.well_formed():
            assert not book.add(bad, verified=True)
    assert len(book) == before
    assert book.rejected >= 3


def test_peerbook_never_adds_self_or_banned(mesh_ids):
    identities, ring = mesh_ids
    book = PeerBook(self_id=1, keyring=ring)
    assert not book.add(make_addr(identities[1], "loopback", 9001))
    book.ban(2)
    assert not book.add(make_addr(identities[2], "loopback", 9002))
    assert 2 not in book and len(book) == 0


def test_peerbook_eviction_is_order_free(mesh_ids):
    identities, ring = mesh_ids
    addrs = _addrs(mesh_ids)[1:]                     # ids 1..7
    retained = []
    for order_seed in range(6):
        rng = random.Random(order_seed)
        shuffled = list(addrs)
        rng.shuffle(shuffled)
        book = PeerBook(self_id=0, keyring=ring, max_new=4, salt=7)
        for a in shuffled:
            book.add(a)
        retained.append(tuple(sorted(book.new)))
        assert len(book.new) == 4 and book.evicted == 3
    assert len(set(retained)) == 1, (
        f"retained set depends on arrival order: {retained}")


def test_peerbook_lifecycle_and_selection(mesh_ids):
    identities, ring = mesh_ids
    book = PeerBook(self_id=0, keyring=ring, max_failures=2)
    for a in _addrs(mesh_ids)[1:4]:                  # ids 1, 2, 3
        book.add(a)
    book.mark_connected(2)
    assert 2 in book.tried and 2 not in book.new
    # tried bucket is offered first
    sel = book.select(3)
    assert sel[0].node_id == 2
    assert {a.node_id for a in sel} == {1, 2, 3}
    # exclude filters connected/dialing ids
    assert {a.node_id for a in book.select(3, exclude={2})} == {1, 3}
    # failures demote then drop
    book.mark_failed(2)
    assert 2 in book.new
    book.mark_failed(2)
    assert 2 not in book
    # bans are permanent
    book.ban(3)
    assert 3 not in book
    assert not book.add(make_addr(identities[3], "loopback", 9003))
    assert all(a.node_id != 3 for a in book.select(8))


def test_peerbook_refreshes_moved_endpoint(mesh_ids):
    identities, ring = mesh_ids
    book = PeerBook(self_id=0, keyring=ring)
    old = make_addr(identities[1], "loopback", 9001)
    new = make_addr(identities[1], "loopback", 19001)
    assert book.add(old)                             # newly learned
    assert not book.add(new)                         # refresh: not novel
    assert book.new[1].port == 19001
    assert book.has_exact(new) and not book.has_exact(old)


# ---------------------------------------------------------------------------
# Hypothesis paths (skipped when the dependency is absent)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(rate=st.floats(min_value=0.1, max_value=64.0),
           burst=st.floats(min_value=1.0, max_value=128.0),
           events=st.lists(st.tuples(
               st.floats(min_value=-5.0, max_value=5.0),
               st.floats(min_value=0.0, max_value=8.0)), max_size=200))
    def test_token_bucket_admission_bound_hypothesis(rate, burst, events):
        _drive_bucket(rate, burst, events)

    @settings(max_examples=200, deadline=None)
    @given(increments=st.lists(st.tuples(
        st.sampled_from(_MIS_FIELDS), st.integers(1, 10)), max_size=100))
    def test_peerscore_ban_monotone_hypothesis(increments):
        _drive_score_monotone(increments)

else:

    @pytest.mark.skip(reason="hypothesis not installed; seeded "
                             "deterministic variants above cover the "
                             "same properties")
    def test_hypothesis_properties():
        pass


# ---------------------------------------------------------------------------
# per-source quotas (eclipse defense, DESIGN.md §15)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def flood_ids():
    return make_identities(40, seed=5)


def test_per_source_quota_bounds_gossip_flood(flood_ids):
    """One relay pushing 32 addrs may land at most its quota in the
    new bucket; a second relay still gets its own full slice."""
    identities, ring = flood_ids
    book = PeerBook(self_id=0, keyring=ring, max_new_per_source=4)
    for i in range(2, 34):
        book.add(make_addr(identities[i], "attacker", 9000 + i), source=1)
    charged = [nid for nid, s in book.sources.items() if s == 1]
    assert len(charged) == 4
    assert all(nid in book for nid in charged)
    for i in range(34, 40):
        book.add(make_addr(identities[i], "elsewhere", 9500 + i), source=2)
    assert sum(1 for s in book.sources.values() if s == 2) == 4


def test_per_source_quota_survivors_are_order_free(flood_ids):
    """Which of a relay's addrs survive its quota depends on the salted
    hash only — not on the order the flood arrived."""
    identities, ring = flood_ids
    addrs = [make_addr(identities[i], "attacker", 9000 + i)
             for i in range(1, 33)]
    survivors = []
    for order_seed in range(5):
        rng = random.Random(order_seed)
        shuffled = list(addrs)
        rng.shuffle(shuffled)
        book = PeerBook(self_id=0, keyring=ring, salt=11,
                        max_new_per_source=6)
        for a in shuffled:
            book.add(a, source=7)
        survivors.append(frozenset(nid for nid in book.sources))
    assert len(set(survivors)) == 1
    assert len(survivors[0]) == 6


def test_first_hand_discharges_relay_claim(flood_ids):
    """An addr learned through a relay is charged to that relay's
    quota — until the peer itself confirms it (its own HELLO addr, or
    a live connection), which upgrades it to first-hand: uncharged,
    and no longer evictable by the relay's flood."""
    identities, ring = flood_ids
    book = PeerBook(self_id=0, keyring=ring, max_new_per_source=2)
    confirmed = make_addr(identities[3], "loopback", 9003)
    assert book.add(confirmed, source=1)
    assert book.sources.get(3) == 1
    # the peer's own HELLO carries the same endpoint: discharge
    book.add(confirmed, source=None)
    assert 3 not in book.sources and 3 in book
    # relay 1 now floods: the confirmed entry never leaves the book
    for i in range(4, 20):
        book.add(make_addr(identities[i], "attacker", 9100 + i), source=1)
    assert 3 in book
    assert sum(1 for s in book.sources.values() if s == 1) == 2


def test_mark_connected_clears_source_charge(flood_ids):
    identities, ring = flood_ids
    book = PeerBook(self_id=0, keyring=ring)
    book.add(make_addr(identities[5], "loopback", 9005), source=2)
    assert book.sources.get(5) == 2
    book.mark_connected(5)
    assert 5 not in book.sources          # tried entries are first-hand
    assert 5 in book


def test_timeout_weight_reaches_ban_threshold():
    from repro.chain.net.peerbook import W_TIMEOUT
    s = PeerScore(timeouts=BAN_THRESHOLD // W_TIMEOUT)
    assert s.misbehavior() == BAN_THRESHOLD and s.banned()
    assert PeerScore(timeouts=1).misbehavior() == W_TIMEOUT
