"""Sharding-policy levers (EXPERIMENTS.md §Perf) stay numerically exact
and produce the intended PartitionSpecs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core import es as es_mod
from repro.models.model import build_model
from repro.sharding.partition import _spec_for, param_specs


def _amesh(shape, names):
    """AbstractMesh across jax versions: (shape, names) vs ((name, n), ...)."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


class TestLeversNumericallyExact:
    """constrain_kv / remat / fsdp must not change model outputs."""

    @pytest.mark.parametrize("flag", ["constrain_kv", "remat"])
    def test_flag_preserves_forward(self, flag):
        cfg = reduced(get_config("qwen3-0.6b"))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  cfg.vocab_size)
        a, _ = model.forward(params, {"tokens": toks})
        cfg2 = dataclasses.replace(cfg, **{flag: not getattr(cfg, flag)})
        b, _ = build_model(cfg2).forward(params, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


class TestExpertAxis:
    def test_expert_axis_model_default(self):
        mesh = _amesh((16, 16), ("data", "model"))
        spec = _spec_for("layers/moe/experts/w1", (35, 128, 7168, 4864),
                         mesh, True)
        assert spec == P(None, "model", "data", None)

    def test_expert_axis_data_moves_tensor_to_model(self):
        mesh = _amesh((16, 16), ("data", "model"))
        spec = _spec_for("layers/moe/experts/w1", (35, 128, 7168, 4864),
                         mesh, True, expert_axis="data")
        assert spec == P(None, "data", None, "model")

    def test_fsdp_pod_combines_axes(self):
        mesh = _amesh((2, 16, 16), ("pod", "data", "model"))
        spec = _spec_for("layers/mlp/w1", (35, 7168, 4864), mesh, True,
                         fsdp_pod=True)
        assert spec == P(None, ("pod", "data"), "model")

    def test_fsdp_pod_falls_back_when_indivisible(self):
        mesh = _amesh((2, 16, 16), ("pod", "data", "model"))
        # 48 % 32 != 0 -> falls back to plain data sharding (48 % 16 == 0)
        spec = _spec_for("layers/mlp/w1", (48, 64), mesh, True,
                         fsdp_pod=True)
        assert spec == P("data", "model")


class TestESCandidates:
    def test_candidate_zero_is_incumbent(self):
        params = {"w": jnp.ones((4, 4))}
        c0 = es_mod.candidate_params(params, jax.random.key(0),
                                     jnp.int32(0), 0.1)
        np.testing.assert_array_equal(np.asarray(c0["w"]),
                                      np.asarray(params["w"]))

    def test_antithetic_pairs_mirror(self):
        params = {"w": jnp.zeros((8,))}
        key = jax.random.key(3)
        c1 = es_mod.candidate_params(params, key, jnp.int32(1), 0.5)
        c2 = es_mod.candidate_params(params, key, jnp.int32(2), 0.5)
        np.testing.assert_allclose(np.asarray(c1["w"]),
                                   -np.asarray(c2["w"]), rtol=1e-6)

    def test_block_never_worse_than_incumbent(self):
        """With candidate 0 == params, the winning loss <= incumbent loss."""
        def eval_fn(p, batch):
            return jnp.sum(jnp.square(p["w"] - batch["t"]))
        params = {"w": jnp.asarray([3.0, -1.0])}
        batch = {"t": jnp.asarray([1.0, 1.0])}
        losses, best = es_mod.es_block(eval_fn, params, batch,
                                       jax.random.key(0), pop_size=9,
                                       sigma=0.1)
        assert float(losses[best]) <= float(losses[0]) + 1e-6
