"""Seeded-batch replay properties of ``data/pipeline.py`` — the
verification-soundness precondition for real-model PoUW: a verifier
re-derives the miner's microbatches from ``(seed, height, micro)``
alone, so a fresh ``SyntheticTokenPipeline`` instance must reproduce
bit-identical batches, always.

When Hypothesis is installed the properties get randomized search with
shrinking; without it, the same drivers run over seeded deterministic
parameter draws (20 seeds each), per the ``test_peerbook.py``
convention.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from repro.chain.workloads.model_train import MICRO_CONFIG
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.steps import tree_digest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SHAPE = InputShape("replay16x2", 16, 2, "train")


def _pipeline(seed: int) -> SyntheticTokenPipeline:
    return SyntheticTokenPipeline(MICRO_CONFIG, SHAPE, seed=seed)


# ---------------------------------------------------------------------------
# shared property drivers (called by both the seeded and Hypothesis paths)
# ---------------------------------------------------------------------------


def _drive_replay(seed: int, height: int, micro: int) -> str:
    """Two *fresh* pipeline instances must agree bit-exactly on
    ``microbatch(height, micro)`` — same arrays, same canonical
    digest."""
    a = _pipeline(seed).microbatch(height, micro)
    b = _pipeline(seed).microbatch(height, micro)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    da, db = tree_digest(a), tree_digest(b)
    assert da == db
    return da


def _drive_positions_distinct(seed: int, height: int, micro: int) -> None:
    """Adjacent chain positions draw different batches (the stream is
    keyed, not constant), and the microbatch stream never aliases the
    plain ``batch(step)`` stream at the same indices."""
    p = _pipeline(seed)
    here = tree_digest(p.microbatch(height, micro))
    assert here != tree_digest(p.microbatch(height, micro + 1))
    assert here != tree_digest(p.microbatch(height + 1, micro))
    assert here != tree_digest(p.batch(height))


# ---------------------------------------------------------------------------
# deterministic seeded paths (always run)
# ---------------------------------------------------------------------------


def test_replay_bit_identical_seeded():
    rng = random.Random(1234)
    for _ in range(20):
        _drive_replay(rng.randrange(1 << 16), rng.randrange(256),
                      rng.randrange(8))


def test_positions_distinct_seeded():
    rng = random.Random(4321)
    for _ in range(20):
        _drive_positions_distinct(rng.randrange(1 << 16),
                                  rng.randrange(256), rng.randrange(8))


def test_replay_stable_across_instances_and_calls():
    """The same position queried repeatedly — and interleaved with other
    positions — never drifts (the pipeline holds no hidden cursor)."""
    p = _pipeline(7)
    first = tree_digest(p.microbatch(3, 1))
    for h, m in [(0, 0), (3, 0), (9, 2), (3, 1), (1, 1)]:
        p.microbatch(h, m)
    assert tree_digest(p.microbatch(3, 1)) == first
    assert tree_digest(_pipeline(7).microbatch(3, 1)) == first


def test_different_seeds_differ():
    assert tree_digest(_pipeline(0).microbatch(0, 0)) != \
        tree_digest(_pipeline(1).microbatch(0, 0))


def test_negative_micro_rejected():
    with pytest.raises(ValueError):
        _pipeline(0).microbatch(0, -1)


def test_labels_shifted_tokens():
    """Train-kind microbatches carry next-token labels, like the plain
    batch stream."""
    b = _pipeline(3).microbatch(5, 0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])
    assert (labels[:, -1] == -1).all()


# ---------------------------------------------------------------------------
# Hypothesis paths (richer randomized search when available)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 1 << 16), height=st.integers(0, 1 << 20),
           micro=st.integers(0, 64))
    @settings(max_examples=25, deadline=None)
    def test_replay_bit_identical_hypothesis(seed, height, micro):
        _drive_replay(seed, height, micro)

    @given(seed=st.integers(0, 1 << 16), height=st.integers(0, 1 << 10),
           micro=st.integers(0, 16))
    @settings(max_examples=25, deadline=None)
    def test_positions_distinct_hypothesis(seed, height, micro):
        _drive_positions_distinct(seed, height, micro)

else:

    @pytest.mark.skip(reason="hypothesis not installed — the seeded "
                             "deterministic drivers above cover the same "
                             "properties")
    def test_replay_bit_identical_hypothesis():
        pass
