"""PoUW training chain: determinism, auditability, rewards, checkpoints."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core.pow_train import PoUWTrainer
from repro.train.checkpoint import (load_checkpoint, save_checkpoint,
                                    state_digest)
from repro.train.steps import TrainHparams, make_train_state

CFG = reduced(get_config("qwen3-0.6b"))
SHAPE = InputShape("t", 32, 4, "train")
HP = TrainHparams(peak_lr=1e-3, warmup_steps=2, total_steps=50)


@pytest.fixture(scope="module")
def full_chain():
    tr = PoUWTrainer(CFG, SHAPE, hp=HP, mode="full", n_miners=4)
    tr.run(4)
    return tr


class TestFullChain:
    def test_chain_verifies(self, full_chain):
        assert full_chain.ledger.verify_chain()

    def test_losses_finite(self, full_chain):
        assert all(np.isfinite(r.loss) for r in full_chain.history)

    def test_rewards_split_evenly(self, full_chain):
        vals = list(full_chain.book.balances.values())
        assert len(vals) == 4
        assert np.allclose(vals, vals[0])
        assert np.isclose(full_chain.book.total_issued, 4 * 50.0)

    def test_audit_replays_bit_exact(self, full_chain):
        assert full_chain.audit_block(2)

    def test_digest_changes_every_block(self, full_chain):
        digests = [r.state_digest for r in full_chain.history]
        assert len(set(digests)) == len(digests)

    def test_block_jash_is_bounded(self, full_chain):
        # the published train step passed §3 validation at construction
        assert full_chain.step_jash._jaxpr_ok


class TestOptimalChain:
    def test_winner_rewarded(self):
        tr = PoUWTrainer(CFG, SHAPE, hp=HP, mode="optimal", n_miners=4,
                         pop_size=6, sigma=0.02)
        tr.run(3)
        assert tr.ledger.verify_chain()
        assert np.isclose(tr.book.total_issued, 3 * 50.0)
        for blk in tr.ledger.blocks:
            assert blk.winner is not None
            assert blk.mode == "optimal"

    def test_determinism_same_seed(self):
        a = PoUWTrainer(CFG, SHAPE, hp=HP, mode="optimal", pop_size=4,
                        sigma=0.02, seed=3)
        b = PoUWTrainer(CFG, SHAPE, hp=HP, mode="optimal", pop_size=4,
                        sigma=0.02, seed=3)
        ra, rb = a.run(2), b.run(2)
        assert [r.state_digest for r in ra] == [r.state_digest for r in rb]


class TestCheckpoint:
    def test_roundtrip_and_digest(self, tmp_path):
        state = make_train_state(CFG, jax.random.key(0))
        path = os.path.join(tmp_path, "ck.npz")
        d1 = save_checkpoint(path, state, {"block": 1})
        restored, d2 = load_checkpoint(path, state)
        assert d1 == d2
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_digest_detects_mutation(self):
        state = make_train_state(CFG, jax.random.key(0))
        d1 = state_digest(state)
        state2 = make_train_state(CFG, jax.random.key(1))
        assert d1 != state_digest(state2)
