"""Credit conservation (PNPCoin §4): the PoUW analogue of the coin only
holds value if every block's reward is conserved — for any sequence of
full/optimal blocks, any miner assignment, and any ``bonus_fraction``
split, the credits issued equal the sum of balances equal the sum of
block rewards."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.rewards import CreditBook, reward_full, reward_optimal

# a block is either full (submitter list + optional bonus winner + split
# fraction) or optimal (winner takes all)
_full_block = st.tuples(
    st.just("full"),
    st.lists(st.integers(0, 15), min_size=1, max_size=48),
    st.one_of(st.none(), st.integers(0, 15)),
    st.floats(0.0, 0.9, allow_nan=False))
_optimal_block = st.tuples(st.just("optimal"), st.integers(0, 15))


@given(blocks=st.lists(st.one_of(_full_block, _optimal_block),
                       min_size=1, max_size=24),
       block_reward=st.floats(0.5, 200.0, allow_nan=False))
@settings(max_examples=80, deadline=None)
def test_credit_conservation(blocks, block_reward):
    book = CreditBook()
    for blk in blocks:
        if blk[0] == "full":
            _, submitters, bonus_winner, bonus_fraction = blk
            reward_full(book, submitters, block_reward,
                        bonus_winner=bonus_winner,
                        bonus_fraction=bonus_fraction)
        else:
            reward_optimal(book, blk[1], block_reward)

    minted = len(blocks) * block_reward
    assert np.isclose(book.total_issued, minted, rtol=1e-9, atol=1e-9)
    assert np.isclose(sum(book.balances.values()), book.total_issued,
                      rtol=1e-9, atol=1e-9)


@given(n=st.integers(1, 64), bonus_fraction=st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_bonus_split_exact(n, bonus_fraction):
    """The §4 leading-zeros bonus carves its fraction out of the base
    split — it must never mint extra credit."""
    book = CreditBook()
    reward_full(book, list(range(n)), 50.0, bonus_winner=0,
                bonus_fraction=bonus_fraction)
    assert np.isclose(book.total_issued, 50.0, rtol=1e-9)
    assert np.isclose(sum(book.balances.values()), 50.0, rtol=1e-9)


def test_empty_block_mints_nothing():
    book = CreditBook()
    reward_full(book, [], 50.0)
    assert book.total_issued == 0.0 and book.balances == {}
